"""Topology layer: hierarchical ClusterSpec pricing, the depth-2
flat adapter (byte-identical to the legacy two-bandwidth model),
level-monotonicity properties, level-k plan evaluation, heterogeneous
memory feasibility, and hybrid TP/PP topology placement."""
import math
import random

import pytest

from repro.cluster.topology import (ClusterLevel, ClusterSpec, DeviceGroup,
                                    gpu_cluster, mixed_memory_fleet,
                                    tpu_multipod)
from repro.configs import (DeviceInfo, MULTI_POD_MESH, SINGLE_POD_MESH,
                           MeshConfig, OSDPConfig, get_arch, get_shape)
from repro.core.cost_model import (DP, ZDP, ZDP_POD, CostEnv, Decision,
                                   PlanEvaluator, op_cost, plan_cost,
                                   uniform_plan)
from repro.core.descriptions import OperatorDesc, describe
from repro.core.search import schedule, search_hybrid, search_plan

DEV = DeviceInfo()


def _flat_ring(nbytes, n, alpha, bw):
    return 0.0 if n <= 1 else (n - 1) * (alpha + nbytes / n / bw)


# --- the depth-2 degenerate case ---------------------------------------------

def test_depth2_adapter_shape():
    spec = ClusterSpec.from_flat(DEV, MULTI_POD_MESH)
    assert spec.depth == 2
    assert spec.n_devices == 32
    assert spec.span_ways(1) == 16
    assert spec.mode_names == (DP, ZDP, ZDP_POD)
    assert spec.mode_span(ZDP) == 2
    assert spec.mode_span(ZDP_POD) == 1
    assert spec.shard_ways(ZDP) == 32
    assert spec.shard_ways(ZDP_POD) == 16
    assert spec.shard_ways(DP) == 1


def test_hierarchical_ring_equals_flat_ring_at_depth_1():
    """A single-level span must price exactly like the classic flat
    ring (n-1)(alpha + B/n/bw) — the degenerate identity every deeper
    formula builds on (1e-12, per the refactor contract)."""
    for n, bw, nbytes in ((8, 12e9, 1e9), (16, 50e9, 3.7e8),
                          (256, 450e9, 1e11), (2, 1e9, 1.0)):
        spec = ClusterSpec(
            levels=(ClusterLevel("data", n, bw, DEV.alpha),), device=DEV)
        got = spec.ring_time(nbytes, 1)
        want = _flat_ring(nbytes, n, DEV.alpha, bw)
        assert got == pytest.approx(want, rel=1e-12)


def test_depth2_single_pod_op_cost_matches_legacy_flat_formula():
    """On a single-pod mesh the depth-2 adapter must reproduce the
    pre-topology flat formulas to 1e-12: ZDP = rounds flat rings over
    the data extent, DP = 2 rings."""
    env = CostEnv(DEV, SINGLE_POD_MESH, checkpointing=False)
    op = OperatorDesc("op", 10**9, 1e9, 64.0, layers=4)
    n = env.n_data
    p = op.param_bytes / env.n_tp
    c_dp = op_cost(op, Decision("op", (DP,)), 8, 1024, env)
    want_dp = 2 * _flat_ring(p, n, DEV.alpha, DEV.ici_bw)
    assert c_dp.comm_time == pytest.approx(want_dp, rel=1e-12)
    c_z = op_cost(op, Decision("op", (ZDP,)), 8, 1024, env)
    want_z = 3 * _flat_ring(p, n, DEV.alpha, DEV.ici_bw)
    assert c_z.comm_time == pytest.approx(want_z, rel=1e-12)


def test_depth2_multi_pod_zdp_pod_matches_legacy():
    """ZDP_POD pricing (in-pod gather + cross-pod grad all-reduce) is
    unchanged by the hierarchical refactor."""
    env = CostEnv(DEV, MULTI_POD_MESH, checkpointing=False)
    op = OperatorDesc("op", 10**9, 0.0, 0.0, layers=1)
    p = op.param_bytes / env.n_tp
    n_l, n_p = 16, 2
    c = op_cost(op, Decision("op", (ZDP_POD,)), 8, 1024, env)
    want = (3 * _flat_ring(p, n_l, DEV.alpha, DEV.ici_bw)
            + 2 * _flat_ring(p / n_l, n_p, DEV.alpha, DEV.dci_bw))
    assert c.comm_time == pytest.approx(want, rel=1e-12)


def test_multi_pod_zdp_priced_hierarchically_not_bottleneck():
    """Full-span ZDP on a multi-pod adapter now runs one ring per
    level instead of a flat ring at the bottleneck (DCI) bandwidth —
    strictly cheaper, and equal to the explicit per-level sum."""
    env = CostEnv(DEV, MULTI_POD_MESH, checkpointing=False)
    op = OperatorDesc("op", 10**9, 0.0, 0.0, layers=1)
    p = op.param_bytes / env.n_tp
    n_l, n_p = 16, 2
    n = n_l * n_p
    c = op_cost(op, Decision("op", (ZDP,)), 8, 1024, env)
    want = 3 * ((n_l - 1) * (DEV.alpha + p / n / DEV.ici_bw)
                + (n_p - 1) * (DEV.alpha + p * n_l / n / DEV.dci_bw))
    assert c.comm_time == pytest.approx(want, rel=1e-12)
    bottleneck = 3 * _flat_ring(p, n, DEV.alpha, DEV.dci_bw)
    assert c.comm_time < bottleneck


# --- level monotonicity properties -------------------------------------------

def _three_level(bw2=4e9, ways=(4, 4, 4)):
    return ClusterSpec(levels=(
        ClusterLevel("chip", ways[0], 50e9, 1e-6),
        ClusterLevel("node", ways[1], 20e9, 1e-6),
        ClusterLevel("pod", ways[2], bw2, 1e-6)), device=DEV)


def test_deeper_spans_shard_more_but_cost_more():
    """Widening the span of a collective can only add time (more ways
    at slower levels never cheapen it) while sharding more ways."""
    spec = _three_level()
    nbytes = 1e9
    times = [spec.ring_time(nbytes, k) for k in range(1, 4)]
    ways = [spec.span_ways(k) for k in range(1, 4)]
    assert times == sorted(times)
    assert times[0] < times[1] < times[2]
    assert ways == [4, 16, 64]


@pytest.mark.parametrize("slow_bw", [1e9, 5e9, 10e9])
def test_more_ways_at_a_slower_level_never_cheapens(slow_bw):
    """Growing the ways of any (slower-or-equal) outer level never
    reduces a collective spanning it: the hierarchy price is monotone
    in every level's fan-out."""
    base = _three_level(bw2=slow_bw)
    for extra in (2, 4):
        grown = _three_level(bw2=slow_bw, ways=(4, 4, 4 * extra))
        for nbytes in (1e6, 1e9, 1e11):
            assert grown.ring_time(nbytes, 3) \
                >= base.ring_time(nbytes, 3) - 1e-15


def test_span_rings_prefix_products():
    spec = _three_level()
    rings = spec.gather_rings(3)
    assert [(w, pre) for w, _, _, pre in rings] == [(4, 1), (4, 4),
                                                   (4, 16)]
    outer = spec.outer_rings(1)
    assert [(w, pre) for w, _, _, pre in outer] == [(4, 1), (4, 4)]


# --- level-k plans through the evaluator -------------------------------------

def test_evaluator_matches_plan_cost_on_level_k_plans():
    """Random plans over the full level-k mode set of a depth-3 spec
    must evaluate identically through the tables and the direct
    op_cost walk."""
    spec = _three_level()
    env = CostEnv(DEV, cluster=spec, checkpointing=False)
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    modes = env.topo.mode_names
    assert modes == (DP, ZDP, "ZDP@1", "ZDP@2")
    rng = random.Random(7)
    for trial in range(5):
        decs = {}
        for op in desc.operators:
            if not op.decidable:
                decs[op.name] = Decision(op.name, (DP,))
                continue
            g = rng.choice([1, 2, 4]) if op.splittable else 1
            decs[op.name] = Decision(
                op.name, tuple(rng.choice(modes) for _ in range(g)))
        for batch in (64, 256):
            want = plan_cost(desc, decs, batch, env)
            ev = PlanEvaluator.for_decisions(desc, env, decs)
            got = ev.plan_cost(ev.modes_from_decisions(decs), batch)
            for f in ("memory", "peak_memory", "time", "comm_time",
                      "compute_time"):
                assert getattr(got, f) == pytest.approx(
                    getattr(want, f), rel=1e-9, abs=1e-12), (trial, f)


def test_level_k_flip_deltas_track_full_evaluation():
    spec = _three_level()
    env = CostEnv(DEV, cluster=spec, checkpointing=False)
    desc = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    gran = {op.name: (4 if op.splittable else 1)
            for op in desc.decidable()}
    ev = PlanEvaluator(desc, env, gran)
    import numpy as np
    ev.begin(np.zeros(ev.n_slices, dtype=np.int8), 128)
    rng = random.Random(3)
    for step in range(150):
        ev.flip(rng.randrange(ev.n_slices), rng.randrange(ev.n_ext))
        if step % 30 == 0:
            want = plan_cost(desc, ev.decisions(ev.current_modes), 128,
                             env)
            got = ev.result()
            assert got.memory == pytest.approx(want.memory, rel=1e-9)
            assert got.time == pytest.approx(want.time, rel=1e-9)


def test_search_uses_level_k_modes_on_deep_topologies():
    """With a 3-level spec whose outer level is slow, the searched plan
    should place some mass at an intermediate level (ZDP@k) — the new
    axis the flat model could not express."""
    spec = _three_level(bw2=2e9)
    env = CostEnv(DEV, cluster=spec, checkpointing=True)
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    res = search_plan(desc, 256, env, OSDPConfig(
        memory_limit_bytes=16 * 2**30, allow_pod_hierarchical=True))
    assert res.feasible
    used = {m for d in res.decisions.values() for m in d.modes}
    assert any(m.startswith("ZDP@") for m in used), used


# --- heterogeneous memory ----------------------------------------------------

def test_mixed_memory_feasibility_flip():
    """A fleet of small+large devices whose even-shard footprint busts
    the small group's budget: infeasible under the uniform flat model
    (limit = worst device, even shards), feasible with
    capacity-weighted sharding against per-group limits."""
    desc = describe(get_arch("arctic-480b"), get_shape("train_4k"))
    het = mixed_memory_fleet(128, 24, 128, 80, pod_size=64, device=DEV)
    # uniform view of the same fleet: every device gets the worst
    # group's budget and an even 1/N shard
    flat_env = CostEnv(DEV, MeshConfig((256, 1), ("data", "model")),
                       checkpointing=True)
    flat = schedule(desc, flat_env, OSDPConfig(
        memory_limit_bytes=het.min_hbm, allow_pod_hierarchical=False),
        batch_candidates=[256])
    het_env = CostEnv(DEV, cluster=het, checkpointing=True)
    aware = schedule(desc, het_env, OSDPConfig(
        memory_limit_bytes=het.min_hbm, allow_pod_hierarchical=True),
        batch_candidates=[256])
    assert not flat.feasible
    assert aware.feasible
    assert aware.cost.memory <= het.min_hbm


def test_weighted_shard_ways():
    het = mixed_memory_fleet(8, 16, 8, 48, pod_size=8, device=DEV)
    # total capacity 8*16 + 8*48 = 512 GiB; binding group 16 GiB
    assert het.shard_ways(ZDP) == pytest.approx(512 / 16)
    assert het.shard_ways(ZDP) > het.n_devices
    # inner spans stay within one (uniform) pod: even sharding
    assert het.shard_ways(ZDP_POD) == 8
    assert het.memory_limit(123.0) == 16 * 2**30
    uniform = tpu_multipod(2, 8, DEV)
    assert uniform.memory_limit(123.0) == 123.0


def test_group_coverage_validated():
    with pytest.raises(ValueError):
        ClusterSpec(levels=(ClusterLevel("data", 8, 50e9),), device=DEV,
                    groups=(DeviceGroup("g", 4, 16 * 2**30),))


def test_interior_degenerate_levels_rejected():
    """A ways>1 level outside a ways==1 level would desynchronize the
    level-index <-> mesh-axis mapping (mesh_config drops ways-1 axes),
    so construction rejects it; trailing (outermost) ways-1 levels are
    fine — from_flat relies on them."""
    with pytest.raises(ValueError):
        ClusterSpec(levels=(ClusterLevel("chip", 4, 50e9),
                            ClusterLevel("node", 1, 20e9),
                            ClusterLevel("pod", 2, 2e9)), device=DEV)
    ClusterSpec(levels=(ClusterLevel("chip", 4, 50e9),
                        ClusterLevel("pod", 1, 2e9)), device=DEV)
    # degenerate data axis: from_flat folds the pod extent inward
    folded = ClusterSpec.from_flat(
        DEV, MeshConfig((2, 16), ("pod", "model")))
    assert folded.span_ways(1) == 2
    assert folded.levels[0].bandwidth == DEV.dci_bw


# --- hybrid placement on a topology ------------------------------------------

A100_2SERVER = DeviceInfo(
    name="2x8-a100", peak_flops=312e12, hbm_bytes=40 * 2**30,
    hbm_bw=1555e9, ici_bw=300e9, dci_bw=12.5e9, alpha=5e-6,
    mxu_efficiency=0.45, devices_per_node=8)


def test_tp_spanning_node_boundary_priced_at_slow_link():
    """Regression for the legacy bug: TP all-reduces were charged
    `ici_bw` unconditionally even when the TP group spanned the
    node/pod boundary.  On a 2-node NVLink/IB cluster, tp=16 must pay
    the slow inter-node link and cost far more than tp=8."""
    from repro.core.hybrid import tp_activation_time
    desc = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    cluster = ClusterSpec.from_device(A100_2SERVER, 16)
    assert cluster.depth == 2 and cluster.span_ways(1) == 8
    t8 = tp_activation_time(desc, A100_2SERVER, 8, 8, cluster)
    t16 = tp_activation_time(desc, A100_2SERVER, 8, 16, cluster)
    t16_legacy = tp_activation_time(desc, A100_2SERVER, 8, 16)
    # the legacy path underpriced the spanning group by ~ici/dci
    assert t16 > 5 * t16_legacy
    assert t16 > 2 * t8
    # within the node, topology and legacy pricing agree
    assert t8 == pytest.approx(
        tp_activation_time(desc, A100_2SERVER, 8, 8), rel=1e-12)


def test_search_hybrid_keeps_tp_inside_the_node():
    """Given the choice, the hybrid search on a 2-node cluster must
    not pick a TP extent that spans the IB link when an in-node
    factorization exists."""
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"),
                    per_layer=False)
    cluster = ClusterSpec.from_device(A100_2SERVER, 16)
    osdp = OSDPConfig(memory_limit_bytes=12 * 2**30,
                      checkpointing=True)
    plan = search_hybrid(desc, A100_2SERVER, 16, osdp,
                         batch_candidates=[32], cluster=cluster)
    assert plan.feasible
    assert plan.tp <= 8, plan.factorization
    assert plan.cluster is cluster


def test_pp_boundary_bandwidth_outermost():
    spec = _three_level()
    # pp=4 splits at the outermost level; pp=16 reaches the middle one
    assert spec.pp_boundary_bandwidth(4) == 4e9
    assert spec.pp_boundary_bandwidth(16) == 20e9
    assert spec.pp_boundary_bandwidth(1) == 50e9


def test_consume_inner_outer():
    spec = _three_level()                      # 4 x 4 x 4
    resid = spec.consume_inner(8)              # tp=8: chip + half node
    assert [l.ways for l in resid.levels] == [2, 4]
    resid2 = spec.consume_outer(4)             # pp=4: the pod level
    assert [l.ways for l in resid2.levels] == [4, 4]
    both = spec.consume_inner(4).consume_outer(4)
    assert [l.ways for l in both.levels] == [4]
    with pytest.raises(ValueError):
        spec.consume_inner(3)
    with pytest.raises(ValueError):
        spec.consume_inner(128)


# --- mesh derivation ---------------------------------------------------------

def test_mesh_config_from_cluster():
    spec = _three_level()
    cfg = spec.mesh_config(model_parallel=2)
    assert cfg.shape == (4, 4, 4, 2)
    assert cfg.axes == ("pod", "node", "chip", "model")
    # MeshConfig.data_parallel only counts legacy pod/data axis names;
    # cluster-aware code reads the extent from the spec instead
    assert cfg.data_parallel == 4
    assert cfg.model_parallel == 2
    flat = ClusterSpec.from_flat(DEV, MULTI_POD_MESH)
    cfg2 = flat.mesh_config(model_parallel=16)
    assert cfg2.shape == (2, 16, 16)
    assert cfg2.axes == ("pod", "data", "model")


def test_to_flat_collapses_to_bottleneck():
    spec = _three_level(bw2=4e9)
    dev, mesh = spec.to_flat()
    assert dev.ici_bw == 50e9
    assert dev.dci_bw == 4e9             # slowest outer level
    assert mesh.shape == (16, 4, 1)
    assert mesh.axes == ("pod", "data", "model")


def test_level_k_plan_materializes_on_cluster_mesh():
    """End-to-end: a searched level-k plan must build real
    NamedShardings on the cluster-derived mesh (subprocess with 64
    forced host devices) — regression for batch/data axis assumptions
    hard-coded to the legacy ('pod', 'data') names."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=64"
        from repro.cluster import ClusterLevel, ClusterSpec
        from repro.configs import (DeviceInfo, OSDPConfig, RunConfig,
                                   get_arch, get_shape)
        from repro.core.plan import make_plan
        from repro.launch.mesh import make_cluster_mesh
        from repro.models.registry import (build_model, input_specs,
                                           input_shardings)
        spec = ClusterSpec(levels=(
            ClusterLevel("chip", 4, 50e9, 1e-6),
            ClusterLevel("node", 4, 20e9, 1e-6),
            ClusterLevel("pod", 4, 2e9, 1e-6)), device=DeviceInfo())
        run = RunConfig(
            model=get_arch("phi4-mini-3.8b"), shape=get_shape("train_4k"),
            mesh=spec.mesh_config(), osdp=OSDPConfig(
                memory_limit_bytes=16 * 2**30,
                allow_pod_hierarchical=True))
        plan = make_plan(run, cluster=spec)
        used = {m for d in plan.decisions.values() for m in d.modes}
        assert any(m.startswith("ZDP@") for m in used), used
        mesh = make_cluster_mesh(spec)
        built = build_model(run, plan, mesh)
        specs = [str(sh.spec) for sh in built.shardings.values()]
        assert any("'chip'" in s or "'node'" in s for s in specs), specs
        sh = input_shardings(run, mesh, input_specs(run))
        assert "'pod', 'node', 'chip'" in str(sh["tokens"].spec)
        print("MATERIALIZED-OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MATERIALIZED-OK" in r.stdout


def test_presets_build():
    g = gpu_cluster(8, 8, nvlink_bw=450e9, ib_bw=50e9)
    assert g.n_devices == 64 and g.depth == 2
    g3 = gpu_cluster(16, 8, spine_nodes=4, ib_bw=50e9, spine_bw=25e9)
    assert g3.depth == 3 and g3.n_devices == 128
    t = tpu_multipod(4, 64)
    assert t.n_devices == 256
    assert "cluster[256]" in t.summary()
