"""Dry-run smoke (deliverable e, CI-sized): lower+compile a small but
real subset of (arch x shape x mesh) combos in a subprocess with the
512-device flag — one per step kind plus one multi-pod."""
import os
import subprocess
import sys

import pytest

COMBOS = [
    ("qwen1.5-0.5b", "train_4k", []),
    ("mamba2-2.7b", "long_500k", []),
    ("dbrx-132b", "decode_32k", []),
    ("hubert-xlarge", "prefill_32k", ["--multi-pod"]),
]


@pytest.mark.parametrize("arch,shape,extra", COMBOS)
def test_dryrun_combo(arch, shape, extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape] + extra,
        capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "0 failed" in r.stdout, r.stdout[-2000:]
