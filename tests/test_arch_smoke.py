"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
variant of the same family (2 layers, d_model<=512, <=4 experts) and
run one forward + one train step on CPU, asserting output shapes and
no NaNs. Decoder archs additionally run prefill + one decode step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_run
from repro.configs import ARCHS, get_arch, reduced
from repro.models.registry import build_model
from repro.optim import AdamWConfig
from repro.train.loop import make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_valid(arch):
    cfg = reduced(get_arch(arch))
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.moe_experts <= 4
    cfg.validate()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    run = tiny_run(arch)
    built = build_model(run)
    cfg = run.model
    params = built.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    x, aux = jax.jit(built.model.forward)(params, batch)
    assert x.shape == (B, S, cfg.d_model), (arch, x.shape)
    assert np.isfinite(np.asarray(x, np.float32)).all(), arch
    logits = built.model.logits(params, x)
    assert logits.shape == (B, S, cfg.padded_vocab)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    run = tiny_run(arch)
    built = build_model(run)
    step_fn, init_fn = make_train_step(built, AdamWConfig(lr=1e-3),
                                       donate=False)
    params, opt = init_fn(jax.random.PRNGKey(0))
    batch = make_batch(run.model, 2, 64)
    p2, opt2, metrics = step_fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(params[k], np.float32),
                           np.asarray(p2[k], np.float32))
        for k in params)
    assert changed, f"{arch}: no parameter moved"


DECODERS = [a for a in ALL_ARCHS if ARCHS[a].is_decoder]


@pytest.mark.parametrize("arch", DECODERS)
def test_prefill_decode(arch):
    run = tiny_run(arch, shape="decode_32k")
    built = build_model(run)
    cfg = run.model
    m = built.model
    params = built.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
    logits, caches = jax.jit(m.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1
                     ).astype(jnp.int32)[:, None]
    kw = {}
    if cfg.rope == "mrope":
        kw["positions3"] = jnp.full((B, 1, 3), S, jnp.int32)
    lg2, caches2 = jax.jit(m.decode_step)(params, caches, tok, jnp.int32(S),
                                          **kw)
    assert lg2.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", [
    "mamba2-2.7b",
    pytest.param("hymba-1.5b", marks=pytest.mark.xfail(
        reason="known pre-existing hymba decode numerics drift: the "
               "attn+ssm mean block's stepwise decode disagrees with "
               "the full forward beyond bf16 tolerance (see "
               "CHANGES.md); not a regression", strict=False)),
])
def test_decode_matches_full_forward(arch):
    """Sub-quadratic archs: stepwise decode == full forward (recurrence
    correctness), up to bf16 noise."""
    run = tiny_run(arch, shape="decode_32k")
    built = build_model(run)
    cfg = run.model
    m = built.model
    params = built.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    logits_full, _ = jax.jit(m.prefill)(params, {"tokens": toks})
    _, caches = jax.jit(m.prefill)(params, {"tokens": toks[:, :S]})
    lg, _ = jax.jit(m.decode_step)(params, caches, toks[:, S:S + 1],
                                   jnp.int32(S))
    a = np.asarray(lg[:, 0, :cfg.vocab_size], np.float32)
    b = np.asarray(logits_full[:, 0, :cfg.vocab_size], np.float32)
    np.testing.assert_allclose(a, b, atol=0.15, rtol=0.1)


def test_encoder_only_skips():
    cfg = get_arch("hubert-xlarge")
    from repro.configs import supported_shapes
    shapes = supported_shapes(cfg)
    assert "decode_32k" not in shapes and "long_500k" not in shapes
    assert set(shapes) == {"train_4k", "prefill_32k"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_matches_description(arch):
    from repro.core.descriptions import describe, sanity_check
    from repro.configs import get_shape
    cfg = get_arch(arch)
    desc = describe(cfg, get_shape("train_4k"))
    sanity_check(desc)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_built_params_match_logical_count(arch):
    """Materialized reduced-model params == closed-form count (+ padding)."""
    run = tiny_run(arch)
    built = build_model(run)
    cfg = run.model
    params = built.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in params.values())
    want = cfg.param_count()
    # stored count may exceed logical due to query-head padding (none on
    # the 1-way test mesh) — on tp=1 they must match exactly
    assert n == want, (arch, n, want)
