"""Distributed semantics on forced multi-device CPU (subprocess — jax
locks the device count at first init, so these run out-of-process).

The ZeRO invariant the whole paper rests on: DP, ZDP, and any mixed
OSDP plan compute IDENTICAL training trajectories — sharding changes
where bytes live, never the math. We train the same tiny model for 3
steps under three plans on a 4-device (2 data x 2 model) mesh and
compare losses bitwise-ish (fp32 tolerance).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# Known pre-existing environment failure, not a code regression: the
# subprocess scripts drive jax.set_mesh, which the CPU-only jax 0.4.x
# in this image does not have yet.
pytestmark = pytest.mark.skipif(
    not hasattr(jax, "set_mesh"),
    reason="distributed semantics tests need jax.set_mesh (>=0.6); "
           "the CPU-only jax in this environment predates it")


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=560)


COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import (OSDPConfig, RunConfig, MeshConfig, get_arch,
                           get_shape, reduced)
from repro.core.plan import make_plan, data_sharding
from repro.models.registry import build_model, input_shardings
from repro.train.loop import make_train_step
from repro.optim import AdamWConfig

def make_batch(cfg, B, S, key=0):
    k = jax.random.PRNGKey(key)
    return {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }

def losses_for(force_mode, split, arch="qwen1.5-0.5b", steps=3):
    cfg = reduced(get_arch(arch))
    mesh_cfg = MeshConfig((2, 2), ("data", "model"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                global_batch=4)
    osdp = OSDPConfig(force_mode=force_mode, operator_splitting=split > 1,
                      default_slice_granularity=max(split, 1))
    run = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg, osdp=osdp)
    plan = make_plan(run)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    built = build_model(run, plan, mesh)
    with jax.set_mesh(mesh):
        step_fn, init_fn = make_train_step(built, AdamWConfig(lr=1e-3),
                                           donate=False)
        params, opt = init_fn(jax.random.PRNGKey(0))
        out = []
        for s in range(steps):
            batch = make_batch(cfg, 4, 64, key=s)
            dsh = data_sharding(mesh)
            batch = {k: jax.device_put(v, NamedSharding(
                mesh, P(("data",), *([None] * (v.ndim - 1)))))
                for k, v in batch.items()}
            params, opt, metrics = step_fn(params, opt, batch)
            out.append(float(metrics["loss"]))
        return out
"""



def test_dp_zdp_mixed_same_trajectory():
    code = COMMON + textwrap.dedent("""
        l_dp = losses_for("DP", 1)
        l_zdp = losses_for("ZDP", 1)
        l_split = losses_for("ZDP", 2)
        print("DP  ", l_dp)
        print("ZDP ", l_zdp)
        print("SPLT", l_split)
        np.testing.assert_allclose(l_dp, l_zdp, rtol=2e-2, atol=2e-2)
        print("EQUIV_OK")
    """)
    r = _run(code)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "EQUIV_OK" in r.stdout, r.stdout


def test_train_step_lowers_with_collectives():
    """On the 2x2 mesh the ZDP plan's HLO must contain all-gathers of
    parameters and reduce-scatters of gradients."""
    code = COMMON + textwrap.dedent("""
        import dataclasses
        from repro.launch.mesh import make_mesh_from_config
        cfg = reduced(get_arch("qwen1.5-0.5b"))
        mesh_cfg = MeshConfig((2, 2), ("data", "model"))
        shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                    global_batch=4)
        run = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                        osdp=OSDPConfig(force_mode="ZDP",
                                        operator_splitting=False))
        plan = make_plan(run)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        built = build_model(run, plan, mesh)
        with jax.set_mesh(mesh):
            step_fn, init_fn = make_train_step(built, donate=False)
            params, opt = init_fn(jax.random.PRNGKey(0))
            batch = make_batch(cfg, 4, 64)
            lowered = step_fn.lower(params, opt, batch)
            compiled = lowered.compile()
            txt = compiled.as_text()
        from repro.roofline.analysis import analyze_lowered
        coll = analyze_lowered(txt)
        assert "all-gather" in coll, list(coll)
        assert ("reduce-scatter" in coll) or ("all-reduce" in coll), \\
            list(coll)
        print("COLL_OK", {k: v for k, v in coll.items() if k != "total_bytes"})
    """)
    r = _run(code)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "COLL_OK" in r.stdout, r.stdout


def test_dp_vs_zdp_collective_bytes():
    """ZDP must move MORE collective bytes than DP (the paper's 1.5x) —
    measured on real compiled HLO, not the cost model."""
    code = COMMON + textwrap.dedent("""
        import dataclasses
        from repro.roofline.analysis import analyze_lowered

        def coll_bytes(force_mode):
            cfg = reduced(get_arch("qwen1.5-0.5b"))
            mesh_cfg = MeshConfig((4, 1), ("data", "model"))
            shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                        global_batch=4)
            run = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                            osdp=OSDPConfig(force_mode=force_mode,
                                            operator_splitting=False,
                                            checkpointing=False))
            plan = make_plan(run)
            mesh = jax.make_mesh((4, 1), ("data", "model"))
            built = build_model(run, plan, mesh)
            with jax.set_mesh(mesh):
                step_fn, init_fn = make_train_step(built, donate=False)
                params, opt = init_fn(jax.random.PRNGKey(0))
                batch = make_batch(cfg, 4, 64)
                batch = {k: jax.device_put(v, NamedSharding(
                    mesh, P(("data",), *([None] * (v.ndim - 1)))))
                    for k, v in batch.items()}
                txt = step_fn.lower(params, opt, batch).compile().as_text()
            return analyze_lowered(txt)["total_bytes"]

        b_dp = coll_bytes("DP")
        b_zdp = coll_bytes("ZDP")
        print("bytes DP", b_dp, "ZDP", b_zdp)
        assert b_zdp > b_dp * 1.2, (b_dp, b_zdp)
        print("RATIO_OK", b_zdp / b_dp)
    """)
    r = _run(code)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "RATIO_OK" in r.stdout, r.stdout
