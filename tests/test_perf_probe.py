"""perf_probe hygiene + measured-bandwidth probe.

The module used to set XLA_FLAGS at import time, which poisoned any
process that merely collected it (pytest, benchmarks.run).  It now
sets the flag inside main(); these tests pin that, and exercise the
measured per-level bandwidth estimate + overlap sanity pairing on a
small fake mesh in a subprocess.
"""
import os
import subprocess
import sys
import textwrap


def _env(**extra):
    e = dict(os.environ)
    e.pop("XLA_FLAGS", None)
    e["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    e.update(extra)
    return e


def _run(code, **extra_env):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=_env(**extra_env),
                       timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_import_leaves_environment_untouched():
    """Importing the probe must not mutate XLA_FLAGS (tier-1 pytest
    collection imports it; the 512-device flag would leak into every
    later jax initialization in the same process)."""
    out = _run("""
        import os
        assert "XLA_FLAGS" not in os.environ
        import repro.launch.perf_probe
        assert "XLA_FLAGS" not in os.environ, os.environ["XLA_FLAGS"]
        print("OK")
    """)
    assert "OK" in out


def test_cli_help_runs_without_env_setup():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.perf_probe", "--help"],
        capture_output=True, text=True, env=_env(), timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "--measure-bw" in r.stdout and "--device" in r.stdout


def test_measure_level_bandwidth_and_overlap_sanity():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        from repro.launch.perf_probe import (measure_level_bandwidth,
                                             overlap_sanity)
        mesh = jax.make_mesh((1, 2, 2), ("pod", "data", "model"))
        m = measure_level_bandwidth(mesh, size_mib=0.25, repeats=2)
        assert set(m) == {"pod", "data", "model"}
        assert m["pod"]["achieved_bytes_per_s"] is None      # span 1
        for ax in ("data", "model"):
            assert m[ax]["ways"] == 2
            assert m[ax]["bytes_moved"] > 0
            assert m[ax]["achieved_bytes_per_s"] > 0
        rows = overlap_sanity(m, "a100-80g", mesh.size)
        assert rows, rows
        # innermost mesh axis pairs with the innermost (fastest) level
        assert rows[0]["axis"] == "model"
        for r in rows:
            assert r["spec_bytes_per_s"] > 0
            assert r["achieved_over_spec"] is not None
        print("OK")
    """)
    assert "OK" in out
