"""Stale-pin detection for the enforced skip/xfail inventory.

conftest.py fails the run when an unpinned skip appears or a pinned
xfail silently passes; this module closes the remaining gap — pins
that point at tests which no longer exist.  A renamed module or test
would otherwise leave a dead entry that quietly sanctions future
regressions under the old name.
"""
import ast
from pathlib import Path

import conftest

TESTS = Path(__file__).resolve().parent


def _test_functions(path: Path) -> set:
    tree = ast.parse(path.read_text())
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
    return names


def test_expected_skip_modules_exist():
    for mod in conftest.EXPECTED_SKIP_MODULES:
        assert (TESTS / mod).is_file(), \
            f"EXPECTED_SKIP_MODULES pins missing module {mod}"


def test_expected_xfails_resolve():
    for nodeid in conftest.EXPECTED_XFAILS:
        mod, _, tail = nodeid.partition("::")
        path = TESTS / mod
        assert path.is_file(), f"EXPECTED_XFAILS pins missing {mod}"
        func = tail.split("::")[-1].split("[")[0]
        assert func in _test_functions(path), \
            f"EXPECTED_XFAILS pins missing test {mod}::{func}"


def test_inventory_entries_are_test_scoped():
    """Pins must name test modules/tests, not arbitrary files."""
    for mod in conftest.EXPECTED_SKIP_MODULES:
        assert mod.startswith("test_") and mod.endswith(".py"), mod
    for nodeid in conftest.EXPECTED_XFAILS:
        mod, _, tail = nodeid.partition("::")
        assert mod.startswith("test_") and tail.startswith("test"), \
            nodeid
