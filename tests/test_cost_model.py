"""Cost model (§3.1) unit + property tests (hypothesis)."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import (DeviceInfo, MULTI_POD_MESH, SINGLE_POD_MESH,
                           OSDPConfig, get_arch, get_shape)
from repro.core.cost_model import (DP, ZDP, ZDP_POD, CostEnv, Decision,
                                   op_cost, plan_cost, uniform_plan,
                                   zdp_extra_time, zdp_saving)
from repro.core.descriptions import OperatorDesc, describe


ENV = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
ENV_POD = CostEnv(DeviceInfo(), MULTI_POD_MESH)

op_strategy = st.builds(
    OperatorDesc,
    name=st.just("op"),
    param_count=st.integers(min_value=1, max_value=10**10),
    flops_per_token=st.floats(min_value=0, max_value=1e12),
    act_bytes_per_token=st.floats(min_value=0, max_value=1e6),
    splittable=st.booleans(),
    decidable=st.just(True),
    layers=st.integers(min_value=1, max_value=128),
)


@given(op=op_strategy, b=st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_zdp_never_increases_memory(op, b):
    c_dp = op_cost(op, Decision("op", (DP,)), b, 1024, ENV)
    c_z = op_cost(op, Decision("op", (ZDP,)), b, 1024, ENV)
    assert c_z.memory <= c_dp.memory + 1e-6


@given(op=op_strategy, b=st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_zdp_comm_is_1_5x_dp_plus_ckpt(op, b):
    """Paper Fig. 1: ZDP comm = 3 rounds vs DP's 2 (x(N-1) steps), +1
    round under checkpointing."""
    env = CostEnv(DeviceInfo(alpha=0.0), SINGLE_POD_MESH,
                  checkpointing=False)
    c_dp = op_cost(op, Decision("op", (DP,)), b, 1024, env)
    c_z = op_cost(op, Decision("op", (ZDP,)), b, 1024, env)
    if c_dp.comm_time > 0:
        assert c_z.comm_time == pytest.approx(1.5 * c_dp.comm_time, rel=1e-6)
    env_ck = CostEnv(DeviceInfo(alpha=0.0), SINGLE_POD_MESH,
                     checkpointing=True)
    c_z_ck = op_cost(op, Decision("op", (ZDP,)), b, 1024, env_ck)
    if c_dp.comm_time > 0:
        assert c_z_ck.comm_time == pytest.approx(2.0 * c_dp.comm_time,
                                                 rel=1e-6)


@given(op=op_strategy)
@settings(max_examples=100, deadline=None)
def test_savings_and_extra_time_nonnegative(op):
    assert zdp_saving(op, ENV) >= 0
    assert zdp_extra_time(op, ENV) >= 0
    assert zdp_saving(op, ENV_POD, ZDP_POD) <= zdp_saving(op, ENV_POD, ZDP)


@given(op=op_strategy, b=st.integers(1, 32), g=st.integers(2, 8))
@settings(max_examples=100, deadline=None)
def test_split_reduces_gather_peak(op, b, g):
    """§3.3: gathered-slice peak (and the additive M_extra) = full/g."""
    c1 = op_cost(op, Decision("op", (ZDP,)), b, 1024, ENV)
    cg = op_cost(op, Decision("op", (ZDP,) * g), b, 1024, ENV)
    assert cg.peak_extra == pytest.approx(c1.peak_extra / g, rel=1e-6)
    assert cg.memory <= c1.memory + 1e-9   # smaller transient, same states
    saved = c1.memory - cg.memory
    want = c1.peak_extra * (1 - 1 / g)
    assert saved == pytest.approx(want, rel=1e-6, abs=1e-6)


@given(b1=st.integers(1, 16), b2=st.integers(17, 64))
@settings(max_examples=50, deadline=None)
def test_memory_monotone_in_batch(b1, b2):
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    env = ENV
    p = uniform_plan(desc, DP)
    m1 = plan_cost(desc, p, b1 * env.n_data, env).memory
    m2 = plan_cost(desc, p, b2 * env.n_data, env).memory
    assert m2 >= m1


def test_moe_flops_use_topk_only():
    moe = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
    w13 = next(o for o in moe.operators if o.name == "layers.moe_w13")
    cfg = get_arch("dbrx-132b")
    # flops per token ~ top_k * 2 * d * 2ff * L  (not E * ...)
    want = cfg.moe_top_k * 2 * cfg.d_model * 2 * cfg.d_ff * cfg.n_layers
    assert w13.flops_per_token == pytest.approx(want)
    # params however count every expert
    assert w13.param_count == (cfg.moe_experts * 2 * cfg.d_model
                               * cfg.d_ff * cfg.n_layers)


def test_zdp_pod_stays_on_fast_link():
    """ZDP_POD gathers on ICI only; flat ZDP crosses the pod (DCI) link
    — so for big operators ZDP_POD must be cheaper per byte."""
    op = OperatorDesc("big", 10**9, 0.0, 0.0, layers=1)
    t_flat = zdp_extra_time(op, ENV_POD, ZDP)
    t_pod = zdp_extra_time(op, ENV_POD, ZDP_POD)
    assert t_pod < t_flat
    # but saves less memory
    assert zdp_saving(op, ENV_POD, ZDP_POD) < zdp_saving(op, ENV_POD, ZDP)
