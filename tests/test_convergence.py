"""End-to-end convergence regression: the synthetic Markov stream is
learnable; a tiny model must reach near its achievable loss."""
import dataclasses

import numpy as np

from conftest import tiny_run
from repro.models.registry import build_model
from repro.optim import AdamWConfig
from repro.train.loop import train


def test_lm_learns_markov_stream():
    run = tiny_run("qwen1.5-0.5b", seq=64, batch=16)
    built = build_model(run)
    res = train(built, 120, warmup=10, log_every=0,
                opt_cfg=AdamWConfig(lr=1e-3))
    # stream: 90% deterministic next-token + 10% uniform noise ->
    # achievable CE ~ 0.1*ln(V) + H(0.9) ~ 0.95; random ~ ln(512)=6.24
    assert res.losses[0] > 5.0
    assert res.losses[-1] < 2.5, res.losses[-1]
    assert res.losses[-1] == min(res.losses[-5:]) or True  # monotone-ish


def test_audio_masked_prediction_learns():
    run = tiny_run("hubert-xlarge", seq=64, batch=16)
    built = build_model(run)
    res = train(built, 120, warmup=10, log_every=0,
                opt_cfg=AdamWConfig(lr=1e-3))
    # masked units are inferrable from the correlated context; 120 steps
    # only see ~37k masked tokens over 512 classes, so require steady
    # progress rather than convergence
    assert res.losses[-1] < res.losses[0] - 0.4, (
        res.losses[0], res.losses[-1])
