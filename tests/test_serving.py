"""Serving tests (ISSUE 5): engine correctness, KV-cache accounting,
and serving-search goldens.

  * decode-vs-prefill logits parity across the three cached families
    (dense GQA, pure-SSM mamba2, hybrid hymba — hymba gets a looser
    tolerance for its known pre-existing decode-numerics drift, whose
    strict-tolerance variant stays the pinned xfail in
    test_arch_smoke.py; see CHANGES.md);
  * scalar-t == vector-t decode (the continuous engine's per-slot
    position vector must be a pure generalization);
  * greedy continuous decoding is deterministic across request
    orderings, and per-request output is bitwise equal to running the
    request alone through the static engine;
  * request-latency accounting sanity;
  * predicted per-sequence cache bytes == measured `jax.eval_shape`
    sizes of the runtime caches across every decoder arch and KV
    dtype (the cost model's first-class KV/SSM memory term);
  * one pinned `search_serve` golden decision row, plus a re-solve of
    the committed BENCH_search.json training cases asserting their
    decisions' (step_time_ms, feasible, nodes) stay byte-identical
    (fig5/fig9 golden rows are pinned by benchmarks/fig5_end_to_end.py
    --quick and tests/test_selective_remat.py respectively).
"""
import json
import sys
from functools import lru_cache
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_run
from repro.configs import ARCHS, get_arch, get_shape, reduced
from repro.core.api import search_serve
from repro.core.descriptions import describe
from repro.models.common import attn_geometry
from repro.models.attention import init_kv_cache
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Engine, Request

ROOT = Path(__file__).resolve().parent.parent

FAMILIES3 = ["qwen1.5-0.5b", "mamba2-2.7b", "hymba-1.5b"]
DECODERS = sorted(a for a in ARCHS if ARCHS[a].is_decoder)


@lru_cache(maxsize=None)
def _served(arch):
    run = tiny_run(arch, shape="decode_32k")
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    return built, params


def _prompts(cfg, n, s, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (n, s)).astype(np.int32)


# ---------------------------------------------------------------------------
# decode correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,atol", [
    ("qwen1.5-0.5b", 0.15),
    ("mamba2-2.7b", 0.15),
    # hymba's attn+ssm mean block drifts beyond the bf16 tolerance of
    # the other families (known pre-existing decode numerics issue —
    # the strict-tolerance variant is the pinned xfail in
    # test_arch_smoke.py); the loose bound still catches structural
    # breakage (wrong cache wiring produces O(1) logit error)
    ("hymba-1.5b", 0.5),
])
def test_decode_matches_prefill(arch, atol):
    """One decode step after an S-token prefill reproduces the
    (S+1)-token prefill's last-position logits."""
    built, params = _served(arch)
    cfg = built.model.cfg
    m = built.model
    B, S = 2, 24
    toks = _prompts(cfg, B, S + 1, seed=1)
    logits_full, _ = jax.jit(m.prefill)(params, {"tokens": jnp.asarray(toks)})
    _, caches = jax.jit(m.prefill)(
        params, {"tokens": jnp.asarray(toks[:, :S])})
    lg, _ = jax.jit(m.decode_step)(params, caches,
                                   jnp.asarray(toks[:, S:S + 1]),
                                   jnp.int32(S))
    a = np.asarray(lg[:, 0, :cfg.vocab_size], np.float32)
    b = np.asarray(logits_full[:, 0, :cfg.vocab_size], np.float32)
    np.testing.assert_allclose(a, b, atol=atol, rtol=0.1)


@pytest.mark.parametrize("arch", FAMILIES3)
def test_scalar_t_equals_vector_t(arch):
    """The per-slot position vector is a pure generalization: with
    every slot at the same position, logits and caches are bitwise
    identical to the scalar-t decode."""
    built, params = _served(arch)
    cfg = built.model.cfg
    m = built.model
    B, S = 3, 16
    toks = _prompts(cfg, B, S)
    _, caches_a = jax.jit(m.prefill)(params, {"tokens": jnp.asarray(toks)})
    _, caches_b = jax.jit(m.prefill)(params, {"tokens": jnp.asarray(toks)})
    step = _prompts(cfg, B, 1, seed=2)
    lg_s, ca = jax.jit(m.decode_step)(params, caches_a,
                                      jnp.asarray(step), jnp.int32(S))
    lg_v, cb = jax.jit(m.decode_step)(params, caches_b, jnp.asarray(step),
                                      jnp.full((B,), S, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    for la, lb in zip(jax.tree_util.tree_leaves(ca),
                      jax.tree_util.tree_leaves(cb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES3)
def test_continuous_matches_static_per_request(arch):
    """Greedy continuous batching emits, per request, exactly the
    tokens the static engine produces for that request alone (matched
    cache_len -> bitwise equality)."""
    built, params = _served(arch)
    cfg = built.model.cfg
    CL = 48
    prompts = _prompts(cfg, 4, 16)
    news = [5, 12, 3, 7]
    eng = Engine(built, params, cache_len=CL)
    refs = {i: eng.generate(prompts[i:i + 1], news[i]).tokens[0]
            for i in range(4)}
    ce = ContinuousEngine(built, params, max_slots=2, cache_len=CL)
    results, stats = ce.run([Request(i, prompts[i], news[i])
                             for i in range(4)])
    assert stats.completed == 4
    for r in results:
        np.testing.assert_array_equal(r.tokens, refs[r.rid])


@pytest.mark.parametrize("arch", FAMILIES3)
def test_greedy_deterministic_across_orderings(arch):
    """Submitting the same requests in a different order changes the
    schedule but not any request's greedy output."""
    built, params = _served(arch)
    cfg = built.model.cfg
    prompts = _prompts(cfg, 4, 12, seed=3)
    news = [6, 2, 9, 4]
    reqs = [Request(i, prompts[i], news[i]) for i in range(4)]
    ce = ContinuousEngine(built, params, max_slots=2, cache_len=32)
    res_a, _ = ce.run(reqs)
    res_b, _ = ce.run([reqs[2], reqs[0], reqs[3], reqs[1]])
    by_rid_a = {r.rid: r.tokens for r in res_a}
    by_rid_b = {r.rid: r.tokens for r in res_b}
    assert by_rid_a.keys() == by_rid_b.keys()
    for rid in by_rid_a:
        np.testing.assert_array_equal(by_rid_a[rid], by_rid_b[rid])


def test_latency_accounting_sanity():
    built, params = _served("qwen1.5-0.5b")
    cfg = built.model.cfg
    n = 5
    prompts = _prompts(cfg, n, 8)
    news = [3, 1, 6, 2, 4]
    ce = ContinuousEngine(built, params, max_slots=2, cache_len=16)
    results, stats = ce.run([Request(i, prompts[i], news[i])
                             for i in range(n)])
    assert stats.completed == n
    assert stats.useful_tokens == sum(news)
    assert stats.prefill_steps == n
    assert 0.0 < stats.slot_utilization <= 1.0
    assert stats.wall_s > 0
    seen = set()
    for r in results:
        seen.add(r.rid)
        assert r.n_generated == news[r.rid]
        assert 0.0 <= r.t_admitted <= r.t_first_token <= r.t_finished
        assert r.queue_wait_s >= 0.0 and r.ttft_s >= 0.0
        assert r.ttft_s <= r.latency_s
        assert 1 <= r.admitted_at_step <= r.finished_at_step
    assert seen == set(range(n))
    # with 2 slots and 5 requests, someone must have waited in queue
    assert max(r.queue_wait_s for r in results) > 0.0


def test_admission_respects_slot_limit():
    """max_slots bounds in-flight work: with 1 slot, requests complete
    strictly one after another (engine-step intervals never overlap)."""
    built, params = _served("qwen1.5-0.5b")
    cfg = built.model.cfg
    prompts = _prompts(cfg, 3, 8)
    ce = ContinuousEngine(built, params, max_slots=1, cache_len=16)
    results, stats = ce.run([Request(i, prompts[i], 3) for i in range(3)])
    spans = sorted((r.admitted_at_step, r.finished_at_step)
                   for r in results)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0
    assert stats.decode_steps == 3 * 2    # 2 decode tokens per request


def test_prompt_longer_than_cache_rejected():
    """An unservable request terminates INVALID instead of raising
    mid-run (which would abandon every other live slot)."""
    built, params = _served("qwen1.5-0.5b")
    cfg = built.model.cfg
    ce = ContinuousEngine(built, params, max_slots=1, cache_len=8)
    good = Request(1, _prompts(cfg, 1, 4)[0], 2)
    results, stats = ce.run([Request(0, _prompts(cfg, 1, 9)[0], 2), good])
    by_rid = {r.rid: r for r in results}
    assert by_rid[0].status == "INVALID"
    assert "exceeds" in by_rid[0].error
    assert by_rid[0].n_generated == 0
    assert by_rid[1].status == "OK"
    assert by_rid[1].n_generated == 2
    assert stats.invalid == 1 and stats.completed == 1


# ---------------------------------------------------------------------------
# KV-cache memory term: predicted == measured
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", DECODERS)
def test_cache_bytes_match_eval_shape(arch):
    """The cost model's per-sequence cache term equals the byte size
    of the runtime caches, exactly, for every decoder arch."""
    run = tiny_run(arch, shape="decode_32k")
    built = build_model(run)
    desc = describe(run.model, run.shape)
    for B, CL in ((1, 16), (3, 48), (2, 200)):
        caches = jax.eval_shape(lambda B=B, CL=CL:
                                built.model.init_caches(B, CL))
        measured = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(caches))
        predicted = desc.cache_bytes_per_seq(CL) * B
        assert measured == predicted, (arch, B, CL, measured, predicted)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "phi4-mini-3.8b",
                                  "dbrx-132b"])
def test_kv_cache_bytes_across_dtypes(arch, dtype):
    """KV-dtype scaling: the cost model's kv_dtype_bytes knob tracks
    the runtime cache dtype exactly (attention-only archs, where the
    whole cache is the KV term)."""
    cfg = reduced(get_arch(arch))
    desc = describe(cfg, get_shape("decode_32k"))
    geom = attn_geometry(cfg, 1)
    B, CL = 2, 32
    cache = jax.eval_shape(lambda: init_kv_cache(cfg, geom, B, CL,
                                                 dtype=dtype))
    measured = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(cache))
    itemsize = jnp.zeros((), dtype).dtype.itemsize
    predicted = desc.cache_bytes_per_seq(CL, kv_dtype_bytes=itemsize) * B
    assert measured == predicted, (arch, dtype, measured, predicted)


def test_sliding_window_caps_cache_bytes():
    """Beyond the window the KV term stops growing (rolling cache)."""
    cfg = reduced(get_arch("hymba-1.5b"))
    assert cfg.sliding_window > 0
    desc = describe(cfg, get_shape("decode_32k"))
    w = cfg.sliding_window
    assert desc.cache_bytes_per_seq(w) == desc.cache_bytes_per_seq(4 * w)
    assert desc.cache_bytes_per_seq(w // 2) < desc.cache_bytes_per_seq(w)


def test_cache_bytes_monotone_in_len():
    cfg = reduced(get_arch("qwen1.5-0.5b"))
    desc = describe(cfg, get_shape("decode_32k"))
    vals = [desc.cache_bytes_per_seq(n) for n in (1, 8, 64, 512)]
    assert vals == sorted(vals) and vals[0] < vals[-1]


# ---------------------------------------------------------------------------
# search_serve goldens + committed-benchmark stability
# ---------------------------------------------------------------------------

def test_search_serve_golden_row():
    """Pinned serving decision: llama3-405b on 256x16GiB — the big
    matmuls shard (ZDP, split 4), the small/undecidable ops replicate,
    and the KV budget admits exactly 21 slots/device."""
    plan = search_serve(get_arch("llama3-405b"), prompt_len=512,
                        decode_len=128, n_devices=256,
                        memory_limit_gib=16.0)
    assert plan.feasible
    assert plan.max_slots_per_device == 21
    assert plan.max_concurrency == 5376
    got = {k: (d.uniform(), d.split) for k, d in plan.decisions.items()}
    assert got == {
        "embed.tok": ("DP", 1), "head.out": ("ZDP", 4),
        "final_norm": ("DP", 1), "layers.attn_qkv": ("ZDP", 4),
        "layers.attn_out": ("ZDP", 4), "layers.attn_scores": ("DP", 1),
        "layers.attn_norm": ("DP", 1), "layers.ffn_w13": ("ZDP", 4),
        "layers.ffn_w2": ("ZDP", 4), "layers.ffn_norm": ("DP", 1),
    }
    # the same model/limit pair is unservable without the plan
    naive = search_serve(get_arch("llama3-405b"), prompt_len=512,
                         decode_len=128, n_devices=1,
                         memory_limit_gib=16.0, force_mode="DP",
                         max_slots=4)
    assert not naive.feasible


def test_search_serve_respects_memory_limit():
    for gib in (2.0, 4.0):
        plan = search_serve(get_arch("qwen1.5-0.5b"), prompt_len=128,
                            decode_len=64, n_devices=1,
                            memory_limit_gib=gib)
        assert plan.feasible
        assert plan.cost.memory <= gib * 2**30
        # one more slot than the admission limit must NOT fit
        over = search_serve(
            get_arch("qwen1.5-0.5b"), prompt_len=128, decode_len=64,
            n_devices=1, memory_limit_gib=gib,
            slot_candidates=[plan.max_slots_per_device + 1])
        assert not over.feasible


def test_bench_training_decisions_unchanged():
    """Re-solve the committed BENCH_search.json quick training cases
    and assert the recorded decisions' fingerprints (deterministic
    step_time_ms / feasibility / solver effort) are byte-identical —
    the serving additions must not move any training answer."""
    doc = json.loads((ROOT / "BENCH_search.json").read_text())
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.search_time import _search_plan_cases
        from repro.configs import OSDPConfig
        from repro.core.search import search_plan
    finally:
        sys.path.pop(0)
    for name, desc, env, lim, batch, ckpt in _search_plan_cases(quick=True):
        recorded = doc["current"].get(name)
        if recorded is None:
            continue
        for solver, want in recorded["solvers"].items():
            osdp = OSDPConfig(search=solver, memory_limit_bytes=lim,
                              operator_splitting=True,
                              default_slice_granularity=4,
                              checkpointing=ckpt)
            res = search_plan(desc, batch, env, osdp)
            assert round(res.cost.time * 1e3, 3) == want["step_time_ms"], \
                (name, solver)
            assert res.feasible == want["feasible"], (name, solver)
            assert res.nodes_visited == want["nodes_visited"], \
                (name, solver)
