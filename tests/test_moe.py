"""MoE layer unit tests: dispatch/combine vs the dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.cost_model import Decision, DP
from repro.models.moe import _capacity, moe_forward, moe_ref, route
from repro.models.registry import build_model
from conftest import tiny_run


def _setup(cap_factor=8.0, top_k=2, experts=4):
    cfg = reduced(get_arch("dbrx-132b"))
    cfg = dataclasses.replace(cfg, moe_capacity_factor=cap_factor,
                              moe_top_k=top_k, moe_experts=experts)
    run = dataclasses.replace(tiny_run("dbrx-132b"), model=cfg)
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    lp = {k: v[0] for k, v in params.items() if k.startswith("layers/")}
    return cfg, built, lp


def test_moe_matches_dense_oracle_no_drops():
    """With generous capacity the sparse dispatch == dense computation."""
    cfg, built, lp = _setup(cap_factor=8.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y, aux = moe_forward(cfg, built.model.pset, lp, x)
    y_ref = moe_ref(cfg, lp["layers/moe/router"], lp["layers/moe/w13"],
                    lp["layers/moe/w2"], x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=5e-2, rtol=5e-2)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With tight capacity outputs are a subset (dropped tokens -> only
    partial expert contributions), never garbage."""
    cfg, built, lp = _setup(cap_factor=0.5)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    y, _ = moe_forward(cfg, built.model.pset, lp, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    # dropped-token rows shrink toward zero; norm must not exceed the
    # no-drop output norms by much
    y_full, _ = moe_forward(
        dataclasses.replace(cfg, moe_capacity_factor=8.0),
        built.model.pset, lp, x)
    assert (np.linalg.norm(np.asarray(y, np.float32))
            <= np.linalg.norm(np.asarray(y_full, np.float32)) * 1.05)


def test_router_normalized_topk():
    cfg, built, lp = _setup()
    xt = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model))
    p, e, aux = route(cfg, lp["layers/moe/router"], xt)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(e) < cfg.moe_experts).all()
    # aux loss is ~1 for a balanced router (E * sum f*p with f~p~1/E)
    assert 0.2 < float(aux) < 5.0


def test_capacity_rounding():
    cfg, _, _ = _setup()
    c = _capacity(cfg, 1024)
    assert c % 8 == 0 and c >= cfg.moe_top_k * 1024 / cfg.moe_experts
