"""The exact ILP backend (core/ilp.py): correctness, the anytime mode,
the SearchResult optimality certificate, and the PR-3 regression pin.

Deterministic companion to tests/test_solver_oracle.py (the hypothesis
differential suite): these run in every environment, including ones
without hypothesis.  Only the milp-backend cases skip when scipy is
absent — that skip is pinned in conftest's EXPECTED_SKIP_MODULES.
"""
import math
import random

import pytest

from repro.configs import (DeviceInfo, SINGLE_POD_MESH, OSDPConfig,
                           SOLVERS, get_arch, get_shape)
from repro.configs.base import SELECTIVE
from repro.core.cost_model import CostEnv
from repro.core.descriptions import describe
from repro.core.ilp import HAVE_SCIPY_MILP, ILP_BACKENDS, solve_ilp
from repro.core.search import (SliceItem, _solve_dfs, _solve_greedy,
                               _solve_knapsack, search_plan)

MODES = ("ZDP", "ZDP+R", "DP+R")
BACKENDS = [
    pytest.param("milp", marks=pytest.mark.skipif(
        not HAVE_SCIPY_MILP, reason="scipy.optimize.milp unavailable")),
    "bnb",
]


def _mk_multi(rng, n, start=0):
    """n items with 1-3 distinct modes and continuous random costs
    (distinct ratios almost surely: unique optimum, no decode ties)."""
    items = []
    for i in range(start, start + n):
        modes = MODES[:rng.randint(1, len(MODES))]
        items.append(SliceItem(
            f"op{i}", 0, 1,
            {m: rng.uniform(1, 100) for m in modes},
            {m: rng.uniform(0.01, 10.0) for m in modes}))
    return items


def _mk_grouped(rng, n_sigs, copies):
    """copies interchangeable items per signature (per-layer stacks) —
    the grouping/decode path the real model descriptions exercise."""
    items = []
    for s in range(n_sigs):
        modes = MODES[:rng.randint(1, len(MODES))]
        sav = {m: rng.uniform(1, 100) for m in modes}
        ext = {m: rng.uniform(0.01, 10.0) for m in modes}
        for c in range(copies):
            items.append(SliceItem(f"op{s}_{c}", 0, 1, dict(sav),
                                   dict(ext)))
    return items


def _cost(items, choice):
    return sum(items[i].extra_time[c]
               for i, c in enumerate(choice) if c)


def _cover(items, choice):
    return sum(items[i].savings[c]
               for i, c in enumerate(choice) if c)


def _brute(items, need):
    """Exact reference by exhaustive enumeration (multi-mode)."""
    import itertools
    best = math.inf
    menus = [[None] + list(it.savings) for it in items]
    for combo in itertools.product(*menus):
        sav = sum(items[i].savings[c]
                  for i, c in enumerate(combo) if c)
        if sav >= need:
            best = min(best, sum(items[i].extra_time[c]
                                 for i, c in enumerate(combo) if c))
    return best


def _capacity(items):
    return sum(max(it.savings.values()) for it in items)


# --- exactness --------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", range(6))
def test_exact_vs_brute_force(seed, backend):
    rng = random.Random(seed)
    items = _mk_multi(rng, 8)
    need = rng.uniform(0.2, 0.9) * _capacity(items)
    res = solve_ilp(items, need, backend=backend)
    assert res.backend == backend
    assert res.optimal and res.gap == 0.0
    assert _cover(items, res.choice) >= need - 1e-9
    t = _cost(items, res.choice)
    assert res.objective == pytest.approx(t, rel=1e-12)
    assert res.lower_bound == pytest.approx(t, rel=1e-12)
    assert t == pytest.approx(_brute(items, need), rel=1e-9)


@pytest.mark.skipif(not HAVE_SCIPY_MILP,
                    reason="scipy.optimize.milp unavailable")
@pytest.mark.parametrize("seed", range(6))
def test_backends_agree_byte_identical(seed):
    """milp and bnb reach the same unique optimum — identical choices,
    not just equal costs (continuous costs: ties have measure zero)."""
    rng = random.Random(50 + seed)
    items = _mk_grouped(rng, 5, 5)
    need = rng.uniform(0.3, 0.8) * _capacity(items)
    a = solve_ilp(items, need, backend="milp")
    b = solve_ilp(items, need, backend="bnb")
    assert a.optimal and b.optimal
    assert a.objective == pytest.approx(b.objective, rel=1e-9)
    assert a.choice == b.choice


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", range(6))
def test_matches_dfs_byte_identical(seed, backend):
    """The decode contract: grouped counts map back to per-item choices
    in the dfs's canonical order, so the decisions match _solve_dfs
    exactly wherever both are exact."""
    rng = random.Random(100 + seed)
    items = _mk_grouped(rng, 4, 6)
    rng.shuffle(items)                    # decode must survive any order
    need = rng.uniform(0.3, 0.8) * _capacity(items)
    res = solve_ilp(items, need, backend=backend)
    choice_dfs, _ = _solve_dfs(items, need)
    assert res.optimal
    assert res.objective == pytest.approx(_cost(items, choice_dfs),
                                          rel=1e-9)
    assert list(res.choice) == list(choice_dfs)


@pytest.mark.parametrize("backend", BACKENDS)
def test_trivial_and_uncoverable(backend):
    rng = random.Random(3)
    items = _mk_multi(rng, 5)
    triv = solve_ilp(items, 0.0, backend=backend)
    assert triv.optimal and triv.objective == 0.0
    assert triv.choice == [None] * 5
    # uncoverable: proven infeasible, max-saving fallback (identical
    # to _solve_knapsack's fallback; repair escalates all solvers to
    # the same all-max plan)
    need = 1.5 * _capacity(items)
    res = solve_ilp(items, need, backend=backend)
    assert res.optimal and math.isinf(res.objective)
    assert math.isinf(res.gap)
    expect = [max(it.savings, key=it.savings.get) for it in items]
    assert list(res.choice) == expect
    kn, _ = _solve_knapsack(items, need)
    assert list(kn) == expect
    _, t_greedy = _solve_greedy(items, need)
    assert math.isinf(t_greedy)


def test_bad_backend_rejected():
    items = _mk_multi(random.Random(0), 3)
    with pytest.raises(ValueError, match="backend"):
        solve_ilp(items, 10.0, backend="simplex")
    if not HAVE_SCIPY_MILP:
        with pytest.raises(ImportError, match="scipy"):
            solve_ilp(items, 10.0, backend="milp")


# --- anytime mode -----------------------------------------------------------

def _hard_instance():
    """An instance where the ratio-greedy incumbent is strictly
    suboptimal and the tree is deep enough that a tiny budget cannot
    close the gap (verified: unbudgeted bnb beats greedy on it)."""
    rng = random.Random(11)
    items = _mk_multi(rng, 40)
    need = 0.62 * _capacity(items)
    return items, need


def test_anytime_node_budget_returns_incumbent_and_bound():
    items, need = _hard_instance()
    exact = solve_ilp(items, need, backend="bnb")
    assert exact.optimal
    trunc = solve_ilp(items, need, backend="bnb", node_budget=3)
    assert not trunc.optimal
    assert _cover(items, trunc.choice) >= need - 1e-9
    # the incumbent is feasible but worse; the bound is admissible
    assert trunc.objective >= exact.objective - 1e-9
    assert trunc.lower_bound <= exact.objective + 1e-9
    assert trunc.lower_bound <= trunc.objective + 1e-9
    assert trunc.gap >= 0.0
    # the gap genuinely separates: greedy incumbent != optimum here
    assert trunc.objective > exact.objective * (1 + 1e-9)


def test_anytime_time_budget_returns_incumbent_and_bound():
    items, need = _hard_instance()
    trunc = solve_ilp(items, need, backend="bnb", time_budget=1e-9)
    assert not trunc.optimal
    assert _cover(items, trunc.choice) >= need - 1e-9
    assert trunc.lower_bound <= trunc.objective + 1e-9


@pytest.mark.skipif(not HAVE_SCIPY_MILP,
                    reason="scipy.optimize.milp unavailable")
def test_milp_with_time_budget_stays_feasible():
    """A generous time budget must not degrade the milp path (HiGHS
    closes these instances in milliseconds)."""
    items, need = _hard_instance()
    res = solve_ilp(items, need, backend="milp", time_budget=30.0)
    assert _cover(items, res.choice) >= need - 1e-9
    assert res.lower_bound <= res.objective + 1e-9
    ref = solve_ilp(items, need, backend="bnb")
    assert res.objective <= ref.objective * (1 + 1e-9)


# --- nodes_visited semantics (unified across solvers) -----------------------

def test_nodes_visited_monotone_in_budget():
    """One effort scalar per solver, in the backend's natural unit
    (see the SearchResult comment).  The guaranteed monotone axis is
    the solver's *budget* on one fixed instance — a truncated run is a
    prefix of the full one — not instance size (better pruning on a
    bigger instance can legitimately expand fewer nodes)."""
    items, need = _hard_instance()
    dfs_nodes = [_solve_dfs(items, need, node_budget=b)[1]
                 for b in (10, 1000, 2_000_000)]
    assert dfs_nodes == sorted(dfs_nodes)
    assert dfs_nodes[0] <= 10 + 1 and dfs_nodes[-1] > 0
    ilp_nodes = [solve_ilp(items, need, backend="bnb",
                           node_budget=b).nodes
                 for b in (3, 2_000_000)]
    assert ilp_nodes == sorted(ilp_nodes) and ilp_nodes[0] >= 1
    # knapsack cells grow with the need (the DP cap is ceil(need/Q);
    # unit-scale synthetic savings need an explicit sub-unit quantum)
    q = _capacity(items) / 4096
    _, c_lo = _solve_knapsack(items, 0.3 * _capacity(items), quantum=q)
    _, c_hi = _solve_knapsack(items, 0.6 * _capacity(items), quantum=q)
    assert 0 < c_lo <= c_hi


def test_nodes_visited_short_circuit_zeros():
    """0 is a legitimate effort value: dfs's root capacity prune and
    knapsack's quantized-uncoverable check both bail before exploring.
    The ilp still reports its model size (>= 1) on the same instance."""
    items = _mk_multi(random.Random(7), 8)
    need = 1.5 * _capacity(items)
    assert _solve_dfs(items, need)[1] == 0
    assert _solve_knapsack(items, need)[1] == 0
    assert solve_ilp(items, need, backend="bnb").nodes >= 1
    assert solve_ilp(items, 0.0).nodes >= 1


# --- the SearchResult certificate through search_plan -----------------------

QWEN_LIM = int(2.3 * 2**30)               # inside the [2.22, 2.60] window


def _qwen_search(solver, lim=QWEN_LIM):
    desc = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH, checkpointing=False)
    return search_plan(desc, 256, env, OSDPConfig(
        search=solver, memory_limit_bytes=lim,
        operator_splitting=True, default_slice_granularity=4,
        checkpointing=False))


def test_search_plan_ilp_matches_dfs_byte_identical():
    """solver="ilp" through the full engine reproduces the dfs plan
    exactly on a real model where the dfs is exact (its node budget
    does not truncate) — the acceptance bar."""
    r_ilp = _qwen_search("ilp")
    r_dfs = _qwen_search("dfs")
    assert r_ilp.feasible and r_dfs.feasible
    assert r_ilp.decisions == r_dfs.decisions
    assert r_ilp.cost.time == r_dfs.cost.time
    # the certificate only the ilp carries
    assert r_ilp.proven_optimal is True
    assert r_ilp.solver_backend in ("milp", "bnb")
    assert r_ilp.lower_bound is not None
    assert r_ilp.lower_bound >= 0.0 and math.isfinite(r_ilp.lower_bound)
    for r in (r_dfs,):
        assert r.proven_optimal is None
        assert r.lower_bound is None
        assert r.solver_backend == ""


def test_search_plan_nodes_visited_populated_per_solver():
    """At 2.45 GiB every backend does real cover work (at 2.3 GiB the
    knapsack's round-down quantization legitimately short-circuits to
    its fallback with 0 cells — see the SearchResult comment)."""
    for solver in SOLVERS:
        res = _qwen_search(solver, lim=int(2.45 * 2**30))
        assert res.feasible, solver
        assert res.nodes_visited >= 1, solver


def test_osdp_api_exposes_certificate():
    from repro.core import osdp
    plan = osdp(get_arch("qwen1.5-0.5b"), get_shape("train_4k"),
                SINGLE_POD_MESH, memory_limit_gib=2.3, search="ilp",
                checkpointing=False)
    assert plan.search is not None and plan.search.feasible
    assert plan.search.proven_optimal is True
    assert plan.search.solver_backend in ("milp", "bnb")


# --- the PR-3 regression pin: greedy (and truncated dfs) lose dominance -----

def test_selective_remat_ilp_dominates_truncated_dfs_and_greedy():
    """The case the audit was built for (PR 3 selective checkpointing):
    on the 4-mode phi4 per-layer problem at 16 GiB the dfs runs with a
    10k-node cap (the unbudgeted search does not terminate in minutes
    on a problem the ILP closes in milliseconds), so its plan carries a
    real gap — measured 2.27% — and greedy's heuristic gap is 8.79%.
    Pin both: the ILP must strictly dominate, and the measured gaps
    must stay in their bands (a collapse to 0 means the budget cap
    silently moved; a blow-up means a solver regressed)."""
    from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"),
                    per_layer=True)
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False)
    res = {}
    for solver in SOLVERS:
        res[solver] = search_plan(desc, 8, env, OSDPConfig(
            search=solver, memory_limit_bytes=16 * 2**30,
            operator_splitting=True, default_slice_granularity=4,
            checkpointing=SELECTIVE))
        assert res[solver].feasible, solver
    t_ilp = res["ilp"].cost.time
    assert res["ilp"].proven_optimal is True
    gap = {s: res[s].cost.time / t_ilp - 1.0 for s in SOLVERS}
    # ILP strictly dominates the truncated dfs and the greedy heuristic
    assert 0.01 < gap["dfs"] < 0.05, gap
    assert 0.05 < gap["greedy"] < 0.12, gap
    assert -2e-3 <= gap["knapsack"] < 0.03, gap
    assert gap["greedy"] > gap["dfs"]


# --- config surface ---------------------------------------------------------

def test_solver_alias_and_validation():
    assert OSDPConfig(solver="ilp").search == "ilp"
    assert OSDPConfig(solver="greedy").search == "greedy"
    # alias agrees with an explicit search=
    assert OSDPConfig(solver="ilp", search="ilp").search == "ilp"
    with pytest.raises(ValueError, match="solver"):
        OSDPConfig(solver="ilp", search="greedy")
    with pytest.raises(ValueError, match="search"):
        OSDPConfig(search="simplex")
    with pytest.raises(ValueError, match="ilp_backend"):
        OSDPConfig(ilp_backend="cplex")
    with pytest.raises(ValueError, match="ilp_time_budget_s"):
        OSDPConfig(ilp_time_budget_s=-1.0)
    assert set(ILP_BACKENDS) == {"auto", "milp", "bnb"}
    assert SOLVERS == ("dfs", "knapsack", "greedy", "ilp")
