"""Plan -> PartitionSpec compilation + operator-splitting semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_run
from repro.configs import OSDPConfig, get_arch, get_shape, reduced
from repro.core.cost_model import DP, ZDP, Decision
from repro.core.operator_split import chunked_ffn, chunked_matmul
from repro.models.registry import build_model
from repro.sharding.specs import (WeightSpec, _merge_modes, build_param_set,
                                  layout_for, seg_matmul)


# --- segment layout ----------------------------------------------------------

def test_merge_modes_uniform_collapses():
    # merged runs also carry the contributing plan-slice indices
    assert _merge_modes([ZDP] * 4, 1024) == [(ZDP, 0, 1024, (0, 1, 2, 3))]
    assert _merge_modes([DP] * 8, 512) == [(DP, 0, 512,
                                            tuple(range(8)))]


def test_merge_modes_mixed():
    segs = _merge_modes([ZDP, ZDP, DP, DP], 1024)
    assert segs == [(ZDP, 0, 512, (0, 1)), (DP, 512, 512, (2, 3))]
    # boundaries snap to 128 where possible (MXU alignment)
    segs = _merge_modes([ZDP, DP, DP], 1152)
    assert all(s % 128 == 0 for _, s, _, _ in segs)


def test_layout_single_segment_when_no_zdp_axis():
    spec = WeightSpec("w", (64,), "op", zdp_axis=None)
    lay = layout_for(spec, Decision("op", (ZDP, ZDP)))
    assert len(lay.segments) == 1 and lay.segments[0].mode == DP


# --- seg_matmul semantics -----------------------------------------------------

def _pset_for(shape, zdp_axis, decision, stacked=False, tp_axis=None):
    spec = WeightSpec("w", shape, "op", tp_axis=tp_axis, zdp_axis=zdp_axis,
                      stacked=stacked)
    return build_param_set([spec], {"op": decision}, None,
                           jax.random.PRNGKey(0))


def test_seg_matmul_sum_variant_matches_plain():
    """Input-dim split (Figure 4): sum of slice products == full matmul."""
    pset = _pset_for((256, 64), 0, Decision("op", (ZDP, DP, ZDP, DP)))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    w_full = jnp.concatenate([pset.params[k] for k, _ in pset.segments("w")],
                             axis=0)
    y = seg_matmul(x, pset.params, pset, "w", 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w_full),
                               atol=1e-4, rtol=1e-4)


def test_seg_matmul_concat_variant_matches_plain():
    """Output-dim split: concat of slice outputs == full matmul."""
    pset = _pset_for((64, 256), 1, Decision("op", (DP, ZDP)))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    w_full = jnp.concatenate([pset.params[k] for k, _ in pset.segments("w")],
                             axis=1)
    y = seg_matmul(x, pset.params, pset, "w", 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w_full),
                               atol=1e-4, rtol=1e-4)


# --- chunked (uniform-mode) splitting ------------------------------------------

@pytest.mark.parametrize("g", [1, 2, 4, 8])
def test_chunked_matmul_equivalence(g):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 17, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    y = chunked_matmul(x, w, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("act", ["swiglu", "gelu"])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_chunked_ffn_equivalence(act, g):
    two = 2 if act == "swiglu" else 1
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
    w13 = jax.random.normal(jax.random.PRNGKey(1), (64, two * 128)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(2), (128, 64)) * 0.1
    y = chunked_ffn(x, w13, w2, g, act)
    y1 = chunked_ffn(x, w13, w2, 1, act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1), atol=1e-4,
                               rtol=1e-3)


# --- plans change params layout, not math --------------------------------------

@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "hymba-1.5b"])
def test_forward_invariant_under_plan(arch):
    """The same seed + different OSDP plans must give identical loss on
    one device (plans change sharding/layout, never semantics)."""
    run_dp = tiny_run(arch, osdp=OSDPConfig(enabled=True, force_mode="DP",
                                            operator_splitting=False))
    run_zs = tiny_run(arch, osdp=OSDPConfig(enabled=True, force_mode="ZDP",
                                            default_slice_granularity=4))
    from repro.core.plan import make_plan
    losses = []
    for run in (run_dp, run_zs):
        plan = make_plan(run)
        built = build_model(run, plan)
        params = built.init(jax.random.PRNGKey(0))
        batch = make_batch(run.model, 2, 64)
        loss, _ = jax.jit(built.model.loss_fn)(params, batch)
        losses.append(float(loss))
    # segment init differs per-leaf RNG; compare magnitudes only loosely
    assert abs(losses[0] - losses[1]) < 0.5, losses


def test_zdp_plan_shards_over_data_axis():
    """On a fake 4-device mesh the ZDP weights' shardings use `data`."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        from repro.configs import (OSDPConfig, RunConfig, MeshConfig,
                                   get_arch, get_shape, reduced)
        from repro.core.plan import make_plan
        from repro.models.registry import build_model
        import dataclasses
        cfg = reduced(get_arch("phi4-mini-3.8b"))
        mesh_cfg = MeshConfig((2, 2), ("data", "model"))
        shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                    global_batch=4)
        run = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                        osdp=OSDPConfig(force_mode="ZDP",
                                        operator_splitting=False))
        plan = make_plan(run)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        built = build_model(run, plan, mesh)
        sh = built.shardings["layers/ffn/w13"]
        assert "data" in str(sh.spec), sh.spec
        assert "model" in str(sh.spec), sh.spec
        sh_dp = built.shardings["layers/ffn/norm_scale"]
        assert "data" not in str(sh_dp.spec), sh_dp.spec
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env())
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def _env():
    import os
    e = dict(os.environ)
    e["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return e
