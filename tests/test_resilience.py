"""Resilience layer tests (ISSUE 7).

  * crash-safe checkpointing: atomic tmp-dir + rename survives an
    injected mid-write crash (previous checkpoint stays the newest
    visible one), bf16 leaves round-trip, CRC/truncation/missing-file
    corruption is rejected with `CheckpointCorruptError`, retention
    prunes to `keep_last`, and `--resume` skips completed steps;
  * `ClusterSpec.degrade` properties: devices and total HBM strictly
    shrink, every mode's `shard_ways` is non-increasing, the memory
    limit never loosens while the binding (min-HBM) group survives,
    and the degraded spec still satisfies the post-init invariants;
  * deterministic fault schedules: pure functions of (seed, ids) —
    same schedule, same outcome, including full engine-run replay;
  * engine hardening: INVALID / REJECTED / TIMED_OUT / FAILED terminal
    states, bounded retry with backoff, admission under memory
    pressure, and the no-fault path's byte-identity to an empty
    schedule;
  * supervisors: serving survives a device-group loss with zero lost
    acknowledged requests; training replans on the heterogeneous
    fleet preset and resumes from the newest valid checkpoint.
"""
import os
from functools import lru_cache
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import tiny_run
from repro.checkpoint import io as ckpt_io
from repro.checkpoint.io import (CheckpointCorruptError,
                                 CheckpointCrashError)
from repro.cluster.topology import (ClusterSpec, gpu_cluster,
                                    mixed_memory_fleet, tpu_multipod)
from repro.models.registry import build_model
from repro.resilience import (CheckpointCrash, DeviceGroupLoss, DeviceLost,
                              EMPTY_SCHEDULE, FaultSchedule, MemoryPressure,
                              SlowRequest, TransientFailures)
from repro.resilience.supervisor import (ServeSupervisor, TrainSupervisor,
                                         merge_stats)
from repro.serving.engine import ContinuousEngine, Request
from repro.train.loop import restore_or_init, train


@lru_cache(maxsize=None)
def _served():
    run = tiny_run("qwen1.5-0.5b", shape="decode_32k")
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    return built, params


@lru_cache(maxsize=None)
def _trainable():
    run = tiny_run("qwen1.5-0.5b", seq=32, batch=2)
    built = build_model(run)
    return built


def _reqs(n, n_new=3, prompt_len=5, **kw):
    built, _ = _served()
    rng = np.random.default_rng(0)
    v = built.model.cfg.vocab_size
    return [Request(i, rng.integers(0, v, prompt_len).astype(np.int32),
                    n_new, **kw) for i in range(n)]


def _engine(slots=2, cache_len=16, **kw):
    built, params = _served()
    return ContinuousEngine(built, params, max_slots=slots,
                            cache_len=cache_len, **kw)


# ---------------------------------------------------------------------------
# crash-safe checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    import ml_dtypes
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(4, 3)).astype(np.float32),
        "b": rng.normal(size=(3,)).astype(ml_dtypes.bfloat16),
        "opt": [rng.normal(size=(2,)).astype(np.float32),
                np.int32(7)],
    }


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = _tree()
    ckpt_io.save(str(tmp_path), 3, tree)
    restored, step = ckpt_io.restore(str(tmp_path), tree)
    assert step == 3
    assert str(np.asarray(restored["b"]).dtype) == "bfloat16"
    for a, b in [(tree["w"], restored["w"]), (tree["b"], restored["b"]),
                 (tree["opt"][0], restored["opt"][0])]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_injected_crash_preserves_previous_checkpoint(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt_io.save(d, 1, tree)
    with pytest.raises(CheckpointCrashError) as ei:
        ckpt_io.save(d, 2, _tree(seed=1), crash_after_leaves=1)
    assert ei.value.step == 2
    # the crashed step is invisible; the previous one is intact
    assert ckpt_io.latest_step(d) == 1
    assert ckpt_io.verify(d) > 0
    assert os.path.isdir(tmp_path / "step_00000002.tmp")
    # the retry overwrites the debris and completes
    ckpt_io.save(d, 2, _tree(seed=1))
    assert ckpt_io.latest_step(d) == 2
    assert not os.path.isdir(tmp_path / "step_00000002.tmp")


@pytest.mark.parametrize("mode", ["flip", "truncate", "missing"])
def test_corruption_detected(tmp_path, mode):
    d = str(tmp_path)
    tree = _tree()
    ckpt_io.save(d, 1, tree)
    step_dir = tmp_path / "step_00000001"
    victim = step_dir / "w.npy"
    if mode == "flip":
        raw = bytearray(victim.read_bytes())
        raw[-1] ^= 0xFF
        victim.write_bytes(bytes(raw))
        match = "CRC32"
    elif mode == "truncate":
        victim.write_bytes(victim.read_bytes()[:40])
        match = "truncated|unreadable"
    else:
        victim.unlink()
        match = "missing"
    with pytest.raises(CheckpointCorruptError, match=match):
        ckpt_io.restore(d, tree)
    with pytest.raises(CheckpointCorruptError, match=match):
        ckpt_io.verify(d)


def test_retention_keep_last(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        ckpt_io.save(d, s, _tree(), keep_last=2)
    assert ckpt_io.completed_steps(d) == [4, 5]


def test_train_resume_skips_completed_steps(tmp_path):
    built = _trainable()
    d = str(tmp_path)
    quiet = lambda *a: None
    r1 = train(built, 4, ckpt_dir=d, ckpt_every=2, log_every=0,
               print_fn=quiet)
    assert r1.steps == 4 and ckpt_io.latest_step(d) == 4
    # resume semantics: n_steps is the TOTAL target
    r2 = train(built, 6, ckpt_dir=d, resume=True, log_every=0,
               print_fn=quiet)
    assert r2.start_step == 4 and r2.steps == 2
    # already done: trains nothing
    r3 = train(built, 6, ckpt_dir=d, resume=True, log_every=0,
               print_fn=quiet)
    assert r3.steps == 0 and r3.start_step == 6
    _, _, _, start = restore_or_init(built, d, print_fn=quiet)
    assert start == 6


# ---------------------------------------------------------------------------
# ClusterSpec.degrade
# ---------------------------------------------------------------------------

def _all_shard_ways(spec: ClusterSpec):
    return {m: spec.shard_ways(m) for m in spec.mode_names}


@pytest.mark.parametrize("spec,kw", [
    (tpu_multipod(4, 16), dict(level="pod", ways=1)),
    (tpu_multipod(4, 16), dict(level="pod", ways=3)),
    (gpu_cluster(8, 8), dict(ways=2)),          # default outermost
    (gpu_cluster(8, 8, spine_nodes=4), dict(level="spine", ways=1)),
    (mixed_memory_fleet(8, 16.0, 8, 80.0, pod_size=8),
     dict(group="large")),
    (mixed_memory_fleet(8, 16.0, 8, 80.0, pod_size=8),
     dict(level="pod", ways=1)),
])
def test_degrade_only_shrinks(spec, kw):
    deg = spec.degrade(**kw)
    assert deg.n_devices < spec.n_devices
    assert deg.total_hbm < spec.total_hbm
    # every surviving mode's shard capacity is non-increasing
    before = _all_shard_ways(spec)
    for mode, ways in _all_shard_ways(deg).items():
        if mode in before:
            assert ways <= before[mode] + 1e-9, (mode, ways, before)
    # the spec invariants survived (post-init re-ran on construction)
    if deg.groups:
        assert sum(g.n_devices for g in deg.groups) == deg.n_devices
    # memory limit never loosens while the binding group survives
    limit = 16.0 * 2**30
    binding = min((g.hbm_bytes for g in spec.groups), default=None)
    survives = binding is not None and any(
        g.hbm_bytes == binding for g in deg.groups)
    if not spec.groups or survives:
        assert deg.memory_limit(limit) <= spec.memory_limit(limit)


def test_degrade_rejects_bad_requests():
    spec = mixed_memory_fleet(8, 16.0, 8, 80.0, pod_size=8)
    with pytest.raises(ValueError, match="not both"):
        spec.degrade(group="small", level="pod")
    with pytest.raises(ValueError, match="no group"):
        spec.degrade(group="huge")
    with pytest.raises(ValueError, match="no level"):
        spec.degrade(level="rack")
    with pytest.raises(ValueError, match="survivor"):
        spec.degrade(level="pod", ways=2)       # 2 pods, need >= 1 left
    single = ClusterSpec(levels=(
        spec.levels[0].__class__("data", 1, 1e9, 1e-6),))
    with pytest.raises(ValueError, match="single-device"):
        single.degrade()


def test_degrade_group_collapses_outer_level():
    spec = mixed_memory_fleet(8, 16.0, 8, 80.0, pod_size=8)
    deg = spec.degrade(group="large")
    assert deg.n_devices == 8
    assert [g.name for g in deg.groups] == ["small"]
    # the min-HBM group survived: the limit is unchanged (not loosened)
    assert deg.memory_limit(0.0) == spec.memory_limit(0.0)
    # full-ZDP capacity-weighted divisor collapsed to the plain count
    assert deg.shard_ways("ZDP") == pytest.approx(8.0)
    assert spec.shard_ways("ZDP") == pytest.approx(
        spec.total_hbm / spec.min_hbm)


# ---------------------------------------------------------------------------
# fault-schedule determinism
# ---------------------------------------------------------------------------

def test_fault_schedule_pure_and_seeded():
    a = FaultSchedule(seed=11, transient=TransientFailures(0.4))
    b = FaultSchedule(seed=11, transient=TransientFailures(0.4))
    c = FaultSchedule(seed=12, transient=TransientFailures(0.4))
    rows = [(r, k) for r in range(32) for k in (1, 2, 3)]
    assert [a.attempt_fails(*x) for x in rows] == \
           [b.attempt_fails(*x) for x in rows]
    assert [a.attempt_fails(*x) for x in rows] != \
           [c.attempt_fails(*x) for x in rows]
    frac = np.mean([a.attempt_fails(r, 1) for r in range(500)])
    assert 0.25 < frac < 0.55
    assert not any(FaultSchedule(transient=TransientFailures(0.0))
                   .attempt_fails(r, 1) for r in range(50))
    assert all(FaultSchedule(transient=TransientFailures(1.0))
               .attempt_fails(r, 1) for r in range(50))
    for r in range(100):
        n = a.fail_after_tokens(r, 1, 8)
        assert n is None or 1 <= n <= 8


def test_fault_schedule_events():
    ev1 = DeviceGroupLoss(at_step=5, group="large")
    ev2 = DeviceGroupLoss(at_step=9)
    sched = FaultSchedule(device_losses=(ev2, ev1),
                          ckpt_crashes=(CheckpointCrash(4, 2),),
                          pressure=(MemoryPressure(3, 7, 0.5),))
    assert sched.device_loss_at(4) is None
    assert sched.device_loss_at(5) == ev1
    assert sched.device_loss_at(100) == ev1        # earliest due first
    after = sched.without(ev1)
    assert after.device_loss_at(100) == ev2
    assert after.without(ev2).device_loss_at(100) is None
    assert sched.checkpoint_crash_at(4).after_leaves == 2
    assert sched.checkpoint_crash_at(5) is None
    assert sched.slot_factor(2) == 1.0
    assert sched.slot_factor(3) == 0.5
    assert sched.slot_factor(7) == 1.0
    assert EMPTY_SCHEDULE.empty and not sched.empty


# ---------------------------------------------------------------------------
# engine hardening
# ---------------------------------------------------------------------------

def test_invalid_requests_do_not_poison_the_run():
    built, _ = _served()
    v = built.model.cfg.vocab_size
    rng = np.random.default_rng(0)
    good = Request(0, rng.integers(0, v, 5).astype(np.int32), 3)
    bad = [
        Request(1, np.zeros(0, np.int32), 3),                 # empty
        Request(2, np.zeros((2, 3), np.int32), 3),            # not 1-D
        Request(3, rng.integers(0, v, 99).astype(np.int32), 3),  # long
        Request(4, rng.integers(0, v, 5).astype(np.int32), 0),   # no new
    ]
    results, stats = _engine().run([good] + bad, seed=0)
    by = {r.rid: r for r in results}
    assert by[0].status == "OK" and by[0].n_generated == 3
    for r in bad:
        assert by[r.rid].status == "INVALID"
        assert by[r.rid].error
    assert stats.invalid == 4 and stats.completed == 1
    assert stats.terminal == 5


def test_backpressure_rejects_beyond_queue_depth():
    reqs = _reqs(8)
    results, stats = _engine(slots=2, max_queue=2).run(reqs, seed=0)
    assert stats.rejected == 4 and stats.completed == 4
    statuses = [r.status for r in sorted(results, key=lambda r: r.rid)]
    # FIFO: the first max_slots + max_queue are admitted
    assert statuses == ["OK"] * 4 + ["REJECTED"] * 4
    # unbounded queue accepts everything
    _, s2 = _engine(slots=2).run(reqs, seed=0)
    assert s2.rejected == 0 and s2.completed == 8


def test_deadlines_time_out():
    reqs = _reqs(4, n_new=4, deadline_steps=6)
    results, stats = _engine(slots=1).run(reqs, seed=0)
    by = {r.rid: r for r in results}
    assert by[0].status == "OK"
    assert stats.timed_out >= 2
    assert stats.completed + stats.timed_out == 4
    queue_expired = [r for r in results
                     if r.status == "TIMED_OUT" and "queue" in r.error]
    assert queue_expired and all(r.n_generated == 0
                                 for r in queue_expired)


def test_transient_failures_retry_then_fail():
    reqs = _reqs(6)
    always = FaultSchedule(seed=1, transient=TransientFailures(1.0))
    # no retry budget: every request fails on its first attempt
    _, s0 = _engine(max_retries=0).run(reqs, seed=0, faults=always)
    assert s0.failed == 6 and s0.completed == 0 and s0.retries == 0
    # p = 1 fails every attempt: the budget is spent, attempts recorded
    results, s2 = _engine(max_retries=2).run(reqs, seed=0, faults=always)
    assert s2.failed == 6 and s2.retries == 12
    assert all(r.attempts == 3 for r in results)
    assert s2.useful_tokens == 0 and s2.wasted_tokens > 0
    # moderate p with retries recovers completions
    some = FaultSchedule(seed=7, transient=TransientFailures(0.35))
    _, sa = _engine(max_retries=2).run(reqs, seed=0, faults=some)
    _, sb = _engine(max_retries=0).run(reqs, seed=0, faults=some)
    assert sa.completed >= sb.completed
    assert sa.useful_tokens >= sb.useful_tokens


def test_memory_pressure_sheds_admission_not_requests():
    reqs = _reqs(6)
    squeezed = FaultSchedule(pressure=(MemoryPressure(0, 10_000, 0.5),))
    results, stats = _engine(slots=2).run(reqs, seed=0, faults=squeezed)
    assert stats.completed == 6            # degraded, not dropped
    assert all(r.status == "OK" for r in results)


def test_stall_burns_steps_without_tokens():
    reqs = _reqs(2, n_new=3)
    stalled = FaultSchedule(slow=(SlowRequest(0, 4),))
    results, stats = _engine(slots=2).run(reqs, seed=0, faults=stalled)
    by = {r.rid: r for r in results}
    assert by[0].status == "OK" and by[0].n_generated == 3
    assert by[0].finished_at_step > by[1].finished_at_step
    base_results, base = _engine(slots=2).run(reqs, seed=0)
    assert stats.decode_steps == base.decode_steps + 4


def test_empty_schedule_is_byte_identical():
    reqs = _reqs(5, n_new=4)
    r0, s0 = _engine(slots=2).run(reqs, seed=3)
    r1, s1 = _engine(slots=2).run(reqs, seed=3, faults=FaultSchedule())
    r2, s2 = _engine(slots=2).run(reqs, seed=3, faults=EMPTY_SCHEDULE)
    rows = lambda rs: [(r.rid, r.status, r.admitted_at_step,
                        r.finished_at_step, r.tokens.tolist())
                       for r in rs]
    assert rows(r0) == rows(r1) == rows(r2)
    assert (s0.decode_steps, s0.prefill_steps, s0.useful_tokens) == \
           (s1.decode_steps, s1.prefill_steps, s1.useful_tokens) == \
           (s2.decode_steps, s2.prefill_steps, s2.useful_tokens)


def test_device_loss_raises_with_pending_and_replay():
    reqs = _reqs(6, n_new=4)
    faults = FaultSchedule(device_losses=(DeviceGroupLoss(at_step=7),))
    with pytest.raises(DeviceLost) as ei:
        _engine(slots=2).run(reqs, seed=0, faults=faults)
    e = ei.value
    # the loss is detected at the first loop-top check due at >= at_step
    # (the engine clock advances multiple times inside one iteration)
    assert e.step >= 7
    acked = {r.rid for r in e.results}
    pending = {r.rid for r in e.pending}
    assert acked | pending == set(range(6)) and not acked & pending
    assert e.stats is not None and e.stats.completed == len(e.results)
    # deterministic replay: the same schedule fails identically
    with pytest.raises(DeviceLost) as ei2:
        _engine(slots=2).run(reqs, seed=0, faults=faults)
    assert {r.rid for r in ei2.value.pending} == pending
    assert [r.tokens.tolist() for r in ei2.value.results] == \
           [r.tokens.tolist() for r in e.results]


# ---------------------------------------------------------------------------
# supervisors
# ---------------------------------------------------------------------------

def test_serve_supervisor_zero_lost_acknowledged():
    from repro.core.api import rescore_serve, search_serve
    built, params = _served()
    cfg = built.model.cfg
    reqs = _reqs(6, n_new=4)
    cluster = gpu_cluster(4, 8)

    plan_fn = lambda cl: search_serve(
        cfg, prompt_len=5, decode_len=4, cluster=cl,
        memory_limit_gib=16.0, max_slots=4)
    factory = lambda plan, cl: ContinuousEngine(
        built, params, max_slots=2, cache_len=16)
    rescore = lambda plan, cl: rescore_serve(
        cfg, plan, cluster=cl, memory_limit_gib=16.0)

    sup = ServeSupervisor(plan_fn, factory, cluster, rescore_fn=rescore,
                          print_fn=lambda *a: None)
    faults = FaultSchedule(
        device_losses=(DeviceGroupLoss(at_step=7, level="rack"),))
    run = sup.run(reqs, seed=0, faults=faults)
    assert sorted(r.rid for r in run.results) == list(range(6))
    assert all(r.status == "OK" for r in run.results)
    assert run.stats.completed == 6
    [rec] = run.recoveries
    assert rec.kind == "device_loss" and rec.n_devices_after == 24
    assert rec.stale_feasible is not None
    assert 1 <= rec.requeued <= len(reqs)
    # a second identical run recovers identically
    run2 = sup.run(reqs, seed=0, faults=faults)
    assert sorted(r.rid for r in run2.results) == list(range(6))


def test_train_supervisor_replans_on_heterogeneous_fleet(tmp_path):
    built = _trainable()
    cluster = mixed_memory_fleet(8, 16.0, 8, 80.0, pod_size=8)
    quiet = lambda *a: None

    def train_fn(faults):
        return train(built, 6, ckpt_dir=str(tmp_path), ckpt_every=2,
                     keep_last=2, resume=True, log_every=0,
                     faults=faults, print_fn=quiet)

    seen = []

    def plan_fn(cl):
        seen.append(cl)
        from repro.core.api import osdp
        return osdp(built.run.model, built.run.shape, cluster=cl,
                    memory_limit_gib=16.0)

    sup = TrainSupervisor(train_fn, plan_fn, cluster,
                          ckpt_dir=str(tmp_path),
                          stale_fit_fn=lambda cl: False,
                          print_fn=quiet)
    faults = FaultSchedule(
        device_losses=(DeviceGroupLoss(at_step=4, group="large"),),
        ckpt_crashes=(CheckpointCrash(at_step=2, after_leaves=1),))
    run = sup.run(faults=faults)
    assert run.result.start_step + run.result.steps == 6
    kinds = [r.kind for r in run.recoveries]
    assert kinds == ["checkpoint_crash", "device_loss"]
    loss = run.recoveries[1]
    assert loss.stale_feasible is False and loss.replan_feasible
    assert loss.resumed_from_step == 4      # the step-4 checkpoint
    assert [cl.n_devices for cl in seen] == [8]   # replanned once
    assert ckpt_io.verify(str(tmp_path)) > 0


def test_merge_stats_adds_counters():
    reqs = _reqs(4, n_new=3)
    _, a = _engine(slots=2).run(reqs[:2], seed=0)
    _, b = _engine(slots=2).run(reqs[2:], seed=0)
    m = merge_stats([a, b, None])
    assert m.completed == 4
    assert m.useful_tokens == a.useful_tokens + b.useful_tokens
    assert m.decode_steps == a.decode_steps + b.decode_steps
