"""Data pipeline / checkpoint / serving / roofline-parser tests."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_run
from repro.checkpoint import io as ckpt_io
from repro.configs import get_arch, get_shape, reduced
from repro.data.synthetic import Dataset, mrope_positions
from repro.models.registry import build_model
from repro.roofline.analysis import analyze_lowered, roofline
from repro.serving.engine import Engine
from repro.train.loop import train


# --- data ---------------------------------------------------------------------

def test_dataset_deterministic():
    cfg = reduced(get_arch("phi4-mini-3.8b"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                global_batch=4)
    ds = Dataset(cfg, shape, seed=7)
    a = ds.global_batch(3)
    b = ds.global_batch(3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = ds.global_batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_dataset_host_sharding_covers_global():
    cfg = reduced(get_arch("phi4-mini-3.8b"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=32,
                                global_batch=8)
    ds = Dataset(cfg, shape)
    g = ds.global_batch(0)
    parts = [ds.host_batch(0, h, 4) for h in range(4)]
    re = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(re, g["tokens"])


def test_dataset_labels_are_next_token():
    cfg = reduced(get_arch("qwen1.5-0.5b"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=16,
                                global_batch=2)
    b = Dataset(cfg, shape).global_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_audio_batch_masks_labels():
    cfg = reduced(get_arch("hubert-xlarge"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                global_batch=2)
    b = Dataset(cfg, shape).global_batch(0)
    assert b["frames"].shape == (2, 64, cfg.d_model)
    assert ((b["labels"] >= 0) == b["mask"]).all()


def test_mrope_positions_grid():
    pos = mrope_positions(1, 16, 8)
    assert pos.shape == (1, 24, 3)
    # patches share t=0, text is diagonal
    assert (pos[0, :16, 0] == 0).all()
    assert (pos[0, 16:, 0] == pos[0, 16:, 1]).all()


# --- checkpoint -----------------------------------------------------------------

def test_checkpoint_roundtrip_exact():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16) * 1.5,
                   "c": jnp.array(3, jnp.int32)},
        "tup": (jnp.zeros((2,)), jnp.ones((2,), jnp.float64)
                if jax.config.read("jax_enable_x64") else jnp.ones((2,))),
    }
    with tempfile.TemporaryDirectory() as d:
        ckpt_io.save(d, 12, tree)
        assert ckpt_io.latest_step(d) == 12
        got, step = ckpt_io.restore(d, tree)
        assert step == 12
        flat_a = jax.tree.leaves(tree)
        flat_b = jax.tree.leaves(got)
        for x, y in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


def test_checkpoint_resume_continues_training():
    run = tiny_run("qwen1.5-0.5b", batch=4)
    built = build_model(run)
    with tempfile.TemporaryDirectory() as d:
        r1 = train(built, 4, ckpt_dir=d, log_every=0, warmup=2)
        assert ckpt_io.latest_step(d) == 4
        r2 = train(built, 2, ckpt_dir=d, log_every=0, warmup=2)
        assert ckpt_io.latest_step(d) == 6


# --- serving --------------------------------------------------------------------

def test_engine_greedy_deterministic():
    run = tiny_run("qwen1.5-0.5b", shape="decode_32k")
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    eng = Engine(built, params)
    prompts = np.random.default_rng(0).integers(
        0, run.model.vocab_size, (2, 16)).astype(np.int32)
    a = eng.generate(prompts, 6).tokens
    b = eng.generate(prompts, 6).tokens
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < run.model.vocab_size).all()


def test_engine_rejects_encoder_only():
    run = tiny_run("hubert-xlarge")
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    eng = Engine(built, params)
    with pytest.raises(AssertionError):
        eng.generate(np.zeros((1, 4), np.int32), 1)


# --- roofline parser ------------------------------------------------------------

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[16,2048]{1,0} all-gather(bf16[16,128]{1,0} %p), dimensions={1}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %g), to_apply=%add
  %rs.1 = f32[64]{0} reduce-scatter(f32[1024]{0} %g2), dimensions={0}
  %a2a = bf16[8,32]{1,0} all-to-all(bf16[8,32]{1,0} %x), dimensions={0}
  %agx-start = bf16[4,8]{1,0} all-gather-start(bf16[4,4]{1,0} %q)
  %fusion.all-gather-like = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
}
"""


def test_analyze_lowered_counts_and_bytes():
    got = analyze_lowered(HLO_SAMPLE)
    assert got["all-gather"]["count"] == 2      # bare + -start
    assert got["all-reduce"]["count"] == 1
    assert got["reduce-scatter"]["count"] == 1
    assert got["all-to-all"]["count"] == 1
    assert got["all-gather"]["bytes"] == 16 * 2048 * 2 + 4 * 8 * 2
    assert got["reduce-scatter"]["bytes"] == 1024 * 4
    assert got["total_bytes"] == sum(
        v["bytes"] for k, v in got.items() if k != "total_bytes")


def test_roofline_terms_dominant():
    rec = {
        "mesh": "16x16", "kind": "train", "params": 1e9,
        "active_params": 1e9, "tokens": 1e6,
        "cost_analysis": {"flops": 1e15, "bytes_accessed": 1e9},
        "collectives": {"total_bytes": 1e10},
    }
    t = roofline(rec)
    assert t.dominant == "compute"
    assert t.compute_s == pytest.approx(1e15 / 197e12)
    assert t.collective_s == pytest.approx(1e10 / 50e9)
