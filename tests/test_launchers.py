"""CLI integration tests: the train / serve launchers end-to-end."""
import os
import subprocess
import sys

import pytest


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_train_cli_reduced():
    r = _run(["repro.launch.train", "--arch", "qwen1.5-0.5b", "--reduced",
              "--steps", "8", "--seq", "64", "--batch", "4",
              "--warmup", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: 8 steps" in r.stdout, r.stdout[-500:]
    assert "plan[" in r.stdout          # OSDP pipeline ran


def test_train_cli_force_zdp():
    r = _run(["repro.launch.train", "--arch", "mamba2-2.7b", "--reduced",
              "--steps", "4", "--seq", "32", "--batch", "2",
              "--force-mode", "ZDP", "--warmup", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: 4 steps" in r.stdout


def test_serve_cli_legacy_static():
    r = _run(["repro.launch.serve", "--arch", "hymba-1.5b", "--reduced",
              "--no-plan", "--batch", "2", "--prompt-len", "32",
              "--new-tokens", "8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decoded 8 tokens" in r.stdout, r.stdout[-500:]


def test_serve_cli_planned_continuous():
    r = _run(["repro.launch.serve", "--arch", "qwen1.5-0.5b", "--reduced",
              "--prompt-len", "32", "--new-tokens", "8", "--requests", "5",
              "--mixed", "--memory-limit-gib", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "serve-plan[" in r.stdout, r.stdout[-800:]   # search ran
    assert "admission limit" in r.stdout
    assert "served 5 requests" in r.stdout, r.stdout[-800:]


def test_serve_cli_rejects_encoder():
    r = _run(["repro.launch.serve", "--arch", "hubert-xlarge", "--reduced"])
    assert r.returncode == 1
    assert "encoder-only" in r.stdout
