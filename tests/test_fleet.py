"""Fleet serving tests (ISSUE 8): multi-replica planning, SLO-aware
admission, and the deterministic traffic simulator.

Property harness (all deterministic — seeded loops, no wall clock):

  * replay — the same (fleet, arrivals, seed) reproduces the
    simulation report fingerprint byte-for-byte;
  * load monotonicity — thinned-Poisson arrival sets nest across rate
    scales, and on a single FIFO replica a higher arrival rate never
    improves any common request's ttft (nor the class p99);
  * capacity monotonicity — adding a replica never reduces aggregate
    goodput (OK tokens) under deadline overload;
  * degenerate fleet — a 1-replica/1-class fleet reproduces the
    `search_serve` plan and per-request `ContinuousEngine.run` results
    byte-identically;
  * single-class `RequestClassMix` is an exact alias of the legacy
    `ServingWorkload` path: the committed BENCH_search.json serving
    planner rows re-solve byte-identically through the mix path;
  * `ServeStats` rate guards: empty workloads and all-rejected /
    all-invalid runs never divide by zero.
"""
import json
import math
from functools import lru_cache
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import tiny_run
from repro.cluster.topology import mixed_memory_fleet
from repro.configs import get_arch
from repro.core.api import search_fleet, search_serve
from repro.core.cost_model import RequestClass, RequestClassMix
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Request
from repro.serving.simulator import (SimReplica, TrafficSimulator,
                                     poisson_arrivals, trace_arrivals)

ROOT = Path(__file__).resolve().parent.parent


@lru_cache(maxsize=None)
def _served(arch="qwen1.5-0.5b"):
    run = tiny_run(arch, shape="decode_32k")
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    return built, params


def _replicas(n, slots=2, cache_len=48, max_queue=None):
    built, params = _served()
    return [SimReplica(f"g/{j}", "g",
                       ContinuousEngine(built, params, max_slots=slots,
                                        cache_len=cache_len,
                                        max_queue=max_queue))
            for j in range(n)]


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

MIX2 = RequestClassMix((
    RequestClass("interactive", prompt_len=8, decode_len=4,
                 arrival_rate=0.5),
    RequestClass("batch", prompt_len=16, decode_len=16,
                 arrival_rate=0.15),
))


def test_poisson_arrivals_deterministic_and_sorted():
    a = poisson_arrivals(MIX2, horizon=40, seed=3)
    b = poisson_arrivals(MIX2, horizon=40, seed=3)
    assert a == b and len(a) > 0
    assert all(x.step <= y.step for x, y in zip(a, a[1:]))
    assert {x.cls for x in a} <= {"interactive", "batch"}
    c = poisson_arrivals(MIX2, horizon=40, seed=4)
    assert c != a


def test_poisson_arrival_sets_nest_across_rate_scales():
    """Thinning invariant: for a fixed seed, the arrival set at a
    lower rate is a subset of the set at any higher rate — per
    request (uid), not just in expectation."""
    prev = None
    for scale in (0.25, 0.5, 1.0, 2.0, 4.0):
        cur = {(x.uid, x.step)
               for x in poisson_arrivals(MIX2, horizon=60, seed=9,
                                         rate_scale=scale)}
        if prev is not None:
            assert prev <= cur, f"nesting broken at scale {scale}"
        prev = cur


def test_trace_arrivals_sorted_with_stable_uids():
    arr = trace_arrivals([(5, "b"), (0, "a"), (5, "a")])
    assert [(x.step, x.cls) for x in arr] == \
        [(0, "a"), (5, "a"), (5, "b")]
    assert len({x.uid for x in arr}) == 3


# ---------------------------------------------------------------------------
# replay + load/capacity monotonicity
# ---------------------------------------------------------------------------

def test_fleet_replay_byte_identical():
    """Two fresh fleets fed the same arrivals produce byte-identical
    reports (fingerprint over every per-request field + tokens)."""
    arrivals = poisson_arrivals(MIX2, horizon=24, seed=7)
    reports = [
        TrafficSimulator(_replicas(2), MIX2, seed=5).run(arrivals)
        for _ in range(2)]
    assert reports[0].fingerprint() == reports[1].fingerprint()
    assert reports[0].completed == reports[1].completed > 0


MONO_MIX = RequestClassMix((
    RequestClass("c", prompt_len=8, decode_len=6, arrival_rate=0.25),))


def test_higher_arrival_rate_never_improves_ttft():
    """On a single FIFO replica, extra arrivals can only delay the
    requests both traces share: per-uid ttft is non-decreasing in the
    rate scale, and so is the class p99."""
    results = {}
    for scale in (0.6, 1.2, 2.4):
        arrivals = poisson_arrivals(MONO_MIX, horizon=36, seed=13,
                                    rate_scale=scale, cap_scale=8.0)
        rep = TrafficSimulator(_replicas(1), MONO_MIX, seed=1) \
            .run(arrivals)
        results[scale] = rep
    scales = sorted(results)
    for lo, hi in zip(scales, scales[1:]):
        r_lo, r_hi = results[lo], results[hi]
        ttft_lo = {t.uid: t.ttft_ticks for t in r_lo.requests}
        ttft_hi = {t.uid: t.ttft_ticks for t in r_hi.requests}
        assert set(ttft_lo) <= set(ttft_hi)
        for uid, v in ttft_lo.items():
            assert ttft_hi[uid] >= v, (uid, lo, hi)
        assert (r_hi.per_class["c"].ttft_p99
                >= r_lo.per_class["c"].ttft_p99)
    # the overloaded end actually queues (the property is non-vacuous)
    assert results[2.4].per_class["c"].ttft_p99 > 0.0


def test_adding_a_replica_never_reduces_goodput():
    """Under deadline overload, growing the fleet monotonically grows
    aggregate goodput (OK tokens) and completions."""
    mix = RequestClassMix((
        RequestClass("c", prompt_len=8, decode_len=8,
                     arrival_rate=0.5),))
    arrivals = poisson_arrivals(mix, horizon=32, seed=21)
    toks, done = [], []
    for n in (1, 2, 3):
        rep = TrafficSimulator(_replicas(n), mix,
                               deadline_ticks={"c": 30}, seed=2) \
            .run(arrivals)
        toks.append(rep.ok_tokens)
        done.append(rep.completed)
    assert toks == sorted(toks), toks
    assert done == sorted(done), done
    # overload is real: one replica loses work a bigger fleet serves
    assert toks[0] < toks[-1]


# ---------------------------------------------------------------------------
# degenerate fleet == search_serve + ContinuousEngine.run
# ---------------------------------------------------------------------------

def test_degenerate_fleet_plan_matches_search_serve():
    """A 1-class mix on a homogeneous cluster produces one replica
    group whose plan is byte-identical to plain `search_serve`."""
    model = get_arch("qwen1.5-0.5b")
    fleet = search_fleet(model, classes=[
        RequestClass("default", prompt_len=128, decode_len=64)],
        n_devices=1, memory_limit_gib=4.0)
    solo = search_serve(model, prompt_len=128, decode_len=64,
                        n_devices=1, memory_limit_gib=4.0)
    assert len(fleet.groups) == 1
    g = fleet.groups[0]
    assert g.n_replicas == 1 and g.classes == ("default",)
    assert g.plan.decisions == solo.decisions
    assert g.plan.slots_per_device == solo.slots_per_device
    assert g.plan.max_concurrency == solo.max_concurrency
    assert g.plan.cost == solo.cost
    assert fleet.feasible == solo.feasible
    assert fleet.routing == {"default": {g.name: 1.0}}


def test_degenerate_fleet_sim_matches_engine_run():
    """1 replica, 1 class, every arrival at tick 0: the simulator is
    submit-all-then-drain, so per-request engine results (status,
    tokens, engine-step timestamps) and the engine stats must be
    byte-identical to a plain `ContinuousEngine.run`."""
    mix = RequestClassMix((
        RequestClass("c", prompt_len=8, decode_len=4),))
    n = 5
    arrivals = trace_arrivals([(0, "c")] * n)
    sim = TrafficSimulator(_replicas(1), mix, seed=5)
    rep = sim.run(arrivals)

    built, params = _served()
    eng = ContinuousEngine(built, params, max_slots=2, cache_len=48)
    reqs = [Request(i, sim._prompt("c", arrivals[i].uid), 4)
            for i in range(n)]
    results, stats = eng.run(reqs, seed=5)

    by_rid = {t.rid: t for t in rep.requests}
    assert len(by_rid) == len(results) == n
    for r in results:
        t = by_rid[r.rid]
        er = t.engine_result
        assert er.status == r.status == "OK"
        np.testing.assert_array_equal(np.asarray(er.tokens),
                                      np.asarray(r.tokens))
        assert er.admitted_at_step == r.admitted_at_step
        assert er.finished_at_step == r.finished_at_step
        assert er.attempts == r.attempts
        assert er.prompt_len == r.prompt_len
    st = rep.replica_stats["g/0"]
    for f in ("prefill_steps", "decode_steps", "useful_tokens",
              "completed", "wasted_tokens", "retries", "rejected",
              "invalid", "timed_out", "failed", "slots"):
        assert getattr(st, f) == getattr(stats, f), f


# ---------------------------------------------------------------------------
# single-class mix == legacy workload (exact alias) + BENCH stability
# ---------------------------------------------------------------------------

def test_single_class_mix_is_exact_alias():
    model = get_arch("qwen1.5-0.5b")
    legacy = search_serve(model, prompt_len=128, decode_len=64,
                          n_devices=1, memory_limit_gib=4.0)
    mixed = search_serve(model, mix=RequestClassMix.single(128, 64),
                         n_devices=1, memory_limit_gib=4.0)
    assert mixed.decisions == legacy.decisions
    assert mixed.cost == legacy.cost
    assert mixed.slots_per_device == legacy.slots_per_device
    assert mixed.max_concurrency == legacy.max_concurrency
    assert mixed.feasible == legacy.feasible
    assert mixed.mix is not None and len(mixed.mix) == 1
    assert mixed.class_costs == {"default": legacy.cost}


def test_bench_serving_rows_byte_identical_via_mix():
    """Re-solve the committed BENCH serving planner rows through the
    RequestClassMix path and assert the pinned decision metrics are
    byte-identical — the fleet layer must not move any serving
    answer."""
    from repro.configs import DeviceInfo
    doc = json.loads((ROOT / "BENCH_search.json").read_text())
    rows = {k: v for k, v in doc["serving"]["rows"].items()
            if k.startswith("plan-")}
    assert len(rows) >= 3
    for name, row in rows.items():
        device = (DeviceInfo.preset(row["device"])
                  if row["device"] != "tpu-v5e" else None)
        plan = search_serve(
            get_arch(row["model"]),
            mix=RequestClassMix.single(512 if row["n_devices"] > 1
                                       else 128,
                                       128 if row["n_devices"] > 1
                                       else 64),
            n_devices=row["n_devices"],
            memory_limit_gib=row["limit_gib"], device=device)
        assert plan.feasible == row["planned_feasible"], name
        assert plan.max_concurrency == row["concurrency"], name
        assert plan.slots_per_device == row["slots_per_device"], name
        assert round(plan.cost.tpot * 1e3, 3) == row["tpot_ms"], name
        assert round(plan.cost.ttft * 1e3, 3) == row["ttft_ms"], name
        assert round(plan.cost.throughput, 1) == \
            row["throughput_tok_s"], name
        assert round(plan.cost.memory / 2**30, 2) == \
            row["memory_gib"], name


# ---------------------------------------------------------------------------
# fleet planner structure
# ---------------------------------------------------------------------------

FLEET_MIX = RequestClassMix((
    RequestClass("interactive", prompt_len=128, decode_len=32,
                 arrival_rate=8.0, ttft_slo=0.05, tpot_slo=0.02),
    RequestClass("batch", prompt_len=2048, decode_len=256,
                 arrival_rate=0.5),
))


def test_search_fleet_heterogeneous_structure():
    """On a mixed-memory fleet the SLO strategy partitions the classes
    across device groups; routing covers every class with weights
    summing to 1, and admission caps are positive."""
    plan = search_fleet(get_arch("qwen1.5-0.5b"), mix=FLEET_MIX,
                        cluster=mixed_memory_fleet(8, 4.0, 8, 16.0,
                                                   pod_size=4),
                        memory_limit_gib=4.0,
                        replica_candidates=(1, 2, 4), strategy="slo")
    assert plan.feasible
    assert len(plan.groups) >= 2
    routed = set()
    for g in plan.groups:
        assert g.n_replicas >= 1 and g.devices_per_replica >= 1
        assert g.plan.feasible
        routed.update(g.classes)
    assert routed == {"interactive", "batch"}
    for c in FLEET_MIX.names:
        weights = plan.routing[c]
        assert weights and math.isclose(sum(weights.values()), 1.0)
        assert plan.admission[c] >= 1
    assert plan.throughput > 0 and plan.goodput > 0
    assert "fleet-plan" in plan.summary()


def test_search_fleet_uniform_is_single_group():
    plan = search_fleet(get_arch("qwen1.5-0.5b"), mix=FLEET_MIX,
                        cluster=mixed_memory_fleet(8, 4.0, 8, 16.0,
                                                   pod_size=4),
                        memory_limit_gib=4.0,
                        replica_candidates=(1, 2, 4),
                        strategy="uniform")
    assert len(plan.groups) == 1
    g = plan.groups[0]
    assert set(g.classes) == {"interactive", "batch"}
    # uniform replication is bounded by the smallest device's HBM
    assert g.plan.cost.memory <= 4.0 * 2**30


# ---------------------------------------------------------------------------
# ServeStats guards
# ---------------------------------------------------------------------------

def test_stats_empty_workload_has_no_rate_blowups():
    built, params = _served()
    eng = ContinuousEngine(built, params, max_slots=2, cache_len=16)
    results, stats = eng.run([])
    assert results == []
    assert stats.completed == stats.terminal == 0
    assert stats.completion_rate == 0.0
    assert stats.tokens_per_request == 0.0
    assert stats.goodput_tokens_per_step == 0.0
    assert stats.slot_utilization == 0.0
    assert stats.tokens_per_s >= 0.0


def test_stats_all_invalid_run():
    """Every request INVALID (prompt exceeds the cache): terminal
    counts stay consistent and no rate property divides by zero."""
    built, params = _served()
    cfg = built.model.cfg
    eng = ContinuousEngine(built, params, max_slots=2, cache_len=8)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 16)
                    .astype(np.int32), 4) for i in range(3)]
    results, stats = eng.run(reqs)
    assert all(r.status == "INVALID" for r in results)
    assert stats.completed == 0 and stats.terminal == 3
    assert stats.completion_rate == 0.0
    assert stats.tokens_per_request == 0.0
    assert stats.goodput_tokens_per_step == 0.0
    assert stats.slot_utilization == 0.0


def test_stats_backpressure_rejections_counted():
    built, params = _served()
    cfg = built.model.cfg
    eng = ContinuousEngine(built, params, max_slots=1, cache_len=16,
                           max_queue=0)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8)
                    .astype(np.int32), 2) for i in range(3)]
    results, stats = eng.run(reqs)
    statuses = sorted(r.status for r in results)
    assert statuses == ["OK", "REJECTED", "REJECTED"]
    assert stats.rejected == 2 and stats.completed == 1
    assert stats.completion_rate == pytest.approx(1 / 3)
    assert stats.tokens_per_request == 2.0
