"""Property-based model invariants (hypothesis)."""
import dataclasses

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.attention import attention_ref, flash_attention
from repro.models.common import attn_geometry
from repro.models.ssm import ssd_chunk_scan, ssd_ref
from repro.configs import get_arch


@given(seq=st.integers(8, 48), window=st.integers(1, 64),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_window_geq_seq_equals_full(seq, window, seed):
    """SWA with window >= seq is exactly full causal attention."""
    k0 = jax.random.PRNGKey(seed)
    ks = jax.random.split(k0, 3)
    q = jax.random.normal(ks[0], (1, seq, 1, 2, 8))
    k = jax.random.normal(ks[1], (1, seq, 1, 8))
    v = jax.random.normal(ks[2], (1, seq, 1, 8))
    full = attention_ref(q, k, v, causal=True, window=0)
    win = attention_ref(q, k, v, causal=True, window=max(window, seq))
    np.testing.assert_allclose(np.asarray(win), np.asarray(full), atol=1e-6)


@given(bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_flash_block_size_invariance(bq, bk, seed):
    """Online-softmax result independent of block sizes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    S = 32
    q = jax.random.normal(ks[0], (1, S, 2, 2, 8))
    k = jax.random.normal(ks[1], (1, S, 2, 8))
    v = jax.random.normal(ks[2], (1, S, 2, 8))
    a = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


@given(chunk=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_ssd_equals_sequential_recurrence(chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, S, nh, hd, ns = 1, 32, 2, 8, 4
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    a_log = jax.random.uniform(ks[2], (nh,), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[3], (B, S, ns)) * 0.5
    c = jax.random.normal(ks[4], (B, S, ns)) * 0.5
    y, s = ssd_chunk_scan(x, dt, a_log, b, c, chunk)
    y_ref, s_ref = ssd_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-4,
                               rtol=1e-3)


@pytest.mark.parametrize("arch,tp", [
    ("arctic-480b", 16), ("dbrx-132b", 16), ("hymba-1.5b", 16),
    ("qwen2-vl-2b", 16), ("llama3-405b", 16), ("phi4-mini-3.8b", 16),
    ("qwen1.5-0.5b", 16), ("moonshot-v1-16b-a3b", 16),
    ("hubert-xlarge", 16),
])
def test_attn_geometry_tp_divisibility(arch, tp):
    """Padded GQA geometry must reshape cleanly on the 16-way model axis
    (or fall back to replication) — the dry-run's correctness premise."""
    cfg = get_arch(arch)
    g = attn_geometry(cfg, tp)
    if g.tp:
        assert (g.n_kv * g.group_padded) % tp == 0
        assert g.q_flat % tp == 0
        assert g.group_padded >= g.group
        assert g.padded_heads <= 1.5 * cfg.n_heads
    assert g.n_kv == cfg.n_kv_heads  # kv heads never padded (replicated)


def test_padded_heads_zero_contribution():
    """Query-head padding is masked: logits identical to tp=1 build up to
    dtype noise requires multi-device; here we check the mask shape
    math — padded head outputs are zeroed before wo."""
    from repro.models.attention import _group_mask
    cfg = get_arch("arctic-480b")
    g = attn_geometry(cfg, 16)
    m = _group_mask(g, jnp.float32)
    assert m.shape == (1, g.group_padded)
    assert float(m.sum()) == g.group
