"""Differential oracle for all four cover solvers (hypothesis).

Property-based companion to tests/test_ilp.py: random multi-mode
instances small enough to enumerate exhaustively, and the theorem each
solver is supposed to satisfy:

  * ilp (both backends) == brute force == dfs, to 1e-9;
  * knapsack is exact *on its quantized problem* — its true-cost gap
    is purely quantization loss, which benchmarks/solver_audit.py
    bounds on the real model zoo;
  * greedy never beats the optimum, and on single-mode instances its
    overshoot is bounded by its final pick (the ratio-prefix theorem:
    the prefix minus the last item is the cheapest fractional cover of
    its own coverage, which undershoots the need — so greedy <= OPT +
    ext of the last item taken);
  * uncoverable instances are detected by every backend, with the
    byte-identical fallback on single-mode instances (multi-mode
    fallbacks differ per solver; search_plan's repair escalates all of
    them to the same all-max plan — asserted by the audit's
    decisions_identical column on the committed infeasible rows).
"""
import itertools
import math

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.ilp import HAVE_SCIPY_MILP, solve_ilp
from repro.core.search import (SliceItem, _solve_dfs, _solve_greedy,
                               _solve_knapsack)

MODES = ("ZDP", "ZDP+R", "DP+R")


@st.composite
def instances(draw, max_items=7, max_modes=3,
              min_frac=0.05, max_frac=1.3):
    n = draw(st.integers(1, max_items))
    items = []
    for i in range(n):
        modes = MODES[:draw(st.integers(1, max_modes))]
        sav = {m: draw(st.floats(1.0, 100.0)) for m in modes}
        ext = {m: draw(st.floats(0.01, 10.0)) for m in modes}
        items.append(SliceItem(f"op{i}", 0, 1, sav, ext))
    cap = sum(max(it.savings.values()) for it in items)
    need = draw(st.floats(min_frac, max_frac)) * cap
    return items, need


def _cost(items, choice):
    return sum(items[i].extra_time[c]
               for i, c in enumerate(choice) if c)


def _cover(items, choice):
    return sum(items[i].savings[c]
               for i, c in enumerate(choice) if c)


def _brute(items, need):
    best = math.inf
    menus = [[None] + list(it.savings) for it in items]
    for combo in itertools.product(*menus):
        sav = sum(items[i].savings[c]
                  for i, c in enumerate(combo) if c)
        if sav >= need:
            best = min(best, sum(items[i].extra_time[c]
                                 for i, c in enumerate(combo) if c))
    return best


def _close(a, b):
    return math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-9)


@settings(max_examples=80, deadline=None)
@given(instances())
def test_ilp_bnb_matches_brute_force(inst):
    items, need = inst
    ref = _brute(items, need)
    res = solve_ilp(items, need, backend="bnb")
    assert res.optimal
    if math.isinf(ref):
        assert math.isinf(res.objective)
    else:
        assert _cover(items, res.choice) >= need - 1e-9
        assert _close(_cost(items, res.choice), ref)
        assert _close(res.objective, ref)
        assert _close(res.lower_bound, ref)


@pytest.mark.skipif(not HAVE_SCIPY_MILP,
                    reason="scipy.optimize.milp unavailable")
@settings(max_examples=80, deadline=None)
@given(instances())
def test_ilp_milp_matches_brute_force(inst):
    items, need = inst
    ref = _brute(items, need)
    res = solve_ilp(items, need, backend="milp")
    assert res.optimal
    if math.isinf(ref):
        assert math.isinf(res.objective)
    else:
        assert _cover(items, res.choice) >= need - 1e-9
        assert _close(_cost(items, res.choice), ref)


@settings(max_examples=80, deadline=None)
@given(instances())
def test_dfs_matches_ilp_cost(inst):
    """The paper's solver is exact wherever its node budget does not
    truncate — always, at oracle sizes."""
    items, need = inst
    choice, _ = _solve_dfs(items, need)
    res = solve_ilp(items, need, backend="bnb")
    if math.isinf(res.objective):
        assert _cover(items, choice) < need
    else:
        assert _cover(items, choice) >= need - 1e-9
        assert _close(_cost(items, choice), res.objective)


@settings(max_examples=60, deadline=None)
@given(instances(max_frac=0.95), st.integers(16, 256))
def test_knapsack_exact_on_quantized_problem(inst, buckets):
    """Round savings down to the quantum, round the need up: knapsack
    must hit the exact optimum of THAT problem (cost-wise); the
    true-problem gap is bounded by what quantization destroyed."""
    items, need = inst
    q = sum(max(it.savings.values()) for it in items) / buckets
    choice, _ = _solve_knapsack(items, need, quantum=q)
    q_items = [SliceItem(it.op_name, 0, 1,
                         {m: (it.savings[m] // q) * q
                          for m in it.savings},
                         dict(it.extra_time)) for it in items]
    q_need = math.ceil(need / q) * q
    ref = _brute(q_items, q_need - 1e-9 * q)
    if math.isinf(ref):
        # quantized-uncoverable: documented max-saving fallback
        assert list(choice) == [max(it.savings, key=it.savings.get)
                                for it in items]
    else:
        assert _cover(q_items, choice) >= q_need - 1e-6 * q
        assert _close(_cost(items, choice), ref)


@settings(max_examples=80, deadline=None)
@given(instances(max_modes=1))
def test_greedy_bounded_by_prefix_theorem(inst):
    items, need = inst
    ref = _brute(items, need)
    choice, t = _solve_greedy(items, need)
    if math.isinf(ref):
        assert math.isinf(t)
        return
    assert _cover(items, choice) >= need - 1e-9
    assert t >= ref - 1e-9
    last = max((items[i].extra_time[c]
                for i, c in enumerate(choice) if c), default=0.0)
    assert t <= ref + last + 1e-9


@settings(max_examples=60, deadline=None)
@given(instances(max_modes=1, min_frac=1.01, max_frac=1.6))
def test_uncoverable_single_mode_identical_fallback(inst):
    """Single-mode uncoverable: all four land on the same all-shard
    fallback, byte for byte."""
    items, need = inst
    expect = [max(it.savings, key=it.savings.get) for it in items]
    assert list(_solve_dfs(items, need)[0]) == expect
    assert list(_solve_knapsack(items, need)[0]) == expect
    g_choice, g_t = _solve_greedy(items, need)
    assert list(g_choice) == expect and math.isinf(g_t)
    res = solve_ilp(items, need, backend="bnb")
    assert list(res.choice) == expect
    assert res.optimal and math.isinf(res.objective)


@settings(max_examples=60, deadline=None)
@given(instances(min_frac=1.01, max_frac=1.6))
def test_uncoverable_multi_mode_detected_by_all(inst):
    """Multi-mode uncoverable: every backend signals it (coverage
    short of the need / inf objective) — the identical final plan is
    restored by search_plan's all-max escalation."""
    items, need = inst
    for choice in (_solve_dfs(items, need)[0],
                   _solve_knapsack(items, need)[0]):
        assert _cover(items, choice) < need
    assert math.isinf(_solve_greedy(items, need)[1])
    res = solve_ilp(items, need, backend="bnb")
    assert res.optimal and math.isinf(res.objective)
    assert math.isinf(res.gap)
