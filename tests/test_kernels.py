"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# Known pre-existing environment failure, not a code regression: the
# kernels target the pltpu.CompilerParams API; on the CPU-only
# jax 0.4.x in this image that attribute does not exist and every
# pallas_call raises AttributeError before interpret=True can help.
pytestmark = pytest.mark.skipif(
    not hasattr(pltpu, "CompilerParams"),
    reason="Pallas kernels need jax with pltpu.CompilerParams "
           "(>=0.5); the CPU-only jax in this environment predates it")


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=1e-3)


# --- split_matmul -----------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 64),
                                   (256, 384, 128), (64, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_matmul_sweep(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), dtype)
    y = ops.split_matmul(x, w, bm=64, bn=64, bk=64, interpret=True)
    y_ref = ref.split_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))


def test_split_matmul_is_operator_splitting():
    """K-grid count == paper slice granularity: result independent of g."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 128), jnp.float32)
    outs = [np.asarray(ops.split_matmul(x, w, bk=bk, bm=128, bn=128,
                                        interpret=True))
            for bk in (512, 256, 128, 64)]  # g = 1, 2, 4, 8
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-5)


# --- flash_attention --------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # B, KV, G, S, T, hd
    (1, 1, 1, 64, 64, 32),
    (2, 2, 3, 128, 128, 32),
    (1, 4, 2, 64, 192, 64),     # cross lengths (prefill chunking)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, causal, window, dtype):
    B, KV, G, S, T, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 3)
    q = jax.random.normal(ks[0], (B, KV, G, S, hd), dtype) * 0.5
    k = jax.random.normal(ks[1], (B, KV, T, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, KV, T, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bk=32, interpret=True)
    out_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               **_tol(dtype))


def test_flash_matches_model_path():
    """Kernel and the model's jnp blockwise flash agree."""
    from repro.models.attention import flash_attention as jnp_flash
    B, KV, G, S, hd = 2, 2, 2, 96, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    a = jnp_flash(q, k, v, causal=True, window=13, bq=32, bk=32)
    b = ops.flash_attention(q.transpose(0, 2, 3, 1, 4),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True, window=13,
                            bq=32, bk=32, interpret=True
                            ).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=1e-4)


# --- ssd_scan ----------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # B, S, nh, hd, ns, chunk, bh
    (1, 32, 2, 8, 4, 8, 2),
    (2, 64, 4, 16, 8, 16, 2),
    (1, 128, 8, 32, 16, 32, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(shape, dtype):
    B, S, nh, hd, ns, chunk, bh = shape
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 5)
    x = (jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    a_log = jax.random.uniform(ks[2], (nh,), minval=0.0, maxval=1.5)
    b = (jax.random.normal(ks[3], (B, S, ns)) * 0.5).astype(dtype)
    c = (jax.random.normal(ks[4], (B, S, ns)) * 0.5).astype(dtype)
    y = ops.ssd_scan(x, dt, a_log, b, c, chunk=chunk, bh=bh, interpret=True)
    y_ref = ref.ssd_scan_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=(5e-2 if dtype == jnp.bfloat16 else 1e-4),
                               rtol=2e-2)


def test_ssd_chunk_invariance():
    """y must be independent of the chunk size (state-passing correct)."""
    B, S, nh, hd, ns = 1, 96, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    a_log = jax.random.uniform(ks[2], (nh,), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[3], (B, S, ns)) * 0.5
    c = jax.random.normal(ks[4], (B, S, ns)) * 0.5
    outs = [np.asarray(ops.ssd_scan(x, dt, a_log, b, c, chunk=q,
                                    interpret=True))
            for q in (8, 16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)
