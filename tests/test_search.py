"""Search engine (Algorithm 1) correctness: DFS == brute force == knapsack
on small instances; pruned DFS scales; Scheduler picks the throughput
argmax."""
import itertools
import math
import random

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import (DeviceInfo, SINGLE_POD_MESH, OSDPConfig,
                           get_arch, get_shape)
from repro.core.cost_model import CostEnv, DP, ZDP
from repro.core.descriptions import describe
from repro.core.search import (SliceItem, _solve_dfs, _solve_greedy,
                               _solve_knapsack, schedule, search_plan)


def _mk_items(rng, n):
    items = []
    for i in range(n):
        sav = rng.uniform(1, 100)
        t = rng.uniform(0.01, 10.0)
        items.append(SliceItem(f"op{i}", 0, 1, {ZDP: sav}, {ZDP: t}))
    return items


def _brute_force(items, need):
    best_t, best = math.inf, None
    n = len(items)
    for mask in range(1 << n):
        sav = sum(items[i].savings[ZDP] for i in range(n) if mask >> i & 1)
        if sav < need:
            continue
        t = sum(items[i].extra_time[ZDP] for i in range(n) if mask >> i & 1)
        if t < best_t:
            best_t, best = t, mask
    return best_t


@pytest.mark.parametrize("seed", range(8))
def test_dfs_matches_brute_force(seed):
    rng = random.Random(seed)
    items = _mk_items(rng, 10)
    total = sum(it.savings[ZDP] for it in items)
    need = rng.uniform(0.2, 0.9) * total
    choice, _ = _solve_dfs(items, need)
    t_dfs = sum(items[i].extra_time[c] for i, c in enumerate(choice) if c)
    sav = sum(items[i].savings[c] for i, c in enumerate(choice) if c)
    assert sav >= need - 1e-9
    t_bf = _brute_force(items, need)
    assert t_dfs == pytest.approx(t_bf, rel=1e-9)


@pytest.mark.parametrize("seed", range(4))
def test_knapsack_near_optimal(seed):
    rng = random.Random(100 + seed)
    items = _mk_items(rng, 12)
    total = sum(it.savings[ZDP] for it in items)
    need = 0.5 * total
    t_bf = _brute_force(items, need)
    choice, _ = _solve_knapsack(items, need, quantum=total / 4096)
    sav = sum(items[i].savings[c] for i, c in enumerate(choice) if c)
    t = sum(items[i].extra_time[c] for i, c in enumerate(choice) if c)
    assert sav >= need * (1 - 2e-3)
    assert t <= t_bf * 1.05 + 1e-9


@pytest.mark.parametrize("seed", range(4))
def test_greedy_feasible(seed):
    rng = random.Random(200 + seed)
    items = _mk_items(rng, 20)
    total = sum(it.savings[ZDP] for it in items)
    need = 0.7 * total
    choice, t = _solve_greedy(items, need)
    sav = sum(items[i].savings[c] for i, c in enumerate(choice) if c)
    assert sav >= need
    assert t < math.inf


def test_dfs_scales_to_paper_operator_counts():
    """Paper: 98-194 operators, search in 9-307 s. Our branch-and-bound
    DFS must handle 200 items fast."""
    import time
    rng = random.Random(42)
    items = _mk_items(rng, 200)
    total = sum(it.savings[ZDP] for it in items)
    t0 = time.perf_counter()
    choice, nodes = _solve_dfs(items, 0.6 * total)
    dt = time.perf_counter() - t0
    sav = sum(items[i].savings[c] for i, c in enumerate(choice) if c)
    assert sav >= 0.6 * total - 1e-6
    assert dt < 30.0, f"search took {dt:.1f}s"


def test_infeasible_falls_back_to_max_sharding():
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
    desc = describe(get_arch("llama3-405b"), get_shape("train_4k"))
    res = search_plan(desc, 256, env,
                      OSDPConfig(memory_limit_bytes=1 * 2**30))
    assert not res.feasible
    # every decidable op must be sharded in the fallback plan
    from repro.core.cost_model import DP as DPM
    for op in desc.decidable():
        assert res.decisions[op.name].uniform() != DPM, op.name


def test_memory_limit_binds():
    """Looser limit -> no slower plan; tighter -> no smaller memory."""
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    prev_time = None
    for gib in (64, 32, 16, 8):
        res = search_plan(desc, 256, env,
                          OSDPConfig(memory_limit_bytes=gib * 2**30))
        if res.feasible:
            assert res.cost.memory <= gib * 2**30 * 1.001
            if prev_time is not None:
                assert res.cost.time >= prev_time - 1e-9
            prev_time = res.cost.time


def test_scheduler_returns_throughput_argmax():
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
    desc = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    res = schedule(desc, env, OSDPConfig(), max_batch=512)
    assert res.candidates, "no feasible candidates"
    best_b, best_tp = max(res.candidates, key=lambda c: c[1])
    assert res.batch_size == best_b
    assert res.cost.throughput == pytest.approx(best_tp)


def test_osdp_between_dp_and_fsdp():
    """OSDP plan: memory <= limit, and time <= all-ZDP time (never worse
    than FSDP when feasible) — the paper's core claim."""
    from repro.core import dp_baseline, fsdp_baseline, osdp
    m = get_arch("phi4-mini-3.8b")
    s = get_shape("train_4k")
    p = osdp(m, s, SINGLE_POD_MESH, memory_limit_gib=16)
    pf = fsdp_baseline(m, s, SINGLE_POD_MESH)
    pd = dp_baseline(m, s, SINGLE_POD_MESH)
    assert p.cost.memory <= 16 * 2**30 * 1.001
    assert p.cost.time <= pf.cost.time * 1.001
    assert p.cost.memory <= pd.cost.memory * 1.001
