"""Two-resource timeline cost model + overlapped runtime.

Pins the PR's two contracts:

  * cost model — overlap 0 is byte-identical to the legacy serial sum
    (on random plans across models x envs), comm decomposes exactly
    into per-level buckets, exposed time is monotone non-increasing in
    every overlap factor, and the evaluator (full eval AND O(1) flip
    sequences) tracks the direct `plan_cost` timeline exactly;
  * runtime — the prefetch + gradient-bucketing transforms are
    identity on values: the overlapped train step produces the SAME
    loss trajectory as the legacy step.
"""
import dataclasses
import random

import numpy as np
import pytest

from conftest import tiny_run
from repro.cluster.topology import (ClusterSpec, gpu_cluster,
                                    mixed_memory_fleet, tpu_multipod)
from repro.configs import (DeviceInfo, OSDPConfig, RunConfig, MeshConfig,
                           get_arch, get_shape, reduced)
from repro.core.cost_model import (DP, MODES, ZDP, CostEnv, Decision,
                                   PlanEvaluator, ServingWorkload,
                                   exposed_step_time, plan_cost,
                                   serving_plan_cost, uniform_plan)
from repro.core.descriptions import ShapeConfig, describe
from repro.core.hybrid import Factorization, hybrid_step_time

MODELS = ("phi4-mini-3.8b", "dbrx-132b", "mamba2-2.7b")


def _specs():
    dev = DeviceInfo()
    a100 = DeviceInfo.preset("a100-80g")
    return {
        "flat": ClusterSpec.from_device(dev, 64),
        "multipod": tpu_multipod(4, 16, dev),
        "gpu3": gpu_cluster(8, 8, device=a100, nvlink_bw=300e9,
                            ib_bw=25e9, spine_nodes=2, spine_bw=6e9),
        "mixed": mixed_memory_fleet(8, 16, 8, 48, pod_size=8, device=dev),
    }


def _random_plan(desc, spec, rng):
    modes = [DP, ZDP] + [spec.span_mode(k) for k in range(1, spec.depth)]
    decs = {}
    for op in desc.operators:
        if not op.decidable:
            decs[op.name] = Decision(op.name, (DP,))
            continue
        g = rng.choice([1, 2, 4]) if op.splittable else 1
        decs[op.name] = Decision(
            op.name, tuple(rng.choice(modes) for _ in range(g)))
    return decs


def _cost(desc, decs, batch, spec, ck=True):
    return plan_cost(desc, decs, batch,
                     CostEnv(spec.device, cluster=spec, checkpointing=ck))


# --- overlap = 0 is the legacy model, exactly --------------------------------

@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("spec_name", sorted(_specs()))
def test_zero_overlap_reproduces_legacy(model, spec_name):
    desc = describe(get_arch(model), get_shape("train_4k"))
    spec = _specs()[spec_name]
    spec0 = spec.with_overlap(0.0)
    assert not spec0.has_overlap
    rng = random.Random(hash((model, spec_name)) & 0xFFFF)
    for trial in range(4):
        decs = _random_plan(desc, spec, rng)
        for batch in (64, 512):
            legacy = _cost(desc, decs, batch, spec)
            zeroed = _cost(desc, decs, batch, spec0)
            for f in ("memory", "peak_memory", "time", "comm_time",
                      "compute_time", "throughput"):
                assert getattr(zeroed, f) == pytest.approx(
                    getattr(legacy, f), rel=1e-12, abs=1e-15), f
            # serial composition holds exactly at overlap 0
            assert legacy.time == pytest.approx(
                legacy.compute_time + legacy.comm_time, rel=1e-12)


@pytest.mark.parametrize("model", MODELS)
def test_comm_decomposes_into_level_buckets(model):
    """sum over levels of the comm buckets == the scalar comm_time, and
    the reported time is exactly the exposed-comm combination."""
    desc = describe(get_arch(model), get_shape("train_4k"))
    for spec_name, spec in _specs().items():
        spec_ov = spec.with_overlap(0.5)
        rng = random.Random(hash((model, spec_name, "lv")) & 0xFFFF)
        for trial in range(3):
            decs = _random_plan(desc, spec, rng)
            c = _cost(desc, decs, 256, spec_ov)
            assert len(c.comm_by_level) == spec.depth
            assert sum(c.comm_by_level) == pytest.approx(
                c.comm_time, rel=1e-12, abs=1e-15), spec_name
            want = exposed_step_time(c.compute_time, c.comm_by_level,
                                     spec_ov.overlaps)
            assert c.time == pytest.approx(want, rel=1e-12), spec_name


def test_exposed_time_monotone_in_overlap():
    desc = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
    spec = _specs()["gpu3"]
    decs = uniform_plan(desc, ZDP)
    times = [_cost(desc, decs, 256, spec.with_overlap(ov)).time
             for ov in (0.0, 0.3, 0.7, 1.0)]
    for a, b in zip(times, times[1:]):
        assert b <= a * (1 + 1e-12)
    full = _cost(desc, decs, 256, spec.with_overlap(1.0))
    assert full.time >= full.compute_time * (1 - 1e-12)
    # per-level overlap only hides that level's traffic
    part = _cost(desc, decs, 256,
                 spec.with_overlap({spec.levels[0].name: 1.0}))
    assert full.time <= part.time * (1 + 1e-12)


def test_overlap_validation():
    spec = _specs()["flat"]
    with pytest.raises(ValueError):
        spec.with_overlap(1.5)
    with pytest.raises(ValueError):
        spec.with_overlap({"no-such-level": 0.5})


# --- evaluator equivalence under the timeline --------------------------------

@pytest.mark.parametrize("spec_name", ("multipod", "gpu3"))
def test_evaluator_matches_plan_cost_under_overlap(spec_name):
    desc = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
    spec = _specs()[spec_name].with_overlap(
        {_specs()[spec_name].levels[0].name: 0.9,
         _specs()[spec_name].levels[1].name: 0.4})
    env = CostEnv(spec.device, cluster=spec)
    rng = random.Random(31)
    for trial in range(4):
        decs = _random_plan(desc, spec, rng)
        for batch in (64, 512):
            want = plan_cost(desc, decs, batch, env)
            ev = PlanEvaluator.for_decisions(desc, env, decs)
            got = ev.plan_cost(ev.modes_from_decisions(decs), batch)
            for f in ("memory", "time", "comm_time", "compute_time",
                      "throughput"):
                assert getattr(got, f) == pytest.approx(
                    getattr(want, f), rel=1e-9), (spec_name, f)
            assert tuple(got.comm_by_level) == pytest.approx(
                tuple(want.comm_by_level), rel=1e-9)


def test_incremental_flips_match_full_eval_under_overlap():
    """O(1) flip deltas must track the timeline exactly — the max() in
    the exposed-comm combine happens at result() time, so the per-level
    running sums cannot drift."""
    desc = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
    base = _specs()["gpu3"]
    spec = base.with_overlap({base.levels[0].name: 0.8,
                              base.levels[2].name: 0.5})
    env = CostEnv(spec.device, cluster=spec)
    gran = {op.name: (4 if op.splittable else 1)
            for op in desc.decidable()}
    ev = PlanEvaluator(desc, env, gran)
    ev.begin(np.zeros(ev.n_slices, dtype=np.int8), 256)
    rng = random.Random(13)
    for step in range(120):
        j = rng.randrange(ev.n_slices)
        if not desc.operators[int(ev.slice_op[j])].decidable:
            continue
        ev.flip(j, rng.randrange(len(MODES)))
        if step % 15 == 0:
            want = plan_cost(desc, ev.decisions(ev.current_modes), 256, env)
            got = ev.result()
            assert got.time == pytest.approx(want.time, rel=1e-9)
            assert tuple(got.comm_by_level) == pytest.approx(
                tuple(want.comm_by_level), rel=1e-9)


# --- hybrid + serving paths ---------------------------------------------------

def test_pp_boundary_overlap_monotone_and_zero_identical():
    desc = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
    spec = _specs()["gpu3"]
    dev = spec.device
    f = Factorization(4, 4, 4)
    t0 = hybrid_step_time(0.1, desc, dev, 256, f, cluster=spec)
    t0b = hybrid_step_time(0.1, desc, dev, 256, f,
                           cluster=spec.with_overlap(0.0))
    assert t0 == t0b
    prev = t0
    for ov in (0.3, 0.7, 1.0):
        t = hybrid_step_time(0.1, desc, dev, 256, f,
                             cluster=spec.with_overlap(ov))
        assert t <= prev * (1 + 1e-12)
        prev = t


def test_serving_overlap_monotone_and_zero_identical():
    model = get_arch("phi4-mini-3.8b")
    spec = _specs()["multipod"]
    wl = ServingWorkload(prompt_len=512, decode_len=128)
    n = spec.n_devices
    desc_pre = describe(model, ShapeConfig("serve_prefill", 512, n,
                                           "prefill"))
    desc_dec = describe(model, ShapeConfig("serve_decode", 1, n, "decode"))
    decs = uniform_plan(desc_dec, ZDP)

    def cost(s):
        env = CostEnv(s.device, cluster=s, train=False)
        return serving_plan_cost(desc_pre, desc_dec, decs, wl, env, 8)

    legacy = cost(spec)
    zeroed = cost(spec.with_overlap(0.0))
    assert zeroed.decode_step_time == legacy.decode_step_time
    assert zeroed.prefill_time == legacy.prefill_time
    prev = legacy
    for ov in (0.4, 0.9):
        c = cost(spec.with_overlap(ov))
        assert c.decode_step_time <= prev.decode_step_time * (1 + 1e-12)
        assert c.prefill_time <= prev.prefill_time * (1 + 1e-12)
        prev = c


# --- runtime: the overlapped step is value-identical --------------------------

def test_overlap_config_validation():
    from repro.sharding.specs import OverlapConfig
    with pytest.raises(ValueError):
        OverlapConfig(prefetch=-1)
    with pytest.raises(ValueError):
        OverlapConfig(bucket_bytes=-1)


def test_prefetch_weights_and_bucket_grads_are_identity():
    import jax
    import jax.numpy as jnp
    from repro.sharding.specs import _prefetch_weights
    from repro.train.loop import _bucket_grads
    ws = [jnp.arange(4.0) + i for i in range(5)]
    for ahead in (1, 2):
        out = _prefetch_weights(ws, ahead)
        for a, b in zip(ws, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tree = {"a": jnp.ones((3, 3)), "b": [jnp.zeros((7,)),
                                         jnp.arange(5.0)]}
    for bucket in (1, 40, 10**9):
        got = _bucket_grads(tree, bucket)
        assert jax.tree.structure(got) == jax.tree.structure(tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_overlapped_train_step_same_loss_trajectory():
    """Prefetch barriers + gradient buckets must not change the math:
    same plan, same data, same losses — bit-for-bit."""
    import jax
    import jax.numpy as jnp
    from repro.core.plan import make_plan
    from repro.models.registry import build_model
    from repro.optim import AdamWConfig
    from repro.sharding.specs import OverlapConfig
    from repro.train.loop import make_train_step

    cfg = reduced(get_arch("qwen1.5-0.5b"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=32,
                                global_batch=2)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig((1,), ("data",)),
                    osdp=OSDPConfig(force_mode="ZDP",
                                    operator_splitting=True,
                                    default_slice_granularity=2))
    plan = make_plan(run)
    assert any(len(d.modes) > 1 for d in plan.decisions.values()), \
        "plan must split at least one weight for the prefetch path"

    def losses(overlap):
        built = build_model(run, plan, None, overlap=overlap)
        step_fn, init_fn = make_train_step(built, AdamWConfig(lr=1e-3),
                                           donate=False)
        params, opt = init_fn(jax.random.PRNGKey(0))
        out = []
        for s in range(3):
            k = jax.random.PRNGKey(s)
            batch = {
                "tokens": jax.random.randint(k, (2, 32), 0,
                                             cfg.vocab_size),
                "labels": jax.random.randint(k, (2, 32), 0,
                                             cfg.vocab_size),
            }
            params, opt, m = step_fn(params, opt, batch)
            out.append(float(m["loss"]))
        return out

    base = losses(None)
    over = losses(OverlapConfig(prefetch=1, bucket_bytes=1 << 20))
    assert base == over, (base, over)
