"""Selective per-slice activation checkpointing: the 4-mode axis.

Pins the ISSUE-3 acceptance properties: the selective search dominates
both global checkpointing settings at equal memory limits, at least
one model flips from infeasible(remat-off)/slower(remat-on) to
feasible-and-faster, the legacy fig9 columns stay byte-identical, and
a selective plan compiles to a matching jax.checkpoint policy.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import (DeviceInfo, SINGLE_POD_MESH, OSDPConfig,
                           get_arch, get_shape)
from repro.configs.base import SELECTIVE
from repro.core.cost_model import DP, ZDP, CostEnv, Decision
from repro.core.descriptions import describe
from repro.core.search import schedule

DEV = DeviceInfo()
ENV_ON = CostEnv(DEV, SINGLE_POD_MESH, checkpointing=True)
ENV_OFF = CostEnv(DEV, SINGLE_POD_MESH, checkpointing=False)


def _sched(desc, env, lim_gib, checkpointing, solver="dfs", batches=(256,)):
    return schedule(desc, env, OSDPConfig(
        memory_limit_bytes=lim_gib * 2**30, checkpointing=checkpointing,
        search=solver, operator_splitting=True,
        default_slice_granularity=4, allow_pod_hierarchical=False),
        batch_candidates=batches)


def _thr(res):
    return res.cost.throughput if res.feasible else 0.0


# --- dominance: selective >= max(global on, global off) ---------------------

@pytest.mark.parametrize("solver", ("dfs", "knapsack"))
@pytest.mark.parametrize("model,lim_gib", [
    ("phi4-mini-3.8b", 3), ("phi4-mini-3.8b", 6), ("phi4-mini-3.8b", 12),
    ("mamba2-2.7b", 4), ("mamba2-2.7b", 10),
    ("qwen1.5-0.5b", 2), ("qwen1.5-0.5b", 8),
    ("dbrx-132b", 14),
])
def test_selective_dominates_both_global_settings(model, lim_gib, solver):
    desc = describe(get_arch(model), get_shape("train_4k"))
    t_on = _thr(_sched(desc, ENV_ON, lim_gib, True, solver))
    t_off = _thr(_sched(desc, ENV_OFF, lim_gib, False, solver))
    t_sel = _thr(_sched(desc, ENV_OFF, lim_gib, SELECTIVE, solver))
    assert t_sel >= max(t_on, t_off) * (1 - 1e-9), (
        model, lim_gib, solver, t_on, t_off, t_sel)


def test_infeasible_off_slower_on_flips_to_mixed():
    """The headline: remat-off cannot fit, remat-on merely survives,
    and the mixed plan is feasible AND strictly faster than remat-on —
    with a genuinely mixed remat assignment."""
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    off = _sched(desc, ENV_OFF, 6, False)
    on = _sched(desc, ENV_ON, 6, True)
    sel = _sched(desc, ENV_OFF, 6, SELECTIVE)
    assert not off.feasible
    assert on.feasible and sel.feasible
    assert sel.cost.throughput > on.cost.throughput * (1 + 1e-6)
    n_on = sum(sum(1 for r in (d.remat or ()) if r is True)
               for d in sel.decisions.values())
    n_off = sum(sum(1 for r in (d.remat or ()) if r is False)
                for d in sel.decisions.values())
    assert n_on > 0 and n_off > 0, "expected a genuinely mixed plan"
    assert sel.cost.memory <= 6 * 2**30 * (1 + 1e-9)


def test_selective_remat_benchmark_rows():
    """benchmarks/selective_remat.py on a reduced sweep: dominance on
    every row and at least one flip (full sweep asserts internally)."""
    from benchmarks.selective_remat import main
    rows = main(out=lambda *_: None,
                models=("mamba2-2.7b",), limits=(4, 10, 14))
    assert any(r["flip"] for r in rows)
    for r in rows:
        assert r["selective"] >= max(r["on"], r["off"]) * (1 - 1e-9), r


# --- legacy fig9 columns stay byte-identical --------------------------------

def test_fig9_legacy_row_byte_identical():
    """One pinned fig9 row (nd-48x1024 @ 8 GiB), computed with the
    exact configs benchmarks/fig9_checkpointing.py uses: the printed
    FSDP_ckpt / OSDP_ckpt / speedup fields must reproduce the pre-
    selective-remat engine's output byte for byte."""
    from benchmarks.fig9_checkpointing import BATCHES
    from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8, \
        paper_shape
    from repro.core.descriptions import describe as _describe  # noqa: F401
    from benchmarks.paper_models import nd_ws_description, _gpt
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=True)
    desc = nd_ws_description(_gpt("nd-48x1024", 48, 1024),
                             paper_shape(8))
    lim = 8 * 2**30
    fsdp = schedule(desc, env, OSDPConfig(
        force_mode="ZDP", memory_limit_bytes=lim,
        operator_splitting=False, allow_pod_hierarchical=False,
        checkpointing=True), batch_candidates=BATCHES)
    osdp = schedule(desc, env, OSDPConfig(
        memory_limit_bytes=lim, operator_splitting=True,
        default_slice_granularity=4, allow_pod_hierarchical=False,
        checkpointing=True), batch_candidates=BATCHES)
    t_f = fsdp.cost.throughput if fsdp.feasible else 0.0
    t_o = osdp.cost.throughput if osdp.feasible else 0.0
    row = f"{t_f:.0f},{t_o:.0f},{(t_o / t_f - 1) * 100:.1f}"
    assert row == "27552,28097,2.0"   # pre-PR golden, PR 3


# --- plan -> program: the jax.checkpoint policy -----------------------------

def test_selective_plan_compiles_to_checkpoint_policy():
    import jax
    from conftest import make_batch, tiny_run
    from repro.core.plan import Plan
    from repro.models.registry import build_model
    from repro.optim import AdamWConfig
    from repro.train.loop import make_train_step

    run = tiny_run("phi4-mini-3.8b")
    run = dataclasses.replace(run, osdp=dataclasses.replace(
        run.osdp, checkpointing=SELECTIVE))
    decs = {
        "layers.ffn_w13": Decision("layers.ffn_w13", (DP, DP),
                                   (True, True)),
        "layers.ffn_w2": Decision("layers.ffn_w2", (DP,), (True,)),
        "layers.attn_qkv": Decision("layers.attn_qkv", (DP,), (False,)),
        "layers.attn_out": Decision("layers.attn_out", (ZDP, DP),
                                    (False, True)),
    }
    built = build_model(run, Plan(run, None, decs, None, None))
    # mixed plan -> a save-list policy naming the kept activations
    assert isinstance(built.model.remat, tuple)
    assert "layers/attn/wq" in built.model.remat
    assert "layers/ffn/w13" not in built.model.remat
    step_fn, init_fn = make_train_step(built, AdamWConfig(lr=1e-3),
                                       donate=False)
    params, opt = init_fn(jax.random.PRNGKey(0))
    _, _, metrics = step_fn(params, opt, make_batch(run.model, 2, 64))
    assert np.isfinite(float(metrics["loss"]))

    # uniform-keep plan -> no checkpoint at all
    keep = {k: Decision(k, d.modes, (False,) * len(d.modes))
            for k, d in decs.items()}
    assert build_model(run, Plan(run, None, keep, None, None)
                       ).model.remat is False
    # legacy plan (no explicit bits) -> the global flag
    legacy = {k: Decision(k, d.modes) for k, d in decs.items()}
    run_on = dataclasses.replace(run, osdp=dataclasses.replace(
        run.osdp, checkpointing=True))
    assert build_model(run_on, Plan(run_on, None, legacy, None, None)
                       ).model.remat is True


def test_truthy_checkpointing_keeps_legacy_remat():
    """checkpointing accepted any truthy value when it was a plain
    bool field — 1 must still mean 'global remat on', not silently
    flip to no-remat."""
    cfg = OSDPConfig(checkpointing=1)
    assert cfg.env_checkpointing is True and not cfg.selective_remat
    assert OSDPConfig(checkpointing=0).env_checkpointing is False
    assert OSDPConfig(checkpointing=SELECTIVE).env_checkpointing is False
    # ...all the way through to the compiled model and the summary
    from conftest import tiny_run
    from repro.core.plan import remat_summary
    from repro.models.registry import build_model
    run = tiny_run("qwen1.5-0.5b")
    run = dataclasses.replace(run, osdp=dataclasses.replace(
        run.osdp, checkpointing=1))
    assert build_model(run).model.remat is True
    assert remat_summary({}, run.osdp) == "global on"


def test_force_mode_rejects_selective():
    """force_mode bypasses the search, so there is no remat axis to
    decide — the combination must error loudly, not silently degrade
    to a global no-remat plan."""
    with pytest.raises(ValueError, match="force_mode"):
        OSDPConfig(checkpointing=SELECTIVE, force_mode="ZDP")


def test_misspelled_selective_rejected():
    """Any string other than "selective" would silently fall back to
    the legacy global engine — reject it instead."""
    with pytest.raises(ValueError, match="checkpointing"):
        OSDPConfig(checkpointing="Selective")


def test_plan_summary_reports_remat():
    from repro.core.api import osdp as osdp_api
    plan = osdp_api(get_arch("qwen1.5-0.5b"), get_shape("train_4k"),
                    SINGLE_POD_MESH, memory_limit_gib=2.0,
                    checkpointing=SELECTIVE)
    assert "remat" in plan.summary()
    assert plan.search is not None and plan.search.feasible
