"""PlanEvaluator equivalence: the vectorized/incremental fast path must
return the same PlanCost (1e-9 rel) and the same argmin decisions as
the direct `plan_cost` path — for arbitrary mixed plans, for O(1) flip
sequences, and end-to-end through all three solvers (the pre-PR2
search_plan semantics are replicated here as the golden reference)."""
import random

import numpy as np
import pytest

from repro.configs import (DeviceInfo, MULTI_POD_MESH, SINGLE_POD_MESH,
                           OSDPConfig, get_arch, get_shape)
from repro.core.cost_model import (DP, MODES, ZDP, ZDP_POD, CostEnv,
                                   Decision, PlanEvaluator, plan_cost,
                                   uniform_plan)
from repro.core.descriptions import describe
from repro.core.search import (_build_items, _items_to_decisions,
                               _solve_dfs, _solve_greedy, _solve_knapsack,
                               search_plan)

MODELS = ("phi4-mini-3.8b", "dbrx-132b", "mamba2-2.7b")
ENVS = {
    "single_pod": CostEnv(DeviceInfo(), SINGLE_POD_MESH),
    "multi_pod": CostEnv(DeviceInfo(), MULTI_POD_MESH),
    "serve": CostEnv(DeviceInfo(), SINGLE_POD_MESH, train=False),
    "no_ckpt": CostEnv(DeviceInfo(), SINGLE_POD_MESH, checkpointing=False),
}


def _random_plan(desc, rng, modes):
    """Mixed split/unsplit decisions over random modes."""
    decs = {}
    for op in desc.operators:
        if not op.decidable:
            decs[op.name] = Decision(op.name, (DP,))
            continue
        g = rng.choice([1, 2, 4]) if op.splittable else 1
        decs[op.name] = Decision(
            op.name, tuple(rng.choice(modes) for _ in range(g)))
    return decs


def _assert_cost_equal(got, want, where=""):
    for f in ("memory", "peak_memory", "time", "comm_time",
              "compute_time", "throughput"):
        g, w = getattr(got, f), getattr(want, f)
        assert g == pytest.approx(w, rel=1e-9, abs=1e-12), (where, f, g, w)


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("env_name", sorted(ENVS))
def test_evaluator_matches_plan_cost_on_mixed_plans(model, env_name):
    desc = describe(get_arch(model), get_shape("train_4k"))
    env = ENVS[env_name]
    modes = ("DP", "ZDP", "ZDP_POD") if env.mesh.multi_pod \
        else ("DP", "ZDP")
    rng = random.Random(hash((model, env_name)) & 0xFFFF)
    for trial in range(5):
        decs = _random_plan(desc, rng, modes)
        for batch in (16, 256, 1024):
            want = plan_cost(desc, decs, batch, env)
            ev = PlanEvaluator.for_decisions(desc, env, decs)
            got = ev.plan_cost(ev.modes_from_decisions(decs), batch)
            _assert_cost_equal(got, want, f"{model}/{env_name}/b{batch}")


@pytest.mark.parametrize("model", MODELS)
def test_incremental_flips_match_full_evaluation(model):
    """begin() + a random flip sequence must track plan_cost exactly —
    the repair loop's O(1) delta updates cannot drift."""
    desc = describe(get_arch(model), get_shape("train_4k"))
    env = ENVS["multi_pod"]
    rng = random.Random(7)
    gran = {op.name: (4 if op.splittable else 1)
            for op in desc.decidable()}
    ev = PlanEvaluator(desc, env, gran)
    ev.begin(np.zeros(ev.n_slices, dtype=np.int8), 256)
    for step in range(200):
        j = rng.randrange(ev.n_slices)
        k = int(ev.slice_op[j])
        if not desc.operators[k].decidable:
            continue
        ev.flip(j, rng.randrange(len(MODES)))
        if step % 20 == 0:
            want = plan_cost(desc, ev.decisions(ev.current_modes), 256, env)
            _assert_cost_equal(ev.result(), want, f"{model}/step{step}")
    want = plan_cost(desc, ev.decisions(ev.current_modes), 256, env)
    _assert_cost_equal(ev.result(), want, f"{model}/final")


def test_evaluate_plan_accepts_any_plan_cost_plan():
    """The public one-call wrap must score every plan plan_cost scores —
    including split decisions on non-decidable operators."""
    from repro.core.api import evaluate_plan
    model = get_arch("phi4-mini-3.8b")
    desc = describe(model, get_shape("train_4k"))
    decs = {"final_norm": Decision("final_norm", (ZDP, ZDP))}
    want = plan_cost(desc, decs, 256, ENVS["single_pod"])
    got = evaluate_plan(model, decs, get_shape("train_4k"),
                        SINGLE_POD_MESH, global_batch=256)
    _assert_cost_equal(got, want, "evaluate_plan")


def test_all_dp_memory_matches_base_cost():
    for model in MODELS:
        desc = describe(get_arch(model), get_shape("train_4k"))
        env = ENVS["single_pod"]
        ev = PlanEvaluator(desc, env)
        for batch in (16, 256):
            want = plan_cost(desc, uniform_plan(desc, DP), batch, env)
            assert ev.all_dp_memory(batch) == pytest.approx(
                want.memory, rel=1e-9)


# --- end-to-end golden reference: the pre-optimization search_plan ----------

def _reference_search_plan(desc, global_batch, env, osdp):
    """The pre-PR2 search_plan: direct plan_cost evaluation everywhere,
    full O(slices * ops) re-evaluation per repair flip."""
    items = _build_items(desc, env, osdp)
    base = plan_cost(desc, uniform_plan(desc, DP), global_batch, env)
    need = base.memory - osdp.memory_limit_bytes
    if osdp.search == "dfs":
        choice, _ = _solve_dfs(items, need)
    elif osdp.search == "knapsack":
        choice, _ = _solve_knapsack(items, need)
    else:
        choice, _ = _solve_greedy(items, need)
    choice = list(choice)
    decisions = _items_to_decisions(desc, items, choice)
    cost = plan_cost(desc, decisions, global_batch, env)
    if cost.memory > osdp.memory_limit_bytes:
        remaining = sorted(
            (i for i, c in enumerate(choice) if c is None),
            key=lambda i: min(items[i].extra_time[m]
                              / max(items[i].savings[m], 1e-9)
                              for m in items[i].savings))
        for i in remaining:
            it = items[i]
            choice[i] = min(it.savings,
                            key=lambda m: it.extra_time[m]
                            / max(it.savings[m], 1e-9))
            decisions = _items_to_decisions(desc, items, choice)
            cost = plan_cost(desc, decisions, global_batch, env)
            if cost.memory <= osdp.memory_limit_bytes:
                break
        if cost.memory > osdp.memory_limit_bytes:
            choice = [max(it.savings, key=it.savings.get) for it in items]
            decisions = _items_to_decisions(desc, items, choice)
            cost = plan_cost(desc, decisions, global_batch, env)
    return decisions, cost


# memory limits chosen so each (model, limit) lands in a different
# regime: comfortable, repair-triggering tight, and infeasible-fallback
CASES = [
    ("phi4-mini-3.8b", 64), ("phi4-mini-3.8b", 16), ("phi4-mini-3.8b", 1),
    ("dbrx-132b", 32), ("dbrx-132b", 12),
    ("mamba2-2.7b", 8), ("mamba2-2.7b", 2),
]


@pytest.mark.parametrize("solver", ("dfs", "knapsack", "greedy"))
@pytest.mark.parametrize("model,limit_gib", CASES)
def test_solvers_match_reference_path(solver, model, limit_gib):
    desc = describe(get_arch(model), get_shape("train_4k"))
    env = ENVS["single_pod"]
    osdp = OSDPConfig(search=solver,
                      memory_limit_bytes=limit_gib * 2**30,
                      operator_splitting=True,
                      default_slice_granularity=4)
    want_dec, want_cost = _reference_search_plan(desc, 256, env, osdp)
    got = search_plan(desc, 256, env, osdp)
    assert got.decisions == want_dec, (model, limit_gib, solver)
    _assert_cost_equal(got.cost, want_cost, f"{model}/{limit_gib}/{solver}")
    assert got.feasible == (want_cost.memory <= osdp.memory_limit_bytes)


@pytest.mark.parametrize("solver", ("dfs", "knapsack", "greedy"))
def test_solvers_match_reference_multi_pod(solver):
    """ZDP_POD adds a second mode per item — the grouped DFS and the
    vectorized knapsack must still mirror the reference exactly."""
    desc = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
    env = ENVS["multi_pod"]
    osdp = OSDPConfig(search=solver, memory_limit_bytes=24 * 2**30,
                      operator_splitting=True,
                      default_slice_granularity=4)
    want_dec, want_cost = _reference_search_plan(desc, 256, env, osdp)
    got = search_plan(desc, 256, env, osdp)
    assert got.decisions == want_dec
    _assert_cost_equal(got.cost, want_cost, f"multi_pod/{solver}")


def test_knapsack_matches_scalar_reference():
    """Vectorized DP == the scalar list-of-lists DP, choice-for-choice."""
    from repro.core.search import SliceItem

    def scalar_knapsack(items, need, quantum):
        n = len(items)
        if need <= 0:
            return [None] * n
        cap = int(-(-need // quantum))
        INF = float("inf")
        dp = [INF] * (cap + 1)
        dp[0] = 0.0
        parent = [[None] * (cap + 1) for _ in range(n + 1)]
        for i, it in enumerate(items):
            ndp = dp[:]
            npar = [None] * (cap + 1)
            for m, sav in it.savings.items():
                q = int(sav // quantum)
                if q == 0:
                    continue
                t = it.extra_time[m]
                for s in range(cap + 1):
                    if dp[s] == INF:
                        continue
                    s2 = min(cap, s + q)
                    if dp[s] + t < ndp[s2]:
                        ndp[s2] = dp[s] + t
                        npar[s2] = (s, m)
            dp = ndp
            parent[i + 1] = npar
        if dp[cap] == INF:
            return [max(it.savings, key=it.savings.get) for it in items]
        choice = [None] * n
        s = cap
        for i in range(n, 0, -1):
            p = parent[i][s]
            if p is not None:
                s, m = p
                choice[i - 1] = m
        return choice

    rng = random.Random(3)
    for trial in range(20):
        n = rng.randrange(3, 30)
        two_modes = rng.random() < 0.5
        items = []
        for i in range(n):
            sav = {ZDP: rng.uniform(0, 100)}
            ext = {ZDP: rng.uniform(0.0, 10.0)}
            if two_modes:
                sav[ZDP_POD] = rng.uniform(0, 100)
                ext[ZDP_POD] = rng.uniform(0.0, 10.0)
            items.append(SliceItem(f"op{i}", 0, 1, sav, ext))
        total = sum(max(it.savings.values()) for it in items)
        need = rng.uniform(0.1, 1.2) * total
        quantum = total / rng.choice([64, 256, 1024])
        want = scalar_knapsack(items, need, quantum)
        got, cells = _solve_knapsack(items, need, quantum)
        assert got == want, (trial, need, quantum)
        assert cells >= 0


def test_grouped_dfs_exact_with_duplicate_items():
    """Per-layer descriptions collapse into signature groups — the
    grouped branch-and-bound must still match brute force."""
    import itertools
    import math
    from repro.core.search import SliceItem

    rng = random.Random(11)
    for trial in range(10):
        # few distinct signatures, many copies — like per-layer models
        sigs = [(rng.uniform(1, 50), rng.uniform(0.01, 5.0))
                for _ in range(rng.randrange(2, 4))]
        items = []
        for i in range(12):
            sav, ext = sigs[rng.randrange(len(sigs))]
            items.append(SliceItem(f"op{i}", 0, 1, {ZDP: sav}, {ZDP: ext}))
        total = sum(it.savings[ZDP] for it in items)
        need = rng.uniform(0.2, 0.95) * total
        choice, nodes = _solve_dfs(items, need)
        t = sum(items[i].extra_time[c] for i, c in enumerate(choice) if c)
        sav = sum(items[i].savings[c] for i, c in enumerate(choice) if c)
        assert sav >= need - 1e-9
        best = math.inf
        for mask in range(1 << len(items)):
            s = sum(items[i].savings[ZDP] for i in range(len(items))
                    if mask >> i & 1)
            if s < need:
                continue
            tt = sum(items[i].extra_time[ZDP] for i in range(len(items))
                     if mask >> i & 1)
            best = min(best, tt)
        assert t == pytest.approx(best, rel=1e-9), trial
        assert nodes > 0


# --- the 4-mode axis: per-slice remat (selective checkpointing) -------------

def _random_remat(rng, g):
    """Random explicit/inherit remat tuple (None = all inherit)."""
    if rng.random() < 0.3:
        return None
    return tuple(rng.choice([True, False, None]) for _ in range(g))


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("env_name", sorted(ENVS))
def test_evaluator_matches_plan_cost_with_remat_bits(model, env_name):
    """4-mode plans (sharding x explicit remat per slice) must evaluate
    identically through the table path and the direct op_cost walk."""
    desc = describe(get_arch(model), get_shape("train_4k"))
    env = ENVS[env_name]
    modes = ("DP", "ZDP", "ZDP_POD") if env.mesh.multi_pod \
        else ("DP", "ZDP")
    rng = random.Random(hash((model, env_name, "remat")) & 0xFFFF)
    for trial in range(5):
        decs = {}
        for op in desc.operators:
            g = rng.choice([1, 2, 4]) if op.splittable else 1
            decs[op.name] = Decision(
                op.name, tuple(rng.choice(modes) for _ in range(g)),
                _random_remat(rng, g))
        for batch in (16, 256, 1024):
            want = plan_cost(desc, decs, batch, env)
            ev = PlanEvaluator.for_decisions(desc, env, decs)
            got = ev.plan_cost(ev.modes_from_decisions(decs), batch)
            _assert_cost_equal(got, want,
                               f"{model}/{env_name}/b{batch}/remat")


@pytest.mark.parametrize("model", MODELS)
def test_remat_flip_deltas_match_full_evaluation(model):
    """O(1) flips across all 9 extended columns (sharding x remat
    state) must track the direct evaluation exactly."""
    from repro.core.cost_model import N_EXT
    desc = describe(get_arch(model), get_shape("train_4k"))
    env = ENVS["multi_pod"]
    rng = random.Random(23)
    gran = {op.name: (4 if op.splittable else 1)
            for op in desc.decidable()}
    ev = PlanEvaluator(desc, env, gran)
    ev.begin(np.zeros(ev.n_slices, dtype=np.int8), 256)
    for step in range(300):
        ev.flip(rng.randrange(ev.n_slices), rng.randrange(N_EXT))
        if step % 25 == 0:
            want = plan_cost(desc, ev.decisions(ev.current_modes), 256,
                             env)
            _assert_cost_equal(ev.result(), want, f"{model}/step{step}")
    want = plan_cost(desc, ev.decisions(ev.current_modes), 256, env)
    _assert_cost_equal(ev.result(), want, f"{model}/final")


def test_extended_modes_round_trip():
    from repro.core.cost_model import N_EXT
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    ev = PlanEvaluator(desc, ENVS["single_pod"],
                       {op.name: (4 if op.splittable else 1)
                        for op in desc.decidable()})
    rng = random.Random(5)
    m = np.array([rng.randrange(N_EXT) for _ in range(ev.n_slices)],
                 dtype=np.int8)
    assert (ev.modes_from_decisions(ev.decisions(m)) == m).all()


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("flag", [True, False])
def test_forced_uniform_explicit_remat_matches_legacy_flag(model, flag):
    """On stacked descriptions, a plan with explicit uniform remat bits
    must cost exactly what the legacy global CostEnv.checkpointing flag
    gives (the pre-PR Profiler), decision layout unchanged — the global
    settings stay expressible inside the 4-mode axis."""
    desc = describe(get_arch(model), get_shape("train_4k"))
    env_legacy = CostEnv(DeviceInfo(), SINGLE_POD_MESH, checkpointing=flag)
    rng = random.Random(hash((model, flag)) & 0xFFFF)
    for trial in range(5):
        legacy = _random_plan(desc, rng, ("DP", "ZDP"))
        explicit = {name: Decision(name, d.modes,
                                   (flag,) * len(d.modes))
                    for name, d in legacy.items()}
        for batch in (16, 256):
            want = plan_cost(desc, legacy, batch, env_legacy)
            # explicit bits are env-independent: evaluate them under
            # the OPPOSITE env default to prove nothing leaks through
            env_other = CostEnv(DeviceInfo(), SINGLE_POD_MESH,
                                checkpointing=not flag)
            got = plan_cost(desc, explicit, batch, env_other)
            _assert_cost_equal(got, want, f"{model}/{flag}/b{batch}")


@pytest.mark.parametrize("solver", ("dfs", "knapsack", "greedy"))
def test_legacy_bool_configs_decisions_unchanged(solver):
    """checkpointing=True/False searches must return remat-free
    decisions (remat inherited from the env flag), exactly as pre-PR."""
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    for flag in (True, False):
        env = CostEnv(DeviceInfo(), SINGLE_POD_MESH, checkpointing=flag)
        res = search_plan(desc, 256, env, OSDPConfig(
            search=solver, memory_limit_bytes=8 * 2**30,
            checkpointing=flag))
        assert all(d.remat is None for d in res.decisions.values())


def test_solver_effort_is_reported():
    """nodes_visited: dfs = nodes expanded, knapsack = cells relaxed,
    greedy = items ranked — all populated for the bench JSON."""
    desc = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"))
    env = ENVS["single_pod"]
    for solver in ("dfs", "knapsack", "greedy"):
        # 4 GiB: below the all-DP footprint, so every solver must work
        res = search_plan(desc, 256, env, OSDPConfig(
            search=solver, memory_limit_bytes=4 * 2**30))
        assert res.nodes_visited > 0, solver
