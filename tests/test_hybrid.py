"""Hybrid 3D(+OSDP) search: factorization sweep, TP/PP cost terms,
HybridPlan plumbing, and the paper's 3D+OSDP >= 3D claim."""
import math

import pytest

from repro.configs import DeviceInfo, MeshConfig, OSDPConfig, ShapeConfig
from repro.configs.base import DENSE, ModelConfig
from repro.core.cost_model import DP, ZDP, CostEnv
from repro.core.descriptions import describe
from repro.core.hybrid import (Factorization, HybridPlan, factorizations,
                               hybrid_step_time, pp_bubble_fraction,
                               slice_description, stage_bounds,
                               tp_activation_time)
from repro.core.search import search_hybrid as search_hybrid_core
from repro.sharding.specs import (WeightSpec, stage_of_layer,
                                  stage_weight_specs)

# the paper's Fig. 5 environment: one server, 8 RTX TITAN over PCIe3
# (mirrors benchmarks/paper_models.py, which tests cannot import)
RTX_TITAN_8 = DeviceInfo(
    name="8x-rtx-titan-pcie3", peak_flops=65e12, hbm_bytes=24 * 2**30,
    hbm_bw=672e9, ici_bw=12e9, dci_bw=12e9, alpha=5e-6,
    mxu_efficiency=0.45)


def _gpt(name, layers, hidden):
    heads = max(8, hidden // 64)
    return ModelConfig(
        name=name, family=DENSE, n_layers=layers, d_model=hidden,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * hidden,
        vocab_size=50257, act="gelu", norm="layernorm", rope="none",
        tie_embeddings=True)


ND_48 = _gpt("nd-48x1024", 48, 1024)
PAPER_SHAPE = ShapeConfig("paper_b64", 1024, 64, "train")


def paper_desc(cfg=ND_48):
    return describe(cfg, PAPER_SHAPE, per_layer=True)


# --- factorization enumeration ------------------------------------------------

@pytest.mark.parametrize("n", [8, 16])
def test_factorizations_exhaustive(n):
    """Every (dp, tp, pp) with dp*tp*pp == n appears exactly once."""
    got = {(f.dp, f.tp, f.pp) for f in factorizations(n)}
    want = {(dp, tp, pp)
            for dp in range(1, n + 1)
            for tp in range(1, n + 1)
            for pp in range(1, n + 1)
            if dp * tp * pp == n}
    assert got == want
    assert len(factorizations(n)) == len(got)   # no duplicates
    assert (n, 1, 1) in got                     # pure DP is a legal point


def test_factorizations_caps():
    fs = factorizations(16, max_tp=4, max_pp=2)
    assert fs and all(f.tp <= 4 and f.pp <= 2 for f in fs)
    assert all(f.n_devices == 16 for f in fs)


def test_factorization_mesh_config():
    cfg = Factorization(4, 2, 2).mesh_config()
    assert cfg.shape == (4, 2, 2)
    assert cfg.axes == ("data", "model", "pipe")
    assert cfg.data_parallel == 4
    assert cfg.model_parallel == 2
    assert cfg.pipeline_parallel == 2
    assert cfg.n_devices == 16


# --- cost-term building blocks ------------------------------------------------

def test_stage_bounds_partition_layers():
    for L, pp in ((48, 4), (7, 3), (2, 8), (1, 1)):
        b = stage_bounds(L, pp)
        assert b[0] == 0 and b[-1] == L
        assert list(b) == sorted(b)
        assert len(b) == min(pp, L) + 1          # pp clamped to L
        sizes = [b[i + 1] - b[i] for i in range(len(b) - 1)]
        assert max(sizes) - min(sizes) <= 1       # near-equal stages


def test_slice_description_scales_residue():
    desc = paper_desc()
    sub = slice_description(desc, tp=2, pp=4)
    assert sub.total_params == pytest.approx(desc.total_params / 8, rel=0.01)
    assert sub.resident_act_bytes_per_token == pytest.approx(
        desc.resident_act_bytes_per_token / 8)
    assert slice_description(desc, 1, 1) is desc


def test_tp_pp_terms_zero_when_trivial():
    desc = paper_desc()
    assert tp_activation_time(desc, RTX_TITAN_8, 8, tp=1) == 0.0
    assert pp_bubble_fraction(1, 8) == 0.0
    assert hybrid_step_time(1.0, desc, RTX_TITAN_8, 64,
                            Factorization(8, 1, 1)) == 1.0


def test_hybrid_step_time_monotone_in_bubble():
    desc = paper_desc()
    t2 = hybrid_step_time(1.0, desc, RTX_TITAN_8, 64, Factorization(4, 1, 2))
    t4 = hybrid_step_time(1.0, desc, RTX_TITAN_8, 64, Factorization(2, 1, 4))
    assert 1.0 < t2 < t4


# --- search_hybrid ------------------------------------------------------------

def _run(force_mode=None, mem_gib=16.0, cfg=ND_48, n_dev=8, **kw):
    osdp = OSDPConfig(memory_limit_bytes=mem_gib * 2**30,
                      operator_splitting=force_mode is None,
                      allow_pod_hierarchical=False,
                      checkpointing=False, force_mode=force_mode)
    return search_hybrid_core(paper_desc(cfg), RTX_TITAN_8, n_dev, osdp,
                              batch_candidates=[64], **kw)


@pytest.mark.parametrize("mem_gib", [8.0, 16.0, 24.0])
def test_search_hybrid_respects_memory_limit(mem_gib):
    plan = _run(mem_gib=mem_gib)
    assert isinstance(plan, HybridPlan)
    if plan.feasible:
        assert plan.cost.memory <= mem_gib * 2**30
    assert plan.factorization.n_devices == 8


def test_search_hybrid_3d_osdp_beats_plain_3d():
    """The paper's headline: replacing the DP dimension of 3D with the
    OSDP search never loses (Fig. 5/6 3D vs 3D+OSDP rows)."""
    osdp = _run()
    plain = _run(force_mode="ZDP")
    assert osdp.feasible and plain.feasible
    assert osdp.cost.throughput >= plain.cost.throughput * (1 - 1e-9)


def test_search_hybrid_matches_or_beats_seed_rows():
    """The analytical script this search replaced (benchmarks/hybrid_3d.py
    at the seed commit) reported these best 3D+OSDP rows; the unified
    search must match or beat them on the same inputs."""
    seed_rows = {"nd-48x1024": 46059.0, "nd-64x1280": 21691.0}
    for name, seed_thr in seed_rows.items():
        layers, hidden = name.split("-")[1].split("x")
        plan = _run(cfg=_gpt(name, int(layers), int(hidden)))
        assert plan.feasible, name
        # the seed CSV printed tokens/s rounded to integers: allow the
        # half-token slack that rounding introduced
        assert plan.cost.throughput >= seed_thr - 0.5, (
            f"{name}: {plan.cost.throughput:.0f} < seed {seed_thr:.0f}")


def test_search_hybrid_infeasible_forced_factorization():
    """pp > n_layers is inadmissible: reported infeasible, not raised."""
    plan = _run(cfg=_gpt("ws-2x6144", 2, 6144),
                candidates=[Factorization(1, 1, 8)])
    assert not plan.feasible
    assert plan.cost.throughput == 0.0


def test_search_hybrid_sweep_is_recorded():
    plan = _run()
    assert plan.swept, "no feasible sweep points recorded"
    best = max(thr for _, thr in plan.swept)
    assert plan.cost.throughput == pytest.approx(best)
    # one entry per factorization (split/no-split deduped)
    fs = [f for f, _ in plan.swept]
    assert len(fs) == len(set(fs))


def test_api_entry_point_returns_hybrid_plan():
    from repro.core.api import search_hybrid
    plan = search_hybrid(ND_48, PAPER_SHAPE, n_devices=8,
                         device=RTX_TITAN_8, memory_limit_gib=16.0,
                         checkpointing=False, batch_candidates=[64])
    assert isinstance(plan, HybridPlan)
    assert plan.feasible
    assert "hybrid[" in plan.summary()
    assert all(isinstance(d.modes, tuple)
               for d in plan.decisions.values())


# --- stage-level sharding plumbing -------------------------------------------

def test_stage_of_layer():
    b = stage_bounds(48, 4)
    assert stage_of_layer(0, b) == 0
    assert stage_of_layer(47, b) == 3
    with pytest.raises(ValueError):
        stage_of_layer(48, b)


def test_stage_weight_specs_slices_stacked():
    specs = [
        WeightSpec("embed/tok", (512, 64), "embed.tok"),
        WeightSpec("layers/ffn/w13", (48, 64, 256), "layers.ffn_w13",
                   zdp_axis=2, stacked=True),
        WeightSpec("head/out", (64, 512), "head.out"),
    ]
    b = stage_bounds(48, 4)
    per_stage = [stage_weight_specs(specs, b, s) for s in range(4)]
    # embeddings first stage, head last, stacked split 12 layers each
    assert [s.path for s in per_stage[0]] == ["embed/tok", "layers/ffn/w13"]
    assert [s.path for s in per_stage[3]] == ["layers/ffn/w13", "head/out"]
    for stage in per_stage:
        stacked = [s for s in stage if s.stacked]
        assert stacked[0].shape == (12, 64, 256)
    total = sum(s.shape[0] for stage in per_stage
                for s in stage if s.stacked)
    assert total == 48


def test_stage_weight_specs_tied_embeddings():
    """Tied-embedding models have no head weight: the embedding must
    also land on the last stage to project logits there."""
    specs = [
        WeightSpec("embed/tok", (512, 64), "embed.tok"),
        WeightSpec("layers/ffn/w13", (48, 64, 256), "layers.ffn_w13",
                   zdp_axis=2, stacked=True),
        WeightSpec("final_norm", (64,), "final_norm"),
    ]
    b = stage_bounds(48, 4)
    first = stage_weight_specs(specs, b, 0)
    last = stage_weight_specs(specs, b, 3)
    assert "embed/tok" in [s.path for s in first]
    assert "embed/tok" in [s.path for s in last]
    assert "final_norm" in [s.path for s in last]
    # pp=1: single stage holds everything exactly once
    one = stage_weight_specs(specs, stage_bounds(48, 1), 0)
    assert [s.path for s in one] == ["embed/tok", "layers/ffn/w13",
                                    "final_norm"]
