"""Pin the roofline HLO text parsers (ISSUE 10 satellite).

`roofline/analysis.py` and `roofline/probe.py` scrape post-
optimization HLO dumps with regexes; the perf loop and the dryrun
reports depend on exactly what those regexes count.  These tests pin
them against hand-written HLO fixtures: the collective census (incl.
`-start` async forms and the largest-tensor-per-line rule), the
while-body scope heuristic, the top-k buffer ranking, opcode counts,
and the unknown-dtype -> 0 bytes fallback.
"""
import pytest

from repro.roofline.analysis import (_tensor_bytes, analyze_lowered,
                                     hlo_flops_bytes, roofline)
from repro.roofline.probe import (collectives_by_scope, count_op,
                                  largest_tensors)

# A hand-written post-optimization-style HLO dump.  Layout annotations
# ({1,0}), async -start forms, a while body computation, a comment
# line, and an unknown dtype are all represented.
HLO = """\
HloModule pinned_fixture

%wide.body.1 (p: (f32[64,128], s32[])) -> (f32[64,128], s32[]) {
  %p = (f32[64,128], s32[]) parameter(0)
  %w = f32[64,128]{1,0} get-tuple-element((f32[64,128], s32[]) %p), index=0
  %ag = bf16[16,256]{1,0} all-gather(bf16[8,256]{1,0} %w2), dimensions={0}
  %ar-start = f32[128,128] all-reduce-start(f32[128,128] %w3), to_apply=%sum
  %ar-done = f32[128,128] all-reduce-done(f32[128,128] %ar-start)
  %mm = f32[64,128] dot(f32[64,64] %a, f32[64,128] %b)
}

%cond.2 (p: (f32[64,128], s32[])) -> pred[] {
  %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

ENTRY %main.3 (x: f32[1024,1024]) -> f32[] {
  // %ghost = f32[9999,9999] all-reduce(f32[9999,9999] %nope)
  %big = f32[1024,1024]{1,0} broadcast(f32[] %c), dimensions={}
  %rs = f32[512,128] reduce-scatter(f32[1024,128] %z), dimensions={0}
  %a2a = u8[4,1000] all-to-all(u8[4,1000] %q), dimensions={0}
  %mystery = q4[4096,4096] all-gather(q4[2048,4096] %m), dimensions={0}
  %mm2 = f32[1024,1024] dot(f32[1024,1024] %x, f32[1024,1024] %y)
}
"""

B_AG = 2 * 16 * 256          # bf16[16,256], the largest tensor on its line
B_AR = 4 * 128 * 128         # f32[128,128] (the -start form)
B_RS = 4 * 1024 * 128        # operand f32[1024,128] beats result f32[512,128]
B_A2A = 1 * 4 * 1000         # u8[4,1000]


def test_tensor_bytes_table_and_unknown_dtype():
    assert _tensor_bytes("f32", "8,16") == 4 * 128
    assert _tensor_bytes("bf16", "3") == 6
    assert _tensor_bytes("pred", "7") == 7
    assert _tensor_bytes("f32", "") == 4          # scalar
    assert _tensor_bytes("q4", "4096,4096") == 0  # unknown dtype -> 0


def test_analyze_lowered_census():
    census = analyze_lowered(HLO)
    # NOTE: the census is line-oriented and does NOT skip // comments
    # (post-opt dumps don't carry them inside computations); the
    # commented all-reduce in ENTRY is therefore counted by design —
    # probe.largest_tensors is the comment-aware parser.
    assert census["all-gather"]["count"] == 2     # real + unknown-dtype
    assert census["all-gather"]["bytes"] == B_AG  # q4 falls back to 0
    assert census["all-reduce"]["count"] == 2     # -start + commented
    assert census["all-reduce"]["bytes"] == B_AR + 4 * 9999 * 9999
    assert census["reduce-scatter"]["count"] == 1
    assert census["reduce-scatter"]["bytes"] == B_RS
    assert census["all-to-all"]["bytes"] == B_A2A
    assert "collective-permute" not in census     # zero-count kinds dropped
    assert census["total_bytes"] == (B_AG + B_AR + 4 * 9999 * 9999
                                     + B_RS + B_A2A)


def test_analyze_lowered_counts_start_not_done():
    # the async pair must be counted once: `all-reduce-start` matches
    # (with the -start suffix group), `all-reduce-done` must not
    one = ("%s = f32[8] all-reduce-start(f32[8] %x)\n"
           "%d = f32[8] all-reduce-done(f32[8] %s)\n")
    census = analyze_lowered(one)
    assert census["all-reduce"]["count"] == 1
    assert census["all-reduce"]["bytes"] == 32.0


def test_collectives_by_scope_while_heuristic():
    scopes = collectives_by_scope(HLO)
    # %wide.body.1 contains 'body' -> its all-gather + all-reduce-start
    # land in_loop; ENTRY's reduce-scatter / all-to-all / unknown-dtype
    # all-gather (0 bytes) and the commented all-reduce are top_level
    assert scopes["in_loop"]["count"] == 2
    assert scopes["in_loop"]["bytes"] == B_AG + B_AR
    assert scopes["top_level"]["count"] == 4
    assert scopes["top_level"]["bytes"] == (B_RS + B_A2A
                                            + 4 * 9999 * 9999)


def test_collectives_by_scope_scan_and_while_names():
    for name in ("%while_body.7", "%scan_loop.2", "%region_body.9"):
        hlo = (f"{name} (p: f32[4]) -> f32[4] {{\n"
               f"  %ar = f32[4] all-reduce(f32[4] %x)\n"
               f"}}\n"
               f"ENTRY %e () -> f32[] {{\n"
               f"  %ag = f32[4] all-gather(f32[4] %y)\n"
               f"}}\n")
        scopes = collectives_by_scope(hlo)
        assert scopes["in_loop"]["count"] == 1, name
        assert scopes["top_level"]["count"] == 1, name


def test_largest_tensors_ranking():
    top = largest_tensors(HLO, k=3)
    # ranked by bytes desc; the commented // line must be skipped, so
    # the 9999x9999 ghost may not appear
    names = [name for _, name in top]
    assert all("ghost" not in n for n in names)
    assert top[0][1].startswith("%mystery") is False  # q4 -> 0 bytes
    # f32[1024,1024] (4 MiB) leads: both %big and %mm2 hold one
    assert top[0][0] == pytest.approx(4 * 1024 * 1024 / 2**30)
    assert top[0][1] in ("%big", "%mm2")
    # monotone non-increasing GiB
    sizes = [s for s, _ in top]
    assert sizes == sorted(sizes, reverse=True)


def test_largest_tensors_max_per_head():
    hlo = ("%t = f32[8] add(f32[8] %a, f32[8] %b)\n"
           "%t = f32[64] broadcast(f32[] %c)\n")
    top = largest_tensors(hlo, k=5)
    assert len(top) == 1                 # same head: keep the max
    assert top[0][0] == pytest.approx(256 / 2**30)


def test_count_op():
    assert count_op(HLO, "dot") == 2
    assert count_op(HLO, "all-gather") == 2
    assert count_op(HLO, "broadcast") == 1
    assert count_op(HLO, "convolution") == 0
    # opcode must be followed by '(' — prefixes don't count
    assert count_op("%x = f32[2] dots(f32[2] %y)\n", "dot") == 0


def test_hlo_flops_bytes_normalization():
    cost = {"flops": 1e9, "bytes accessed": 2e6,
            "bytes accessed0{}": 1.5e6, "transcendentals": 3.0,
            "utilization": 0.5}
    out = hlo_flops_bytes([cost])          # list form unwraps
    assert out["flops"] == 1e9
    assert out["bytes_accessed"] == 2e6
    assert out["bytes_accessed0{}"] == 1.5e6
    assert out["transcendentals"] == 3.0
    assert "utilization" not in out


def test_roofline_terms_from_record():
    from repro.configs import DeviceInfo
    device = DeviceInfo()
    record = {"mesh": "4x2", "kind": "train", "tokens": 1000,
              "params": 1e6,
              "cost_analysis": {"flops": 1e12, "bytes_accessed": 1e9},
              "collectives": {"total_bytes": 5e8}}
    terms = roofline(record, device)
    assert terms.compute_s == pytest.approx(1e12 / device.peak_flops)
    assert terms.memory_s == pytest.approx(1e9 / device.hbm_bw)
    assert terms.collective_s == pytest.approx(5e8 / device.ici_bw)
    assert terms.dominant == "collective"
    assert terms.model_flops == pytest.approx(6.0 * 1e6 * 1000)
    assert terms.useful_ratio == pytest.approx(
        terms.model_flops / (1e12 * 8))
