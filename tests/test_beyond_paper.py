"""Beyond-paper extensions: auto slice granularity, ZDP_POD hierarchy,
chunked cross-entropy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_run
from repro.configs import (DeviceInfo, MULTI_POD_MESH, SINGLE_POD_MESH,
                           OSDPConfig, get_arch, get_shape)
from repro.core.cost_model import CostEnv, ZDP
from repro.core.descriptions import OperatorDesc, describe
from repro.core.search import auto_granularity, search_plan
from repro.models.registry import build_model


ENV = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
OSDP_AUTO = OSDPConfig(operator_splitting=True, auto_granularity=True)


def _op(params, layers=1):
    return OperatorDesc("op", params, 0.0, 0.0, splittable=True,
                        layers=layers)


def test_auto_granularity_monotone_in_size():
    """Bigger gathered slices warrant finer splitting."""
    gs = [auto_granularity(_op(p), ENV, OSDP_AUTO)
          for p in (10**4, 10**7, 10**9, 10**11)]
    assert gs == sorted(gs)
    assert gs[0] == 1          # tiny op: splitting is pure alpha loss
    assert gs[-1] >= 8         # huge op: amortize the gather peak


def test_auto_granularity_accounts_layer_stacking():
    """A stacked group gathers one layer at a time — 100 layers of the
    same total mass need far less splitting than one monolith."""
    g_mono = auto_granularity(_op(10**10, layers=1), ENV, OSDP_AUTO)
    g_stack = auto_granularity(_op(10**10, layers=100), ENV, OSDP_AUTO)
    assert g_stack <= g_mono


def test_auto_granularity_plan_not_worse():
    """Auto-g plan must be at least as good as fixed g=4 on the W&S-like
    regime (huge operators) in estimated step time at equal memory."""
    desc = describe(get_arch("llama3-405b"), get_shape("train_4k"))
    lim = 32 * 2**30
    fixed = search_plan(desc, 256, ENV, OSDPConfig(
        operator_splitting=True, default_slice_granularity=4,
        memory_limit_bytes=lim))
    auto = search_plan(desc, 256, ENV, OSDPConfig(
        operator_splitting=True, auto_granularity=True,
        memory_limit_bytes=lim))
    assert auto.cost.time <= fixed.cost.time * 1.02
    assert auto.cost.memory <= lim * 1.001 or not auto.feasible


def test_zdp_pod_chosen_on_multipod_when_cheaper():
    """On the 2-pod mesh with a loose-enough limit, the searched plan
    should use ZDP_POD (in-pod gathers) for some mass instead of flat
    ZDP across the slow pod link."""
    desc = describe(get_arch("llama3-405b"), get_shape("train_4k"))
    env = CostEnv(DeviceInfo(), MULTI_POD_MESH)
    res = search_plan(desc, 256, env, OSDPConfig(
        memory_limit_bytes=40 * 2**30, operator_splitting=False,
        allow_pod_hierarchical=True))
    modes = {m for d in res.decisions.values() for m in d.modes}
    assert "ZDP_POD" in modes, modes


def test_chunked_ce_matches_unchunked():
    """Loss with sequence-chunked CE == plain CE (same params/batch)."""
    run = tiny_run("qwen1.5-0.5b", seq=64, batch=2)
    built = build_model(run)
    m = built.model
    params = built.init(jax.random.PRNGKey(0))
    batch = make_batch(run.model, 2, 64)
    loss_plain, _ = jax.jit(m.loss_fn)(params, batch)

    # force the chunked path by shrinking the threshold
    x, aux = m.forward(params, batch)
    nb, chunk = 4, 16
    xb = jnp.moveaxis(x.reshape(2, nb, chunk, x.shape[-1]), 1, 0)
    lb = jnp.moveaxis(batch["labels"].reshape(2, nb, chunk), 1, 0)
    s = n = 0.0
    for i in range(nb):
        bs, bn = m._ce_block(params, xb[i], lb[i])
        s, n = s + bs, n + bn
    ce_chunked = s / n
    loss_chunked = ce_chunked + 0.01 * aux / max(1, run.model.n_layers)
    np.testing.assert_allclose(float(loss_plain), float(loss_chunked),
                               rtol=1e-5)


def test_chunked_ce_gradients_flow():
    """Chunked path must remain differentiable (remat inside scan)."""
    run = tiny_run("qwen1.5-0.5b", seq=1024, batch=1)
    # padded_vocab=512 -> S*V = 512k < threshold; widen artificially
    cfg = dataclasses.replace(run.model, vocab_size=262144,
                              vocab_pad_multiple=256)
    run = dataclasses.replace(run, model=cfg)
    built = build_model(run)
    m = built.model
    params = built.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 1, 1024)
    grads = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    gn = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                            for g in jax.tree.leaves(grads))))
    assert np.isfinite(gn) and gn > 0
