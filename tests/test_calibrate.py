"""Calibration subsystem tests (ISSUE 10).

Four pinned contracts:

  * the fits recover known constants from synthetic timings and
    enforce their clamps (monotone curve, alpha >= 0, remat in
    [1, 2]);
  * `CalibrationProfile` JSON round-trips to an identical value;
  * `profile=None` and the degenerate `default_profile(device)` price
    every random plan identically to 1e-12 relative, across models
    and cluster shapes — calibration off is byte-equivalent to the
    legacy scalar path;
  * the preset catalog is self-consistent (one source of truth) and
    the committed fig5/fig9 goldens re-assert unmoved with
    calibration disabled.
"""
import dataclasses
import json
import math
import random
import sys
from pathlib import Path

import pytest

from repro.calibrate import fit, store
from repro.calibrate.profile import (CalibrationProfile, EfficiencyCurve,
                                     LinkCalibration, default_profile)
from repro.configs import (DEVICE_PRESETS, DeviceInfo, MeshConfig,
                           MULTI_POD_MESH, PRESET_CATALOG, PRESET_OVERLAP,
                           SINGLE_POD_MESH, get_arch, get_shape)
from repro.core.cost_model import (DP, CostEnv, Decision, plan_cost,
                                   uniform_plan, ZDP)
from repro.core.descriptions import describe

ROOT = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# fits recover known constants
# ---------------------------------------------------------------------------

def test_alpha_beta_fit_recovers_known_constants():
    alpha, bw = 2.5e-5, 3.2e9
    samples = [(b, alpha + b / bw)
               for b in (1e5, 1e6, 4e6, 1.6e7, 6.4e7)]
    a, w = fit.fit_alpha_beta(samples)
    assert a == pytest.approx(alpha, rel=1e-9)
    assert w == pytest.approx(bw, rel=1e-9)


def test_alpha_beta_fit_clamps_negative_intercept():
    # pure-bandwidth samples perturbed so the LSQ intercept dips
    # negative: alpha must clamp to 0 and the slope refit stays sane
    bw = 1e9
    samples = [(1e6, 1e6 / bw * 0.95), (1e7, 1e7 / bw),
               (1e8, 1e8 / bw * 1.01)]
    a, w = fit.fit_alpha_beta(samples)
    assert a == 0.0
    assert w == pytest.approx(bw, rel=0.05)


def test_alpha_beta_fit_latency_dominated_fallback():
    # constant time regardless of size: slope <= 0, bandwidth falls
    # back to the best single-sample bound instead of going negative
    a, w = fit.fit_alpha_beta([(1e6, 1e-3), (1e7, 1e-3), (1e8, 1e-3)])
    assert a >= 0.0 and w > 0.0


def test_alpha_beta_fit_rejects_degenerate_input():
    with pytest.raises(ValueError):
        fit.fit_alpha_beta([(1e6, 1e-3)])
    with pytest.raises(ValueError):
        fit.fit_alpha_beta([(1e6, 1e-3), (1e6, 2e-3)])
    with pytest.raises(ValueError):
        fit.fit_alpha_beta([(1e6, 1e-3), (1e7, -1.0)])


def test_efficiency_fit_recovers_known_curve():
    peak = 1e12
    sizes = [2 * n ** 3 for n in (64, 128, 256, 512)]
    fracs = [0.05, 0.2, 0.6, 0.9]
    samples = [(s, s / (f * peak)) for s, f in zip(sizes, fracs)]
    curve = fit.fit_efficiency_curve(samples, peak_flops=peak)
    for s, f in zip(sizes, fracs):
        assert curve.at(s) == pytest.approx(f, rel=1e-9)


def test_efficiency_fit_is_monotone_and_clipped():
    peak = 1e12
    # non-monotone noise + one sample "above peak" (fraction > 1)
    samples = [(1e6, 1e6 / (0.3 * peak)), (1e7, 1e7 / (0.1 * peak)),
               (1e8, 1e8 / (1.4 * peak))]
    curve = fit.fit_efficiency_curve(samples, peak_flops=peak)
    assert all(b >= a for a, b in zip(curve.fraction, curve.fraction[1:]))
    assert all(0.0 < f <= 1.0 for f in curve.fraction)
    # queries between/outside knots stay monotone and clamped
    last = 0.0
    for flops in (1e5, 1e6, 3e6, 1e7, 5e7, 1e8, 1e9):
        f = curve.at(flops)
        assert last <= f <= 1.0
        last = f


def test_efficiency_fit_averages_duplicate_sizes():
    peak = 1e12
    # same size measured twice: fractions 0.2 and 0.4 average to 0.3
    samples = [(1e6, 1e6 / (0.2 * peak)), (1e6, 1e6 / (0.4 * peak))]
    curve = fit.fit_efficiency_curve(samples, peak_flops=peak)
    assert len(curve.fraction) == 1
    assert curve.at(1e6) == pytest.approx(0.3, rel=1e-9)


def test_remat_fit_recovers_and_clamps():
    assert fit.fit_remat_factor(1.0, 1.37) == pytest.approx(1.37)
    assert fit.fit_remat_factor(1.0, 0.9) == 1.0     # noise below 1
    assert fit.fit_remat_factor(1.0, 2.8) == 2.0     # clamp at hi
    with pytest.raises(ValueError):
        fit.fit_remat_factor(0.0, 1.0)


def test_link_fit_skips_span_one_axes():
    sweeps = {"data": [(1e6, 1e-3), (4e6, 3e-3)],
              "model": []}          # span-1 axis: no bytes moved
    links = fit.fit_link_calibrations(sweeps)
    assert [ln.level for ln in links] == ["data"]
    assert links[0].alpha >= 0 and links[0].bandwidth > 0


# ---------------------------------------------------------------------------
# value-type semantics + validation
# ---------------------------------------------------------------------------

def test_curve_interpolates_in_log_space_and_clamps():
    curve = EfficiencyCurve((6.0, 8.0), (0.2, 0.8))
    assert curve.at(1e5) == 0.2          # below range: clamp
    assert curve.at(1e9) == 0.8          # above range: clamp
    assert curve.at(1e7) == pytest.approx(0.5)   # log-midpoint
    assert curve.at(0.0) == 0.2          # degenerate query
    const = EfficiencyCurve.constant(0.55)
    for flops in (0.0, 1e3, 1e15):
        assert const.at(flops) == 0.55


def test_curve_validation_errors():
    with pytest.raises(ValueError):
        EfficiencyCurve((1.0, 2.0), (0.5,))          # length mismatch
    with pytest.raises(ValueError):
        EfficiencyCurve((), ())                      # empty
    with pytest.raises(ValueError):
        EfficiencyCurve((2.0, 1.0), (0.1, 0.2))      # knots not increasing
    with pytest.raises(ValueError):
        EfficiencyCurve((1.0, 2.0), (0.5, 0.4))      # fractions decreasing
    with pytest.raises(ValueError):
        EfficiencyCurve((1.0,), (1.5,))              # fraction > 1
    with pytest.raises(ValueError):
        EfficiencyCurve((1.0,), (0.0,))              # fraction = 0


def test_link_and_profile_validation_errors():
    with pytest.raises(ValueError):
        LinkCalibration("data", -1e-6, 1e9)
    with pytest.raises(ValueError):
        LinkCalibration("data", 0.0, 0.0)
    curve = EfficiencyCurve.constant(0.5)
    with pytest.raises(ValueError):
        CalibrationProfile("d", curve, remat_factor=0.5)
    with pytest.raises(ValueError):
        CalibrationProfile("d", curve, links=(
            LinkCalibration("data", 0.0, 1e9),
            LinkCalibration("data", 0.0, 2e9)))


def test_profile_json_round_trip_identity(tmp_path):
    profile = CalibrationProfile(
        device="host-cpu",
        efficiency=EfficiencyCurve((5.7, 7.5, 9.3), (0.04, 0.5, 1.0)),
        links=(LinkCalibration("data", 1.5e-4, 9.4e8),
               LinkCalibration("pod", 2.5e-3, 1.2e8)),
        remat_factor=1.26, peak_flops=8.9e10, source="unit test")
    assert CalibrationProfile.from_json(profile.to_json()) == profile
    path = tmp_path / "profile.json"
    profile.save(path)
    assert CalibrationProfile.load(path) == profile
    # the on-disk form is plain JSON with stable keys
    doc = json.loads(path.read_text())
    assert doc["device"] == "host-cpu"
    assert doc["efficiency"]["fraction"] == [0.04, 0.5, 1.0]


def test_profile_from_dict_defaults():
    p = CalibrationProfile.from_dict({
        "device": "x",
        "efficiency": {"log10_flops": [1.0], "fraction": [0.5]}})
    assert p.links == () and p.remat_factor == 1.30
    assert p.peak_flops is None and p.source == ""


# ---------------------------------------------------------------------------
# profile=None is byte-equivalent to the degenerate default profile
# ---------------------------------------------------------------------------

EQUIV_MODELS = ("qwen1.5-0.5b", "phi4-mini-3.8b", "mamba2-2.7b")
EQUIV_MESHES = {
    "single_pod": SINGLE_POD_MESH,
    "multi_pod": MULTI_POD_MESH,
    "narrow": MeshConfig((8, 1), ("data", "model")),
}


def _random_plan(desc, rng, modes):
    decs = {}
    for op in desc.operators:
        if not op.decidable:
            decs[op.name] = Decision(op.name, (DP,))
            continue
        g = rng.choice([1, 2, 4]) if op.splittable else 1
        remat = tuple(rng.choice([None, True, False]) for _ in range(g))
        decs[op.name] = Decision(
            op.name, tuple(rng.choice(modes) for _ in range(g)),
            remat=remat)
    return decs


@pytest.mark.parametrize("model", EQUIV_MODELS)
@pytest.mark.parametrize("mesh_name", sorted(EQUIV_MESHES))
def test_no_profile_equals_default_profile(model, mesh_name):
    mesh = EQUIV_MESHES[mesh_name]
    device = DeviceInfo()
    desc = describe(get_arch(model), get_shape("train_4k"))
    env0 = CostEnv(device, mesh)
    env1 = CostEnv(device, mesh, profile=default_profile(device))
    modes = ("DP", "ZDP", "ZDP_POD") if mesh.multi_pod else ("DP", "ZDP")
    rng = random.Random(hash((model, mesh_name)) & 0xFFFF)
    plans = [uniform_plan(desc, DP), uniform_plan(desc, ZDP)] + \
        [_random_plan(desc, rng, modes) for _ in range(3)]
    for i, decs in enumerate(plans):
        for batch in (16, 512):
            got = plan_cost(desc, decs, batch, env1)
            want = plan_cost(desc, decs, batch, env0)
            for f in ("memory", "peak_memory", "time", "comm_time",
                      "compute_time", "throughput"):
                g, w = getattr(got, f), getattr(want, f)
                assert g == pytest.approx(w, rel=1e-12, abs=1e-15), \
                    (model, mesh_name, i, batch, f, g, w)


def test_no_profile_scalar_identities():
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
    # without a profile the per-op hooks are EXACTLY the scalar path:
    # the goldens pin these floats bit-for-bit
    for work in (1.0, 1e6, 1e12):
        assert env.op_peak_compute(work) == env.peak_compute
    assert env.remat_factor == 1.30
    assert env.remat_compute_delta == 0.30   # the literal, not 1.30-1.0


# ---------------------------------------------------------------------------
# preset catalog: one source of truth
# ---------------------------------------------------------------------------

def test_preset_catalog_is_single_source():
    assert DEVICE_PRESETS == tuple(sorted(PRESET_CATALOG))
    assert set(PRESET_OVERLAP) == set(PRESET_CATALOG)
    for name, preset in PRESET_CATALOG.items():
        assert preset.info.name == name
        assert PRESET_OVERLAP[name] == preset.achievable_overlap
        assert DeviceInfo.preset(name) == preset.info
        auto = DeviceInfo.preset(name, overlap="auto")
        assert auto.overlap == preset.achievable_overlap


def test_preset_unknown_name_raises():
    with pytest.raises(KeyError):
        DeviceInfo.preset("not-a-device")
    with pytest.raises(KeyError):
        store.catalog_default("not-a-device")


def test_store_resolves_registered_over_catalog(tmp_path):
    store.clear()
    try:
        name = DEVICE_PRESETS[0]
        assert store.resolve(name) == default_profile(
            DeviceInfo.preset(name))
        fitted = CalibrationProfile(
            device=name, efficiency=EfficiencyCurve.constant(0.9),
            remat_factor=1.1, source="fitted")
        store.register(fitted)
        assert store.resolve(name) == fitted
        assert store.registered_names() == (name,)
        # load_and_register round-trips through the CLI's on-disk form
        path = tmp_path / "p.json"
        fitted2 = dataclasses.replace(fitted, device="other")
        fitted2.save(path)
        assert store.load_and_register(path) == fitted2
        assert store.resolve("other") == fitted2
    finally:
        store.clear()


# ---------------------------------------------------------------------------
# calibrated behavior: the fitted constants actually reprice
# ---------------------------------------------------------------------------

def _host_profile(alpha=1e-4, bw=1e9, remat=1.5):
    return CalibrationProfile(
        device="host", efficiency=EfficiencyCurve((6.0, 9.0), (0.1, 1.0)),
        links=(LinkCalibration("data", alpha, bw),), remat_factor=remat)


def test_fitted_links_reprice_collectives():
    device = DeviceInfo()
    desc = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    env0 = CostEnv(device, SINGLE_POD_MESH)
    slow = CostEnv(device, SINGLE_POD_MESH,
                   profile=_host_profile(alpha=1e-3, bw=device.ici_bw / 50))
    plan = uniform_plan(desc, ZDP)
    t0 = plan_cost(desc, plan, 64, env0).comm_time
    t1 = plan_cost(desc, plan, 64, slow).comm_time
    assert t1 > t0 * 10    # 50x slower link + huge alpha must show up
    # the link landed on the innermost ("data") level of the topo
    lvl = slow.topo.levels[0]
    assert lvl.alpha == 1e-3
    assert lvl.bandwidth == device.ici_bw / 50


def test_fitted_links_bind_positionally_when_names_differ():
    from repro.cluster.topology import ClusterSpec
    spec = ClusterSpec.from_device(
        dataclasses.replace(DeviceInfo(), devices_per_node=8), 64)
    names = [l.name for l in spec.levels]
    assert "data" not in names     # the interesting case: no name match
    repriced = spec.with_links([LinkCalibration("data", 7e-5, 3e9)])
    assert repriced.levels[0].alpha == 7e-5
    assert repriced.levels[0].bandwidth == 3e9
    # outer level untouched
    assert repriced.levels[1].alpha == spec.levels[1].alpha


def test_efficiency_curve_reprices_compute_by_op_size():
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH,
                  profile=_host_profile())
    # small ops run at the low end of the curve, big ops at the top;
    # sustained flops must be monotone in op size
    peaks = [env.op_peak_compute(w) for w in (1e5, 1e7, 1e9, 1e11)]
    assert all(b >= a for a, b in zip(peaks, peaks[1:]))
    assert peaks[0] == pytest.approx(
        env.topo.effective_peak_flops * 0.1)
    assert peaks[-1] == pytest.approx(env.topo.effective_peak_flops)
    assert env.remat_factor == 1.5
    assert env.remat_compute_delta == pytest.approx(0.5)


def test_search_accepts_profile():
    from repro.core.search import schedule
    from repro.configs import OSDPConfig
    desc = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    env = CostEnv(DeviceInfo(), SINGLE_POD_MESH,
                  profile=_host_profile())
    dp_mem = plan_cost(desc, uniform_plan(desc, DP), 8,
                       CostEnv(DeviceInfo(), SINGLE_POD_MESH)).memory
    osdp = OSDPConfig(enabled=True, memory_limit_bytes=dp_mem * 0.6)
    res = schedule(desc, env, osdp, batch_candidates=[4, 8])
    assert res.feasible
    assert res.cost.memory <= dp_mem * 0.6


# ---------------------------------------------------------------------------
# goldens unmoved with calibration disabled
# ---------------------------------------------------------------------------

def _bench(name):
    sys.path.insert(0, str(ROOT))
    try:
        import importlib
        return importlib.import_module(f"benchmarks.{name}")
    finally:
        sys.path.pop(0)


def test_fig5_golden_unmoved():
    """fig5 --quick asserts its 8-GiB block against the committed
    golden internally; a profile registered in the store must not
    leak into the default (profile=None) pricing path."""
    fig5 = _bench("fig5_end_to_end")
    store.register(_host_profile())
    try:
        rows = fig5.main(out=lambda *a, **k: None, quick=True)
    finally:
        store.clear()
    assert rows


def test_fig9_golden_unmoved():
    fig9 = _bench("fig9_checkpointing")
    rows = fig9.main(out=lambda *a, **k: None, quick=True)
    assert rows


def test_bench_quick_rows_resolve_identically():
    """Re-solve the committed BENCH quick training rows (dfs solver)
    with calibration disabled: step times, feasibility and solver
    effort must be byte-identical to the committed JSON."""
    from repro.configs import OSDPConfig
    from repro.core.search import search_plan
    st = _bench("search_time")
    doc = json.loads((ROOT / "BENCH_search.json").read_text())
    checked = 0
    for name, desc, env, lim, batch, ckpt in st._search_plan_cases(
            quick=True):
        want = doc["current"].get(name, {}).get("solvers", {}).get("dfs")
        if want is None:
            continue
        osdp = OSDPConfig(search="dfs", memory_limit_bytes=lim,
                          operator_splitting=True,
                          default_slice_granularity=4,
                          checkpointing=ckpt)
        res = search_plan(desc, batch, env, osdp)
        assert round(res.cost.time * 1e3, 3) == want["step_time_ms"], name
        assert res.feasible == want["feasible"], name
        assert res.nodes_visited == want["nodes_visited"], name
        checked += 1
    assert checked >= 2
