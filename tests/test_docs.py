"""Docs can't rot: extract and execute the ```python code blocks in
README.md and docs/*.md.

Rules (see docs/cost_model.md header):
  * blocks fenced ```python are executed, in order, in one namespace
    per file — later blocks may use names from earlier ones;
  * REPL-style blocks (>>> / ...) are executed with the prompts
    stripped; their printed-output lines are ignored, only the code
    must run;
  * a fence info string containing `no-exec` (```python no-exec)
    marks an illustrative snippet that is skipped.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])

_FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")


def _python_blocks(text: str):
    """[(start_line, code)] for executable python fences."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m and m.group(1).startswith("python"):
            info = (m.group(1) + " " + m.group(2)).strip()
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            if "no-exec" not in info:
                blocks.append((start, "\n".join(body)))
        i += 1
    return blocks


def _strip_repl(code: str) -> str:
    """Convert >>>-style blocks to plain code, dropping output lines."""
    if ">>>" not in code:
        return code
    out = []
    for line in code.splitlines():
        s = line.lstrip()
        if s.startswith(">>> "):
            out.append(s[4:])
        elif s.startswith("... "):
            out.append(s[4:])
        elif s in (">>>", "..."):
            out.append("")
        # anything else is expected output: ignored
    return "\n".join(out)


@pytest.mark.parametrize(
    "path", DOC_FILES, ids=[p.name for p in DOC_FILES])
def test_doc_code_blocks_execute(path):
    blocks = _python_blocks(path.read_text())
    if not blocks:
        pytest.skip(f"{path.name}: no executable python blocks")
    ns = {"__name__": f"doc_{path.stem}"}
    for start, code in blocks:
        code = _strip_repl(code)
        try:
            exec(compile(code, f"{path.name}:{start}", "exec"), ns)
        except Exception as e:   # pragma: no cover - failure reporting
            pytest.fail(
                f"{path.name} code block at line {start} failed: "
                f"{type(e).__name__}: {e}\n--- block ---\n{code}")
