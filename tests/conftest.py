"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single
real CPU device; only launch/dryrun.py forces 512 host devices."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                           get_shape, reduced)

HOST_MESH = MeshConfig((1, 1), ("data", "model"))


def tiny_run(arch: str, *, seq: int = 64, batch: int = 2,
             shape: str = "train_4k", osdp: OSDPConfig = None) -> RunConfig:
    cfg = reduced(get_arch(arch))
    shp = dataclasses.replace(get_shape(shape), seq_len=seq,
                              global_batch=batch)
    return RunConfig(model=cfg, shape=shp, mesh=HOST_MESH,
                     osdp=osdp or OSDPConfig(enabled=False))


def make_batch(cfg, B, S, key=0):
    k = jax.random.PRNGKey(key)
    import jax.numpy as jnp
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(k, (B, S, cfg.d_model), jnp.bfloat16),
            "mask": jax.random.bernoulli(k, 0.3, (B, S)),
            "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        P = min(16, S // 2)
        st = S - P
        pos = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
        return {
            "tokens": jax.random.randint(k, (B, st), 0, cfg.vocab_size),
            "patches": jax.random.normal(k, (B, P, cfg.d_model),
                                         jnp.bfloat16),
            "positions": pos,
            "labels": jax.random.randint(k, (B, st), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }
