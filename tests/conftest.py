"""Shared fixtures + the enforced skip/xfail inventory.

NOTE: no XLA_FLAGS here — tests run on the single real CPU device;
only launch/dryrun.py forces 512 host devices.

The skip/xfail set is a pinned contract, not ambient noise: a test
that starts skipping for a new reason, or an xfail that silently
starts passing, fails the tier-1 run instead of shrinking coverage
unnoticed.  To change the inventory intentionally, update
EXPECTED_SKIP_MODULES / EXPECTED_XFAILS below in the same PR.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                           get_shape, reduced)

# --- pinned skip/xfail inventory --------------------------------------------
# Modules whose tests may skip, with the only sanctioned reasons:
#   test_kernels.py      — Pallas needs jax with pltpu.CompilerParams
#   test_distributed.py  — needs jax.set_mesh (jax >= 0.6)
#   test_cost_model.py / test_search.py / test_model_properties.py /
#   test_solver_oracle.py
#                        — hypothesis not installed in the local env
#                          (CI installs it; these never skip there)
#   test_ilp.py          — pinned ONLY when scipy is absent: the
#                          milp-backend cases skip; the bnb cases and
#                          everything else in the module still run
# test_overlap.py, test_perf_probe.py, test_calibrate.py and
# test_roofline.py are deliberately NOT listed: the overlap
# timeline/runtime tests, the probe subprocess tests, the calibration
# fit/equivalence tests and the HLO-parser pins run everywhere
# (single-device CPU suffices) and must never skip.
EXPECTED_SKIP_MODULES = frozenset({
    "test_kernels.py",
    "test_distributed.py",
    "test_cost_model.py",
    "test_search.py",
    "test_model_properties.py",
    "test_solver_oracle.py",
})
try:
    from repro.core.ilp import HAVE_SCIPY_MILP as _HAVE_MILP
except Exception:   # pragma: no cover - core must import for any test run
    _HAVE_MILP = False
if not _HAVE_MILP:
    EXPECTED_SKIP_MODULES = EXPECTED_SKIP_MODULES | {"test_ilp.py"}
# Exact tests that may xfail (an XPASS of these also fails the run —
# a silently-passing xfail means the pin is stale):
EXPECTED_XFAILS = (
    "test_arch_smoke.py::test_decode_matches_full_forward[hymba-1.5b]",
)

_inventory_violations = []


def _module_of(nodeid: str) -> str:
    return nodeid.split("::", 1)[0].rsplit("/", 1)[-1]


def _expected_xfail(nodeid: str) -> bool:
    mod = _module_of(nodeid)
    tail = nodeid.split("::", 1)[-1]
    return any(x == f"{mod}::{tail}" for x in EXPECTED_XFAILS)


def pytest_collectreport(report):
    # module-level skips (e.g. importorskip) surface as skipped
    # collection reports
    if report.skipped and report.nodeid:
        if _module_of(report.nodeid) not in EXPECTED_SKIP_MODULES:
            _inventory_violations.append(
                ("collection skip", report.nodeid,
                 str(getattr(report, "longrepr", ""))))


def pytest_runtest_logreport(report):
    if report.when not in ("setup", "call"):
        return
    wasxfail = hasattr(report, "wasxfail")
    if report.skipped:
        if wasxfail:
            if not _expected_xfail(report.nodeid):
                _inventory_violations.append(
                    ("unpinned xfail", report.nodeid, report.wasxfail))
        elif _module_of(report.nodeid) not in EXPECTED_SKIP_MODULES:
            _inventory_violations.append(
                ("unpinned skip", report.nodeid,
                 str(getattr(report, "longrepr", ""))))
    elif report.passed and wasxfail:
        _inventory_violations.append(
            ("xfail PASSED (stale pin)", report.nodeid, report.wasxfail))


def pytest_sessionfinish(session, exitstatus):
    if not _inventory_violations:
        return
    tr = session.config.pluginmanager.get_plugin("terminalreporter")
    lines = [f"  {kind}: {nodeid}  [{reason[:120]}]"
             for kind, nodeid, reason in _inventory_violations]
    msg = ("skip/xfail inventory violations (pin intentional changes "
           "in tests/conftest.py):\n" + "\n".join(lines))
    if tr is not None:
        tr.write_sep("=", "skip/xfail inventory", red=True)
        tr.write_line(msg)
    else:   # pragma: no cover - terminal plugin disabled
        print(msg)
    if session.exitstatus == 0:
        session.exitstatus = 1

HOST_MESH = MeshConfig((1, 1), ("data", "model"))


def tiny_run(arch: str, *, seq: int = 64, batch: int = 2,
             shape: str = "train_4k", osdp: OSDPConfig = None) -> RunConfig:
    cfg = reduced(get_arch(arch))
    shp = dataclasses.replace(get_shape(shape), seq_len=seq,
                              global_batch=batch)
    return RunConfig(model=cfg, shape=shp, mesh=HOST_MESH,
                     osdp=osdp or OSDPConfig(enabled=False))


def make_batch(cfg, B, S, key=0):
    k = jax.random.PRNGKey(key)
    import jax.numpy as jnp
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(k, (B, S, cfg.d_model), jnp.bfloat16),
            "mask": jax.random.bernoulli(k, 0.3, (B, S)),
            "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        P = min(16, S // 2)
        st = S - P
        pos = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
        return {
            "tokens": jax.random.randint(k, (B, st), 0, cfg.vocab_size),
            "patches": jax.random.normal(k, (B, P, cfg.d_model),
                                         jnp.bfloat16),
            "positions": pos,
            "labels": jax.random.randint(k, (B, st), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }
