"""Quickstart: OSDP in five steps.

1. pick an architecture + input shape,
2. run the OSDP search (the paper's Figure-3 one-liner),
3. inspect the plan (which operators DP, which ZDP, what it costs),
4. build the model with the planned shardings,
5. train a few steps on CPU with the reduced config.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import (MeshConfig, OSDPConfig, RunConfig,
                           SINGLE_POD_MESH, get_arch, get_shape, reduced)
from repro.core import dp_baseline, fsdp_baseline, osdp
from repro.models.registry import build_model
from repro.train.loop import train

# ---- 1+2: the paper's API (Figure 3): one call wraps the model -------------
model = get_arch("phi4-mini-3.8b")
shape = get_shape("train_4k")
plan = osdp(model, shape, SINGLE_POD_MESH, memory_limit_gib=16.0)

# ---- 3: what did the search decide? -----------------------------------------
print(plan.summary())
print()
for op, dec in sorted(plan.decisions.items()):
    u = dec.uniform() or f"MIXED{dec.modes}"
    print(f"  {op:24s} -> {u}")

fsdp = fsdp_baseline(model, shape, SINGLE_POD_MESH)
dp = dp_baseline(model, shape, SINGLE_POD_MESH)
print(f"\nest. step time: OSDP {plan.cost.time * 1e3:.0f} ms "
      f"vs FSDP {fsdp.cost.time * 1e3:.0f} ms "
      f"vs DP {dp.cost.time * 1e3:.0f} ms "
      f"(DP memory {dp.cost.memory / 2**30:.0f} GiB/dev — "
      f"{'OOM' if dp.cost.memory > 16 * 2**30 else 'fits'})")

# ---- 3b: remat as a searched axis (checkpointing="selective") ---------------
# At 6 GiB, keeping every activation cannot fit and remat'ing everything
# wastes ~30% compute; the 4-mode search (DP/ZDP x remat/no-remat per
# slice) remats only the slices whose memory it needs.
sel = osdp(model, shape, SINGLE_POD_MESH, memory_limit_gib=6.0,
           checkpointing="selective")
on = osdp(model, shape, SINGLE_POD_MESH, memory_limit_gib=6.0,
          checkpointing=True)
from repro.core.cost_model import count_remat_slices
n_remat = count_remat_slices(sel.decisions)
n_keep = count_remat_slices(sel.decisions, value=False)
print(f"\nselective remat at 6 GiB: {n_remat} slices remat'd, "
      f"{n_keep} keep activations")
print(f"  selective {sel.cost.throughput / 1e6:.2f} Mtok/s vs "
      f"global remat {on.cost.throughput / 1e6:.2f} Mtok/s "
      f"(+{(sel.cost.throughput / on.cost.throughput - 1) * 100:.0f}%)")

# ---- 4+5: train the reduced variant on CPU ----------------------------------
small = reduced(model)
run = RunConfig(
    model=small,
    shape=dataclasses.replace(shape, seq_len=128, global_batch=8),
    mesh=MeshConfig((1, 1), ("data", "model")),
    osdp=OSDPConfig(enabled=False),
)
built = build_model(run)
print(f"\ntraining reduced {small.name} "
      f"({small.param_count() / 1e6:.1f}M params) for 30 steps ...")
res = train(built, 30, warmup=10, log_every=10)
print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
      f"at {res.tokens_per_s:.0f} tok/s")
