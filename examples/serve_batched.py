"""Batched serving example: prefill + decode with KV / SSM caches.

Serves three reduced-architecture families (dense GQA, pure-SSM
mamba2, hybrid hymba) with batched requests, greedy decoding, and a
decode-vs-prefill consistency probe.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                           get_shape, reduced)
from repro.models.registry import build_model
from repro.serving.engine import Engine

for arch in ("qwen1.5-0.5b", "mamba2-2.7b", "hymba-1.5b"):
    cfg = reduced(get_arch(arch))
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    eng = Engine(built, params, temperature=0.0)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 48)).astype(np.int32)
    res = eng.generate(prompts, 24)
    print(f"{arch:14s} [{cfg.family:6s}] prefill {res.prefill_s:.2f}s | "
          f"decode {res.tokens_per_s:6.1f} tok/s | "
          f"sample: {res.tokens[0][:8]}")
