"""Batched serving example: static vs continuous batching.

Serves three reduced-architecture families (dense GQA, pure-SSM
mamba2, hybrid hymba): first the legacy static batch engine, then the
same mixed-length request set through the continuous-batching engine
(request queue, slot KV cache, per-slot decode positions).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                           get_shape, reduced)
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Engine, Request

for arch in ("qwen1.5-0.5b", "mamba2-2.7b", "hymba-1.5b"):
    cfg = reduced(get_arch(arch))
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    eng = Engine(built, params, temperature=0.0)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 48)).astype(np.int32)
    res = eng.generate(prompts, 24)
    print(f"{arch:14s} [{cfg.family:6s}] static: prefill "
          f"{res.prefill_s:.2f}s | decode {res.tokens_per_s:6.1f} tok/s "
          f"| sample: {res.tokens[0][:8]}")

    # continuous: 8 mixed-length requests through 4 slots — short
    # requests finish early and free their slot for the queue
    ce = ContinuousEngine(built, params, max_slots=4, cache_len=72)
    news = [24 if i % 4 == 0 else 6 for i in range(8)]
    reqs = [Request(i, np.random.default_rng(i).integers(
        0, cfg.vocab_size, 48).astype(np.int32), news[i])
        for i in range(8)]
    results, stats = ce.run(reqs)
    print(f"{'':14s} continuous: {stats.completed} requests, "
          f"{stats.useful_tokens} tokens in {stats.decode_steps} decode "
          f"steps ({stats.tokens_per_s:6.1f} tok/s, utilization "
          f"{stats.slot_utilization:.0%})")
