"""Hybrid 3D+OSDP search: pick (dp, tp, pp) AND the per-operator plan.

The paper's strongest configuration replaces the DP dimension of 3D
parallelism with the OSDP search. `search_hybrid` sweeps every
(dp, tp, pp) factorization of the device count and, inside each, runs
the OSDP Scheduler over the per-device model residue — one call
returns the global throughput argmax as a `HybridPlan`.

Run:  PYTHONPATH=src python examples/hybrid_search.py
"""
import jax

from repro.configs import get_arch, get_shape
from repro.core import search_hybrid
from repro.launch.mesh import make_hybrid_mesh

model = get_arch("phi4-mini-3.8b")
shape = get_shape("train_4k")

# ---- the one-call hybrid search (paper Fig. 5/6 "3D+OSDP" row) -------------
# batch_candidates is Algorithm 1's outer loop: the Scheduler keeps the
# throughput argmax over (batch, dp, tp, pp, per-op decisions) jointly.
BATCHES = [16, 32, 64, 128, 256]
plan = search_hybrid(model, shape, n_devices=16, memory_limit_gib=16.0,
                     batch_candidates=BATCHES)
print(plan.summary())

# ---- what else was on the frontier? -----------------------------------------
print("\nswept factorizations (feasible points):")
for f, thr in sorted(plan.swept, key=lambda p: -p[1]):
    mark = " <-- chosen" if f == plan.factorization else ""
    print(f"  {str(f):28s} {thr:12.0f} tok/s{mark}")

# ---- plain 3D (DP dimension forced to FSDP/ZeRO-3) for comparison ----------
plain = search_hybrid(model, shape, n_devices=16, memory_limit_gib=16.0,
                      batch_candidates=BATCHES, force_mode="ZDP")
gain = (plan.cost.throughput / plain.cost.throughput - 1) * 100
print(f"\n3D+OSDP vs plain 3D: {plan.cost.throughput:.0f} vs "
      f"{plain.cost.throughput:.0f} tok/s ({gain:+.1f}%)")

# ---- executing the plan: the 3-axis (data, model, pipe) mesh ----------------
cfg = plan.mesh_config()
print(f"\nexecution mesh: shape={cfg.shape} axes={cfg.axes} "
      f"stages={plan.stage_layers()}")
if len(jax.devices()) >= plan.factorization.n_devices:
    mesh = make_hybrid_mesh(plan)
    print(f"built jax mesh: {mesh}")
else:
    print(f"(need {plan.factorization.n_devices} devices to build the "
          f"jax mesh; have {len(jax.devices())} — run under "
          f"launch/dryrun.py for forced host devices)")
