"""Plan-search explorer: run the OSDP search across all 10 assigned
architectures x memory limits and print the decision matrix — which
operators the search shards, which slices it remats
(checkpointing="selective"), where the memory/time frontier sits, and
how the three solvers compare.

Run:  PYTHONPATH=src python examples/search_plans.py
"""
from repro.configs import (ARCHS, SINGLE_POD_MESH, MULTI_POD_MESH,
                           OSDPConfig, get_shape)
from repro.core import osdp, fsdp_baseline
from repro.core.cost_model import DP, count_remat_slices

shape = get_shape("train_4k")

print(f"{'arch':24s} {'limit':>6s} {'feas':>4s} {'zdp/total':>9s} "
      f"{'remat':>9s} {'mem GiB':>8s} {'t_OSDP ms':>9s} "
      f"{'t_FSDP ms':>9s} {'gain':>6s}")
for name, cfg in sorted(ARCHS.items()):
    for gib in (8, 16, 32):
        # remat searched per slice, jointly with the sharding mode
        plan = osdp(cfg, shape, SINGLE_POD_MESH, memory_limit_gib=gib,
                    checkpointing="selective")
        fsdp = fsdp_baseline(cfg, shape, SINGLE_POD_MESH)
        n_zdp = sum(1 for d in plan.decisions.values()
                    if d.uniform() != DP)
        n_remat = count_remat_slices(plan.decisions)
        n_slices = sum(len(d.remat) for d in plan.decisions.values()
                       if d.remat is not None)
        feas = plan.search.feasible if plan.search else False
        gain = (fsdp.cost.time / plan.cost.time - 1) * 100
        print(f"{name:24s} {gib:4d}G {str(feas):>4s} "
              f"{n_zdp:4d}/{len(plan.decisions):<4d} "
              f"{n_remat:4d}/{n_slices:<4d} "
              f"{plan.cost.memory / 2**30:8.1f} "
              f"{plan.cost.time * 1e3:9.1f} {fsdp.cost.time * 1e3:9.1f} "
              f"{gain:5.1f}%")

print("\nmulti-pod (2x16x16) with hierarchical ZDP_POD (beyond-paper):")
for name in ("llama3-405b", "arctic-480b"):
    cfg = ARCHS[name]
    p_flat = osdp(cfg, shape, MULTI_POD_MESH, memory_limit_gib=16)
    from repro.configs import RunConfig
    print(f"  {name}: flat-ZDP-capable plan time "
          f"{p_flat.cost.time * 1e3:.0f} ms, "
          f"mem {p_flat.cost.memory / 2**30:.1f} GiB "
          f"(modes: {sorted(set(d.uniform() or 'MIXED' for d in p_flat.decisions.values()))})")
