"""End-to-end driver: train a ~100M-param model for a few hundred steps
under three parallel plans (DP / FSDP / OSDP) on a forced 4-device CPU
mesh, verifying the ZeRO invariant (identical loss trajectories) and
reporting wall-clock per plan.

Run:  PYTHONPATH=src python examples/train_osdp_vs_fsdp.py [--steps 200]

(The 4-device mesh is forced via XLA_FLAGS before jax import, so run
this as a script, not inside another jax process.)
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (DENSE, MeshConfig, ModelConfig, OSDPConfig,  # noqa: E402
                           RunConfig, get_shape)
from repro.core.plan import make_plan  # noqa: E402
from repro.data.synthetic import Dataset  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402

# ~100M params: 12 x 768 GPT-ish (the deliverable config; needs an
# accelerator or patience for "a few hundred steps")
MODEL_100M = ModelConfig(
    name="demo-100m", family=DENSE, n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab_size=32768, act="swiglu", rope="rope",
)
# ~8M: CPU-sized default so the demo finishes in minutes
MODEL_SMALL = ModelConfig(
    name="demo-8m", family=DENSE, n_layers=6, d_model=256, n_heads=4,
    n_kv_heads=4, d_ff=1024, vocab_size=8192, act="swiglu", rope="rope",
)
MODEL = MODEL_SMALL


def run_plan(label: str, force_mode, steps: int, seq: int, batch: int,
             model=None):
    global MODEL
    MODEL = model or MODEL
    mesh_cfg = MeshConfig((2, 2), ("data", "model"))
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=seq,
                                global_batch=batch)
    osdp = OSDPConfig(force_mode=force_mode,
                      memory_limit_bytes=2 * 2**30,
                      operator_splitting=force_mode is None)
    run = RunConfig(model=MODEL, shape=shape, mesh=mesh_cfg, osdp=osdp)
    plan = make_plan(run)
    mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axes)
    built = build_model(run, plan, mesh)
    ds = Dataset(MODEL, shape, seed=0)
    with jax.set_mesh(mesh):
        step_fn, init_fn = make_train_step(
            built, AdamWConfig(lr=3e-4), warmup=20, donate=False)
        params, opt = init_fn(jax.random.PRNGKey(0))
        losses = []
        t0 = time.perf_counter()
        for s in range(steps):
            b = ds.global_batch(s)
            b = {k: jax.device_put(jnp.asarray(v), NamedSharding(
                mesh, P(("data",), *([None] * (v.ndim - 1)))))
                for k, v in b.items()}
            params, opt, m = step_fn(params, opt, b)
            losses.append(float(m["loss"]))
        dt = time.perf_counter() - t0
    n_zdp = sum(1 for d in plan.decisions.values()
                if d.uniform() not in ("DP", None))
    print(f"{label:6s} loss {losses[0]:.4f} -> {losses[-1]:.4f} | "
          f"{steps / dt:.2f} steps/s | zdp_ops={n_zdp}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the ~100M deliverable config")
    args = ap.parse_args()
    global MODEL
    MODEL = MODEL_100M if args.full else MODEL_SMALL
    print(f"model: {MODEL.name} = {MODEL.param_count() / 1e6:.1f}M params, "
          f"mesh 2x2 (data x model), {args.steps} steps")
    l_dp = run_plan("DP", "DP", args.steps, args.seq, args.batch)
    l_fsdp = run_plan("FSDP", "ZDP", args.steps, args.seq, args.batch)
    l_osdp = run_plan("OSDP", None, args.steps, args.seq, args.batch)
    d = max(abs(a - b) for a, b in zip(l_dp, l_fsdp))
    d2 = max(abs(a - b) for a, b in zip(l_dp, l_osdp))
    print(f"max |loss_DP - loss_FSDP| = {d:.4f}; "
          f"max |loss_DP - loss_OSDP| = {d2:.4f} "
          f"(ZeRO invariant: sharding never changes the math)")


if __name__ == "__main__":
    main()
