"""Fig. 5/6 — end-to-end training throughput: DP vs FSDP vs OSDP(-base).

For every Table-1 model under 8G / 16G memory limits, run the paper's
pipeline (Profiler -> Search Engine -> Scheduler, batch-size sweep
included) for four strategies:

  DP         all-replicated (PyTorch-DDP)
  FSDP       all-ZDP (FairScale / ZeRO-3)
  OSDP-base  searched plan, no operator splitting
  OSDP       searched plan + operator splitting (granularity 4)

and report est. throughput (samples/s) + the OSDP/FSDP speedup the
paper headlines (max 23%/92%/67% on N&D/W&S/2-server). Fig. 6 = the
same on the two-server A100 environment.

``--quick`` runs only the fig5 8-GiB block and asserts it against the
golden rows pinned below (they also pin the depth-2 ClusterSpec
adapter: any drift in flat-topology pricing fails CI here).
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List

from benchmarks.paper_models import (A100_2SERVER, ALL_FAMILIES, IC_SPECS,
                                     MESH_2SERVER, MESH_8GPU, ND_MODELS,
                                     RTX_TITAN_8, WS_MODELS, ic_description,
                                     nd_ws_description, paper_shape)
from repro.configs.base import DeviceInfo, MeshConfig, OSDPConfig
from repro.core.cost_model import CostEnv, DP, ZDP, plan_cost, uniform_plan
from repro.core.search import schedule


def _strategies(mem_gib: float) -> Dict[str, OSDPConfig]:
    """Paper-faithful strategies use only {DP, ZDP} (no hierarchical
    pod mode); OSDP+hier is this repo's beyond-paper variant."""
    lim = mem_gib * 2**30
    return {
        "DP": OSDPConfig(force_mode="DP", memory_limit_bytes=lim,
                         operator_splitting=False,
                         allow_pod_hierarchical=False),
        "FSDP": OSDPConfig(force_mode="ZDP", memory_limit_bytes=lim,
                           operator_splitting=False,
                           allow_pod_hierarchical=False),
        "OSDP-base": OSDPConfig(search="dfs", memory_limit_bytes=lim,
                                operator_splitting=False,
                                allow_pod_hierarchical=False),
        "OSDP": OSDPConfig(search="dfs", memory_limit_bytes=lim,
                           operator_splitting=True,
                           default_slice_granularity=4,
                           allow_pod_hierarchical=False),
        "OSDP+hier": OSDPConfig(search="dfs", memory_limit_bytes=lim,
                                operator_splitting=True,
                                default_slice_granularity=4,
                                allow_pod_hierarchical=True),
    }


def _descriptions(shape):
    out = []
    for cfg in ND_MODELS:
        out.append(("N&D", cfg.name, nd_ws_description(cfg, shape)))
    for cfg in WS_MODELS:
        out.append(("W&S", cfg.name, nd_ws_description(cfg, shape)))
    for name, hiddens in IC_SPECS:
        out.append(("I&C", name, ic_description(name, hiddens, shape)))
    return out


def run_fig(device: DeviceInfo, mesh: MeshConfig, mem_gib: float,
            max_batch: int = 256) -> List[dict]:
    shape = paper_shape(batch=8)
    env = CostEnv(device, mesh, checkpointing=False)
    rows = []
    for family, name, desc in _descriptions(shape):
        row = {"family": family, "model": name, "mem_gib": mem_gib}
        cands = [b for b in (8, 16, 32, 64, 128, 256) if b <= max_batch]
        for strat, osdp in _strategies(mem_gib).items():
            res = schedule(desc, env, osdp, batch_candidates=cands)
            thr = res.cost.throughput if res.feasible else 0.0
            b = res.batch_size if res.feasible else 0
            if strat.startswith("OSDP") and "base" not in strat:
                # the full system picks the better of split / no-split
                res0 = schedule(desc, env, dataclasses.replace(
                    osdp, operator_splitting=False), batch_candidates=cands)
                if res0.feasible and res0.cost.throughput > thr:
                    thr, b = res0.cost.throughput, res0.batch_size
            row[strat] = thr
            row[f"{strat}_b"] = b
        fsdp = row["FSDP"]
        row["osdp_vs_fsdp"] = (row["OSDP"] / fsdp - 1.0) if fsdp else float(
            "inf")
        rows.append(row)
    return rows


def _csv(r: dict) -> str:
    return (f"{r['family']},{r['model']},{r['mem_gib']},"
            f"{r['DP']:.0f},{r['FSDP']:.0f},{r['OSDP-base']:.0f},"
            f"{r['OSDP']:.0f},{r['OSDP+hier']:.0f},"
            f"{100 * r['osdp_vs_fsdp']:.1f}")


# fig5 @ 8 GiB golden rows (pre-topology HEAD; pins the depth-2
# ClusterSpec adapter byte-for-byte at print precision)
GOLDEN_8GIB = [
    "N&D,nd-48x1024,8,0,36034,37100,37100,37100,3.0",
    "N&D,nd-64x1280,8,0,8915,9132,12983,12983,45.6",
    "N&D,nd-96x1536,8,0,0,0,0,0,inf",
    "W&S,ws-2x6144,8,0,31236,31485,35832,35832,14.7",
    "W&S,ws-3x8192,8,0,0,0,4657,4657,inf",
    "W&S,ws-4x12288,8,0,0,0,0,0,inf",
    "I&C,ic-24,8,0,0,0,8779,8779,inf",
    "I&C,ic-48,8,0,0,0,0,0,inf",
    "I&C,ic-96,8,0,0,0,0,0,inf",
]


def main(out=print, quick: bool = False) -> List[dict]:
    out("fig,family,model,mem_gib,DP,FSDP,OSDP-base,OSDP,OSDP+hier,"
        "osdp_vs_fsdp_pct")
    all_rows = []
    figs = ((("fig5", RTX_TITAN_8, MESH_8GPU, (8,)),) if quick else
            (("fig5", RTX_TITAN_8, MESH_8GPU, (8, 16)),
             ("fig6", A100_2SERVER, MESH_2SERVER, (16,))))
    for fig, device, mesh, mems in figs:
        for mem in mems:
            for r in run_fig(device, mesh, mem):
                out(f"{fig},{_csv(r)}")
                r["fig"] = fig
                all_rows.append(r)
    if quick:
        got = [_csv(r) for r in all_rows]
        bad = [(g, w) for g, w in zip(got, GOLDEN_8GIB) if g != w]
        if bad or len(got) != len(GOLDEN_8GIB):
            lines = "\n".join(f"  got  {g}\n  want {w}" for g, w in bad)
            raise SystemExit(
                f"fig5 8-GiB golden rows drifted:\n{lines}")
        out("# quick check passed: 8-GiB rows match the golden pins")
    return all_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="8-GiB fig5 block only, asserted against the "
                         "golden rows")
    main(quick=ap.parse_args().quick)
