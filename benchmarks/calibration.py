"""Predicted-vs-measured step times: make the planner falsifiable.

    PYTHONPATH=src:. python benchmarks/calibration.py [--quick] [--check]

The loop every other benchmark in this repo cannot close: those
compare *predicted* step times between plans; this one runs `repro
calibrate` against the actual backend (CPU fake devices), re-solves
the same search under (a) the assumed datasheet-style constants and
(b) the fitted CalibrationProfile, then executes real jit'd train
steps for the chosen plans and records per-row relative error of both
models against the measured wall clock.

Committed to the "calibration" section of BENCH_search.json:

  * the fitted constants (efficiency-curve range, link alpha/bw,
    remat factor) and how far they sit from the datasheet guesses,
  * per row: predicted (assumed), predicted (calibrated), measured
    step seconds, both relative errors, and whether calibration
    flipped the planner's decision,
  * headline: calibration must flip >= 1 plan, and every calibrated
    prediction must land within ERR_CEILING of the measured step.

`--check` asserts those claims (CI gate).  Measured numbers calibrate
the CPU emulation backend, so absolute times are machine-dependent;
the *claims* (flip count, error ceilings) are what CI pins.  Both
medians are recorded but their ordering is not asserted: the analytic
model omits optimizer/dispatch overhead, and on CPU emulation the
assumed model's inflated compute (scalar 0.55 efficiency) can
accidentally compensate for it run-to-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import time

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_search.json"

N_FAKE_DEVICES = 4
MEASURE_STEPS = 5
# predicted-vs-measured ceiling for the calibrated model: the analytic
# model omits optimizer/runtime overhead entirely, so parity within a
# small factor is the honest bar on an emulation backend (the assumed
# datasheet constants are orders of magnitude off; see the rows)
ERR_CEILING = 3.0
CEILING_S = 420.0

CASES = [
    # (name, arch, seq, batch_candidates, checkpointing, mem_frac_of_dp)
    # memory fractions chosen so the search sits at a sharding/remat
    # threshold: the fitted constants (alpha ~100x the datasheet guess,
    # a size-dependent efficiency curve instead of a scalar) reorder
    # the candidate covers there and the plan choice flips
    ("qwen-global-ckpt", "qwen1.5-0.5b", 128, (2, 4, 8, 16), True, 0.7),
    ("phi4-global-ckpt", "phi4-mini-3.8b", 128, (2, 4, 8, 16), True, 0.6),
    ("mamba2-selective", "mamba2-2.7b", 128, (2, 4, 8, 16), "selective",
     0.6),
]


def _plan_sig(res):
    return {k: (d.modes, d.remat) for k, d in res.decisions.items()}


def _batch(cfg, B, S, key=0):
    import jax
    k = jax.random.PRNGKey(key)
    return {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }


def _measure_plan(run, plan, mesh, cfg):
    """Median wall-clock of a real jit'd train step for `plan`."""
    import jax
    from repro.models.registry import build_model
    from repro.train.loop import make_train_step

    built = build_model(run, plan, mesh)
    step, init = make_train_step(built, donate=True)
    params, opt = init(jax.random.PRNGKey(0))
    batch = _batch(cfg, run.shape.global_batch, run.shape.seq_len)
    # one warmup step: compile + donation plumbing
    params, opt, _ = step(params, opt, batch)
    jax.block_until_ready(params)
    times = []
    for _ in range(MEASURE_STEPS):
        t0 = time.perf_counter()
        params, opt, _ = step(params, opt, batch)
        jax.block_until_ready(params)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _run_case(name, arch, seq, batches, ckpt, mem_frac, device, profile,
              mesh, mesh_cfg):
    from repro.configs import OSDPConfig, RunConfig, get_arch, get_shape, \
        reduced
    from repro.core.cost_model import CostEnv, DP, plan_cost, uniform_plan
    from repro.core.descriptions import describe
    from repro.core.plan import Plan
    from repro.core.search import schedule

    cfg = reduced(get_arch(arch))
    shp = dataclasses.replace(get_shape("train_4k"), seq_len=seq,
                              global_batch=batches[0])
    desc = describe(cfg, shp)

    # memory limit pegged to the all-DP footprint at the middle batch
    # so the search has a real sharding decision to make
    osdp_probe = OSDPConfig(enabled=True,
                            memory_limit_bytes=float("inf"),
                            checkpointing=ckpt)
    env_asm = CostEnv(device, mesh_cfg,
                      checkpointing=osdp_probe.env_checkpointing)
    env_cal = CostEnv(device, mesh_cfg,
                      checkpointing=osdp_probe.env_checkpointing,
                      profile=profile)
    dp_mem = plan_cost(desc, uniform_plan(desc, DP),
                       batches[len(batches) // 2], env_asm).memory
    limit = dp_mem * mem_frac
    osdp = dataclasses.replace(osdp_probe, memory_limit_bytes=limit)

    # same search, two cost models: assumed datasheet constants vs the
    # fitted profile; batch AND sharding/remat are both up for grabs
    res_asm = schedule(desc, env_asm, osdp, batch_candidates=list(batches))
    res_cal = schedule(desc, env_cal, osdp, batch_candidates=list(batches))
    flip = (res_asm.batch_size != res_cal.batch_size
            or _plan_sig(res_asm) != _plan_sig(res_cal))

    def run_for(res):
        s = dataclasses.replace(shp, global_batch=res.batch_size)
        return RunConfig(model=cfg, shape=s, mesh=mesh_cfg, osdp=osdp)

    run_cal = run_for(res_cal)
    plan_cal = Plan(run_cal, desc, res_cal.decisions, res_cal.cost, res_cal)
    measured = _measure_plan(run_cal, plan_cal, mesh, cfg)
    # both models predict THE SAME executed plan: the calibrated pick
    # at its chosen batch (apples-to-apples against one measurement)
    pred_cal = res_cal.cost.time
    pred_assumed = plan_cost(desc, res_cal.decisions, res_cal.batch_size,
                             env_asm).time
    row = {
        "arch": arch, "seq": seq,
        "batch_candidates": list(batches),
        "checkpointing": str(ckpt),
        "memory_limit_mib": round(limit / 2**20, 1),
        "plan_flip": flip,
        "batch_assumed": res_asm.batch_size,
        "batch_calibrated": res_cal.batch_size,
        "predicted_assumed_ms": round(pred_assumed * 1e3, 3),
        "predicted_calibrated_ms": round(pred_cal * 1e3, 3),
        "measured_ms": round(measured * 1e3, 3),
        "rel_err_assumed": round(abs(pred_assumed - measured) / measured, 4),
        "rel_err_calibrated": round(abs(pred_cal - measured) / measured, 4),
        "measured_tok_per_s": round(
            res_cal.batch_size * seq / measured, 1),
    }
    if flip:
        # the flip is falsifiable: run the assumed-constants pick too
        # and compare achieved throughput
        run_asm = run_for(res_asm)
        plan_asm = Plan(run_asm, desc, res_asm.decisions, res_asm.cost,
                        res_asm)
        measured_asm = _measure_plan(run_asm, plan_asm, mesh, cfg)
        row["measured_assumed_plan_ms"] = round(measured_asm * 1e3, 3)
        row["assumed_plan_tok_per_s"] = round(
            res_asm.batch_size * seq / measured_asm, 1)
    return name, row


def main(out=print, quick: bool = False, check: bool = False,
         json_path=JSON_PATH) -> dict:
    t_start = time.perf_counter()

    # fake devices must be configured before the first jax import
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={N_FAKE_DEVICES}")
    import jax
    from repro.calibrate import bench, fit
    from repro.calibrate.profile import CalibrationProfile
    from repro.configs import DeviceInfo, MeshConfig

    n_dev = len(jax.devices())
    mesh_cfg = MeshConfig((n_dev, 1), ("data", "model"))
    mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axes)

    # --- calibrate this backend ------------------------------------------
    repeats = 2 if quick else 3
    mm = bench.matmul_sweep((64, 128, 256, 512) if quick
                            else (64, 128, 256, 512, 1024),
                            repeats=repeats)
    peak = bench.measured_peak_flops(mm)
    curve = fit.fit_efficiency_curve(mm, peak_flops=peak)
    sweeps = bench.collective_sweep(mesh, (0.25, 1.0, 4.0),
                                    repeats=repeats)
    links = fit.fit_link_calibrations(sweeps)
    t_plain, t_remat = bench.remat_sweep(repeats=repeats)
    remat = fit.fit_remat_factor(t_plain, t_remat)
    profile = CalibrationProfile(
        device="host-cpu", efficiency=curve, links=links,
        remat_factor=remat, peak_flops=peak, source="benchmarks/calibration")
    assert CalibrationProfile.from_json(profile.to_json()) == profile

    # the assumed model: datasheet-style guesses for this backend —
    # measured peak (there is no CPU datasheet) but the hand-set
    # scalar efficiency, link bandwidths, and 1.30 remat factor
    device = dataclasses.replace(
        DeviceInfo(), name="host-cpu", peak_flops=peak,
        hbm_bytes=8 * 2**30)

    link = links[0] if links else None
    constants = {
        "measured_peak_flops": round(peak, 1),
        "efficiency_fraction_range": [round(curve.fraction[0], 4),
                                      round(curve.fraction[-1], 4)],
        "assumed_efficiency": device.mxu_efficiency,
        "fitted_alpha_s": round(link.alpha, 8) if link else None,
        "assumed_alpha_s": device.alpha,
        "fitted_bandwidth_bytes_per_s": round(link.bandwidth, 1)
        if link else None,
        "assumed_bandwidth_bytes_per_s": device.ici_bw,
        "fitted_remat_factor": round(remat, 4),
        "assumed_remat_factor": 1.30,
    }
    out("# fitted constants: " + json.dumps(constants))

    rows = {}
    for case in CASES:
        name, row = _run_case(*case, device, profile, mesh, mesh_cfg)
        rows[name] = row
        out(f"{name}: flip={row['plan_flip']} "
            f"meas={row['measured_ms']}ms "
            f"pred_cal={row['predicted_calibrated_ms']}ms "
            f"(err {row['rel_err_calibrated']}) "
            f"pred_assumed={row['predicted_assumed_ms']}ms "
            f"(err {row['rel_err_assumed']})")

    flips = sum(1 for r in rows.values() if r["plan_flip"])
    errs_cal = sorted(r["rel_err_calibrated"] for r in rows.values())
    errs_asm = sorted(r["rel_err_assumed"] for r in rows.values())
    median_cal = errs_cal[len(errs_cal) // 2]
    median_asm = errs_asm[len(errs_asm) // 2]
    seconds = time.perf_counter() - t_start
    section = {
        "constants": constants,
        "rows": rows,
        "flips": flips,
        "median_rel_err_calibrated": median_cal,
        "median_rel_err_assumed": median_asm,
        "n_fake_devices": n_dev,
        "quick": quick,
        "seconds": round(seconds, 1),
    }
    out(f"# flips={flips} median_err cal={median_cal} "
        f"assumed={median_asm} ({seconds:.0f}s)")

    doc = {}
    if json_path is not None:
        path = pathlib.Path(json_path)
        if path.exists():
            doc = json.loads(path.read_text())
        doc["calibration"] = section
        path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        out(f"# wrote {path}")

    if check:
        if flips < 1:
            raise SystemExit(
                "calibration check FAILED: no row flipped the plan "
                "choice under the fitted constants")
        bad = {n: r["rel_err_calibrated"] for n, r in rows.items()
               if r["rel_err_calibrated"] > ERR_CEILING}
        if bad:
            raise SystemExit(
                f"calibration check FAILED: rows over the "
                f"{ERR_CEILING}x relative-error ceiling: {bad}")
        if seconds > CEILING_S:
            raise SystemExit(
                f"calibration check FAILED: took {seconds:.0f}s "
                f"(ceiling {CEILING_S:.0f}s)")
        out("# calibration check passed: >=1 flip, every row under "
            "the error ceiling")
    return section


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick, check=args.check)
