"""Selective per-slice activation checkpointing vs the global switch.

Sweeps the assigned stacked architectures x memory limits on the TPU
v5e cost environment and compares three remat policies at equal
memory limits and equal batch candidates:

  remat-off    OSDP search, checkpointing=False (keep all activations)
  remat-on     OSDP search, checkpointing=True  (remat everything)
  selective    OSDP search, checkpointing="selective" — remat is a
               per-slice decision co-optimized with DP/ZDP sharding
               and operator splitting (the 4-mode axis)

Headline (asserted here and in tests/test_selective_remat.py):
selective >= max(remat-on, remat-off) on every row, and models that
are INFEASIBLE with remat-off and merely survive with remat-on become
feasible AND faster with the mixed plan — the row's `plan` column
shows how many slices the search chose to remat.

Run:  PYTHONPATH=src:. python benchmarks/selective_remat.py
"""
from __future__ import annotations

import argparse
from typing import List

from repro.configs import DeviceInfo, SINGLE_POD_MESH, OSDPConfig, \
    get_arch, get_shape
from repro.configs.base import SELECTIVE
from repro.core.cost_model import CostEnv, count_remat_slices
from repro.core.descriptions import describe
from repro.core.search import schedule

MODELS = ("qwen1.5-0.5b", "phi4-mini-3.8b", "mamba2-2.7b", "hymba-1.5b",
          "dbrx-132b")
LIMITS_GIB = (2, 3, 4, 6, 10, 14)
BATCHES = (256,)


def _sched(desc, env, lim, checkpointing):
    return schedule(desc, env, OSDPConfig(
        memory_limit_bytes=lim, checkpointing=checkpointing,
        operator_splitting=True, default_slice_granularity=4,
        allow_pod_hierarchical=False), batch_candidates=BATCHES)


def main(out=print, models=MODELS, limits=LIMITS_GIB) -> List[dict]:
    device = DeviceInfo()
    env_on = CostEnv(device, SINGLE_POD_MESH, checkpointing=True)
    env_off = CostEnv(device, SINGLE_POD_MESH, checkpointing=False)
    out("model,mem_gib,off_Mtok_s,on_Mtok_s,selective_Mtok_s,"
        "remat_slices,total_slices,verdict")
    rows: List[dict] = []
    flips = 0
    for name in models:
        desc = describe(get_arch(name), get_shape("train_4k"))
        for gib in limits:
            lim = gib * 2**30
            off = _sched(desc, env_off, lim, False)
            on = _sched(desc, env_on, lim, True)
            sel = _sched(desc, env_off, lim, SELECTIVE)
            t_off = off.cost.throughput if off.feasible else 0.0
            t_on = on.cost.throughput if on.feasible else 0.0
            t_sel = sel.cost.throughput if sel.feasible else 0.0
            best = max(t_on, t_off)
            assert t_sel >= best * (1 - 1e-9), (
                f"{name}@{gib}G: selective {t_sel:.0f} < {best:.0f}")
            n_remat = count_remat_slices(sel.decisions)
            n_total = sum(len(d.remat) for d in sel.decisions.values()
                          if d.remat is not None)
            if t_off == 0.0 and t_on > 0.0 and t_sel > t_on * (1 + 1e-9):
                verdict = "FLIP: off infeasible, on slower, mixed wins"
                flips += 1
            elif 0 < n_remat < n_total:
                verdict = "mixed"
            elif t_sel == 0.0:
                verdict = "infeasible"
            else:
                verdict = "uniform"
            out(f"{name},{gib},{t_off / 1e6:.2f},{t_on / 1e6:.2f},"
                f"{t_sel / 1e6:.2f},{n_remat},{n_total},{verdict}")
            rows.append({"model": name, "mem": gib, "off": t_off,
                         "on": t_on, "selective": t_sel,
                         "remat_slices": n_remat, "flip":
                         verdict.startswith("FLIP")})
    assert flips > 0, "expected at least one infeasible->faster flip"
    out(f"# selective >= max(on, off) on every row (asserted); "
        f"{flips} rows flip from infeasible(off)/slower(on) to "
        f"feasible-and-faster")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.parse_args()
    main()
