"""Fig. 7 — operator splitting impact on per-operator memory and time.

Sweeps hidden sizes {768, 1024, 8192, 12288} x slice granularity
{0(=off),2,4,8,16} on single MatMul operators in ZDP mode and reports:
  * per-device memory (model states/N + gathered slice) — the paper
    observes up to 50% reduction,
  * per-op step time — alpha-dominated for small hidden sizes (larger g
    hurts), beta-dominated for large ones (g irrelevant, memory wins).

Both numbers come from the cost model AND from a real measured
`chunked_matmul` on CPU (time shape only; scaled hardware belongs to
the dry-run).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8
from repro.configs.base import OSDPConfig
from repro.core.cost_model import CostEnv, Decision, ZDP, op_cost
from repro.core.descriptions import OperatorDesc
from repro.core.operator_split import chunked_matmul

HIDDENS = (768, 1024, 8192, 12288)
GRANULARITIES = (0, 2, 4, 8, 16)


def cost_rows() -> List[dict]:
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False)
    rows = []
    for h in HIDDENS:
        op = OperatorDesc(f"matmul_{h}", 4 * h * h, 2.0 * 4 * h * h,
                          4 * h * 2, splittable=True)
        for g in GRANULARITIES:
            modes = (ZDP,) * max(1, g)
            c = op_cost(op, Decision(op.name, modes), 8, 1024, env)
            rows.append({"hidden": h, "g": g,
                         "mem_mib": c.memory / 2**20,
                         "time_ms": c.time * 1e3})
    return rows


def measured_rows(reps: int = 3) -> List[dict]:
    """Real chunked_matmul wall times on CPU (shape of the time curve)."""
    rows = []
    for h in (768, 1024):            # CPU-sized subset
        x = jax.random.normal(jax.random.PRNGKey(0), (256, h), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (h, 4 * h), jnp.float32)
        for g in GRANULARITIES:
            f = jax.jit(lambda x, w, g=max(1, g): chunked_matmul(x, w, g))
            f(x, w).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                f(x, w).block_until_ready()
            dt = (time.perf_counter() - t0) / reps
            rows.append({"hidden": h, "g": g, "cpu_us": dt * 1e6})
    return rows


def main(out=print) -> List[dict]:
    rows = cost_rows()
    out("hidden,granularity,mem_mib,time_ms")
    for r in rows:
        out(f"{r['hidden']},{r['g']},{r['mem_mib']:.1f},{r['time_ms']:.3f}")
    out("# measured chunked_matmul (CPU wall time)")
    out("hidden,granularity,cpu_us")
    for r in measured_rows():
        out(f"{r['hidden']},{r['g']},{r['cpu_us']:.0f}")
    # headline check: memory reduction at h=12288, g=16 vs g=0
    m0 = next(r for r in rows if r["hidden"] == 12288 and r["g"] == 0)
    m16 = next(r for r in rows if r["hidden"] == 12288 and r["g"] == 16)
    out(f"# memory reduction @12288/g16: "
        f"{100 * (1 - m16['mem_mib'] / m0['mem_mib']):.1f}%")
    return rows


if __name__ == "__main__":
    main()
