"""Overlap sweep — what the serial (no-overlap) cost model costs.

Re-scores model-zoo x topology cases under the two-resource timeline
model (`ClusterLevel.overlap`, PR: comm/compute overlap).  For each
case two planners run on the SAME hardware:

  serial  — today's model: every collective serializes with compute
            (all overlap factors 0);
  overlap — the timeline model: each level hides `overlap` of its
            communication under compute, per
            T = T_comp + sum_l max(0, comm_l - ov_l * T_comp).

Both plans are then re-scored under the *overlap-aware* ground truth,
so the rows answer: "what did planning against the serial model cost
on hardware that overlaps?"  Two row kinds show up:

  * flip rows — the overlap-aware planner picks a different plan
    (bigger batch now that its comm hides, a different remat mix, a
    different ZDP span) that genuinely beats the serial plan;
  * tie rows  — the argmin is overlap-invariant (uniform overlap
    scales every candidate's exposed comm together); throughput still
    improves, the *decision* doesn't.  Kept honestly as wins=False.

Uniform overlap mostly produces tie rows; the flips come from
selective-remat spaces (hidden comm frees time the remat search
re-spends) and per-level differentiated overlap (ICI hides well, DCI
doesn't — flipping which span ZDP shards over and the batch argmax).

Results land in ``BENCH_search.json`` under ``"overlap"``.
``--quick`` runs the CI subset; ``--check`` asserts >= 2 flip-win rows
and the wall-clock ceiling.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Union

from repro.cluster.topology import ClusterSpec, gpu_cluster, tpu_multipod
from repro.configs import DeviceInfo, OSDPConfig, get_arch, get_shape
from repro.core.cost_model import CostEnv, PlanEvaluator
from repro.core.descriptions import ModelDescription, describe
from repro.core.search import schedule

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"
CEILING_S = 150.0          # --check wall-clock ceiling (quick subset)
EPS = 1e-6                 # strict-win threshold

Overlap = Union[float, Dict[str, float]]


def _true_cost(desc: ModelDescription, decisions, batch: int,
               spec: ClusterSpec, env_ck):
    """Score a plan under the overlap-aware (or serial) ground truth."""
    env = CostEnv(spec.device, cluster=spec, checkpointing=env_ck)
    ev = PlanEvaluator.for_decisions(desc, env, decisions)
    return ev.plan_cost(ev.modes_from_decisions(decisions), batch)


def _plan_sig(plan) -> dict:
    return {k: (d.modes, d.remat) for k, d in plan.decisions.items()}


def _run_case(name: str, desc: ModelDescription, spec: ClusterSpec,
              limit_bytes: float, batches: List[int], ov: Overlap,
              selective: bool = False, out=print) -> dict:
    env_ck = False if selective else True
    osdp = OSDPConfig(memory_limit_bytes=limit_bytes,
                      checkpointing="selective" if selective else True)
    spec_ov = spec.with_overlap(ov)
    t0 = time.perf_counter()
    serial = schedule(desc, CostEnv(spec.device, cluster=spec,
                                    checkpointing=env_ck),
                      osdp, batch_candidates=batches)
    over = schedule(desc, CostEnv(spec_ov.device, cluster=spec_ov,
                                  checkpointing=env_ck),
                    osdp, batch_candidates=batches)
    dt = time.perf_counter() - t0

    # ground truth: both plans under the overlap-aware timeline; the
    # serial plan also under its own (serial) model so the row separates
    # "overlap sped the same plan up" from "replanning won on top"
    true_serial = _true_cost(desc, serial.decisions, serial.batch_size,
                             spec_ov, env_ck)
    true_over = _true_cost(desc, over.decisions, over.batch_size,
                           spec_ov, env_ck)
    serial_own = _true_cost(desc, serial.decisions, serial.batch_size,
                            spec, env_ck)
    differs = (serial.batch_size != over.batch_size
               or _plan_sig(serial) != _plan_sig(over))
    win = bool(differs
               and true_over.throughput > true_serial.throughput * (1 + EPS))
    row = {
        "kind": "schedule", "cluster": spec.summary(),
        "model": desc.model.name, "n_devices": spec.n_devices,
        "overlap": ov, "selective": selective,
        "serial_batch": serial.batch_size, "overlap_batch": over.batch_size,
        "serial_model_tok_s": round(serial_own.throughput, 1),
        "serial_tok_s": round(true_serial.throughput, 1),
        "overlap_tok_s": round(true_over.throughput, 1),
        "plan_differs": bool(differs), "overlap_win": win,
        "seconds": round(dt, 3),
    }
    out(f"{name},{desc.model.name},{spec.n_devices},ov={ov},"
        f"{true_serial.throughput:.0f},{true_over.throughput:.0f},"
        f"differs={differs},win={win}")
    return row


# --- the sweep ---------------------------------------------------------------

def _cases(quick: bool, device: Optional[str] = None,
           extra_overlap: Optional[float] = None):
    """(name, runner) pairs; each runner returns a result row."""
    dev = DeviceInfo.preset(device) if device else DeviceInfo()
    a100 = DeviceInfo.preset("a100-80g")
    h100 = DeviceInfo.preset("h100-sxm")
    shape = get_shape("train_4k")
    llama = describe(get_arch("llama3-405b"), shape)
    arctic = describe(get_arch("arctic-480b"), shape)

    spec_tpu = tpu_multipod(4, 64, dev)
    spec_spine = gpu_cluster(64, 8, device=h100, nvlink_bw=450e9,
                             ib_bw=50e9, spine_nodes=8, spine_bw=12.5e9)
    cases = []

    def add(name, desc, spec, lim_gib, batches, ov, selective=False):
        cases.append((name, lambda out: _run_case(
            name, desc, spec, lim_gib * 2**30, batches, ov,
            selective=selective, out=out)))

    # selective-remat spaces: hidden gather time frees step time the
    # remat search re-spends on keeping activations -> plan flips at
    # high overlap even when the factor is uniform
    for ov in (0.5, 0.9):
        add(f"tpu-llama405-sel-{ov}", llama, spec_tpu, 100,
            [128, 256, 512], ov, selective=True)
        add(f"spine-arctic-sel-{ov}", arctic, spec_spine, 60,
            [128, 256, 512], ov, selective=True)

    # per-level differentiated overlap on the TPU multipod: hiding only
    # the intra-pod (ICI) gathers flips the batch argmax; hiding only
    # the cross-pod (DCI) traffic flips which span ZDP shards over
    add("tpu-llama405-ici0.9", llama, spec_tpu, 128, [128, 256, 512],
        {"data": 0.9})
    add("tpu-llama405-dci0.9", llama, spec_tpu, 128, [128, 256, 512],
        {"pod": 0.9})

    if not quick:
        # uniform-overlap tie rows: throughput moves, the argmin
        # doesn't (uniform hiding scales all candidates together)
        spec_slow = gpu_cluster(32, 8, device=a100, nvlink_bw=300e9,
                                ib_bw=12.5e9)
        for ov in (0.5, 0.9):
            add(f"spine-arctic-{ov}", arctic, spec_spine, 72,
                [256, 512, 1024], ov)
            add(f"slow-llama405-{ov}", llama, spec_slow, 76,
                [128, 256, 512], ov)
            add(f"slow-dbrx-sel-{ov}",
                describe(get_arch("dbrx-132b"), shape), spec_slow, 30,
                [128, 256, 512], ov, selective=True)

    if extra_overlap is not None:
        add(f"tpu-llama405-sel-x{extra_overlap}", llama, spec_tpu, 100,
            [128, 256, 512], float(extra_overlap), selective=True)
    return cases


def main(out=print, quick: bool = False, check: bool = False,
         json_path: Optional[Path] = None, device: Optional[str] = None,
         overlap: Optional[float] = None) -> dict:
    path = Path(json_path) if json_path else JSON_PATH
    out("case,model,n_devices,overlap,serial_tok_s,overlap_tok_s,notes")
    t0 = time.perf_counter()
    rows: Dict[str, dict] = {}
    for name, runner in _cases(quick, device, overlap):
        rows[name] = runner(out)
    elapsed = time.perf_counter() - t0

    flip_wins = sum(1 for r in rows.values() if r["overlap_win"])
    out(f"# {len(rows)} cases, {flip_wins} overlap plan-flip wins, "
        f"{elapsed:.1f}s")

    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    doc["overlap"] = {"rows": rows, "flip_wins": flip_wins,
                      "quick": quick, "seconds": round(elapsed, 3)}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    out(f"# wrote {path}")

    if check:
        if flip_wins < 2:
            raise SystemExit(
                f"overlap-aware planning flipped-and-won only "
                f"{flip_wins} cases (< 2)")
        if elapsed > CEILING_S:
            raise SystemExit(
                f"sweep took {elapsed:.1f}s (ceiling {CEILING_S:.0f}s)")
        out("# check passed: >= 2 flip wins, within ceiling")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI subset (6 cases)")
    ap.add_argument("--check", action="store_true",
                    help="assert >= 2 flip wins and the ceiling")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="base DeviceInfo preset for the TPU fleet "
                         "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
    ap.add_argument("--overlap", type=float, default=None,
                    help="extra uniform overlap factor to add to the "
                         "sweep grid")
    a = ap.parse_args()
    main(quick=a.quick, check=a.check, json_path=a.json, device=a.device,
         overlap=a.overlap)
