"""Fig. 9 — OSDP vs FSDP with activation checkpointing enabled.

Under remat, ZDP pays a 4th parameter all-gather for the recompute
pass (§4.3) while DP recomputes from local weights — so OSDP's
advantage over FSDP grows (paper: up to 108.3%, avg 52.9%).

Beyond the paper's global on/off switch, the third axis searches remat
per slice jointly with the sharding mode (`checkpointing="selective"`,
the 4-mode axis): every row asserts that the mixed plan's throughput
dominates BOTH global settings at the same memory limit, and rows
where remat-off is infeasible while remat-on merely survives flip to
feasible-and-faster.  The legacy FSDP_ckpt / OSDP_ckpt columns are
computed exactly as before (byte-identical; pinned by
tests/test_selective_remat.py).

Run:  PYTHONPATH=src:. python benchmarks/fig9_checkpointing.py [--quick]
"""
from __future__ import annotations

import argparse
from typing import List

from benchmarks.fig5_end_to_end import _descriptions
from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8, paper_shape
from repro.configs.base import OSDPConfig, SELECTIVE
from repro.core.cost_model import CostEnv
from repro.core.search import schedule

BATCHES = (8, 16, 32, 64, 128, 256)


def main(out=print, quick: bool = False) -> List[dict]:
    shape = paper_shape(8)
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=True)
    env_off = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False)
    out("family,model,mem_gib,FSDP_ckpt,OSDP_ckpt,speedup_pct,"
        "OSDP_nockpt,OSDP_selective,sel_vs_best_pct")
    rows = []
    speedups = []
    flips = []
    descs = _descriptions(shape)
    if quick:
        seen = set()
        descs = [d for d in descs
                 if d[0] not in seen and not seen.add(d[0])]
    for mem in ((8,) if quick else (8, 16)):
        lim = mem * 2**30
        for family, name, desc in descs:
            fsdp = schedule(desc, env, OSDPConfig(
                force_mode="ZDP", memory_limit_bytes=lim,
                operator_splitting=False, allow_pod_hierarchical=False,
                checkpointing=True), batch_candidates=BATCHES)
            osdp = schedule(desc, env, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                default_slice_granularity=4, allow_pod_hierarchical=False,
                checkpointing=True), batch_candidates=BATCHES)
            nock = schedule(desc, env_off, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                default_slice_granularity=4, allow_pod_hierarchical=False,
                checkpointing=False), batch_candidates=BATCHES)
            sel = schedule(desc, env_off, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                default_slice_granularity=4, allow_pod_hierarchical=False,
                checkpointing=SELECTIVE), batch_candidates=BATCHES)
            t_f = fsdp.cost.throughput if fsdp.feasible else 0.0
            t_o = osdp.cost.throughput if osdp.feasible else 0.0
            t_n = nock.cost.throughput if nock.feasible else 0.0
            t_s = sel.cost.throughput if sel.feasible else 0.0
            best = max(t_o, t_n)
            assert t_s >= best * (1 - 1e-9), (
                f"{name}@{mem}G: selective {t_s:.0f} < "
                f"max(ckpt {t_o:.0f}, no-ckpt {t_n:.0f})")
            if t_n == 0.0 and t_o > 0.0 and t_s > t_o * (1 + 1e-9):
                flips.append(f"{name}@{mem}G")
            sp = (t_o / t_f - 1) * 100 if t_f else float("inf")
            if t_f and t_o:
                speedups.append(sp)
            gain = (t_s / best - 1) * 100 if best else float("inf")
            out(f"{family},{name},{mem},{t_f:.0f},{t_o:.0f},{sp:.1f},"
                f"{t_n:.0f},{t_s:.0f},{gain:.1f}")
            rows.append({"family": family, "model": name, "mem": mem,
                         "fsdp": t_f, "osdp": t_o, "nockpt": t_n,
                         "selective": t_s})
    if speedups:
        out(f"# avg OSDP-vs-FSDP speedup with ckpt: "
            f"{sum(speedups) / len(speedups):.1f}% "
            f"(max {max(speedups):.1f}%) — paper: avg 52.9%, max 108.3%")
    out("# selective remat >= max(global on, global off) on every row "
        "(asserted)")
    if flips:
        out("# infeasible(remat-off) & slower(remat-on) -> "
            "feasible-and-faster mixed: " + ", ".join(flips))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="one model per family, 8 GiB only (CI smoke)")
    a = ap.parse_args()
    main(quick=a.quick)
