"""Fig. 9 — OSDP vs FSDP with activation checkpointing enabled.

Under remat, ZDP pays a 4th parameter all-gather for the recompute
pass (§4.3) while DP recomputes from local weights — so OSDP's
advantage over FSDP grows (paper: up to 108.3%, avg 52.9%).
"""
from __future__ import annotations

from typing import List

from benchmarks.fig5_end_to_end import _descriptions
from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8, paper_shape
from repro.configs.base import OSDPConfig
from repro.core.cost_model import CostEnv
from repro.core.search import schedule


def main(out=print) -> List[dict]:
    shape = paper_shape(8)
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=True)
    out("family,model,mem_gib,FSDP_ckpt,OSDP_ckpt,speedup_pct")
    rows = []
    speedups = []
    for mem in (8, 16):
        lim = mem * 2**30
        for family, name, desc in _descriptions(shape):
            fsdp = schedule(desc, env, OSDPConfig(
                force_mode="ZDP", memory_limit_bytes=lim,
                operator_splitting=False, allow_pod_hierarchical=False,
                checkpointing=True), batch_candidates=(8, 16, 32, 64, 128, 256))
            osdp = schedule(desc, env, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                default_slice_granularity=4, allow_pod_hierarchical=False,
                checkpointing=True), batch_candidates=(8, 16, 32, 64, 128, 256))
            t_f = fsdp.cost.throughput if fsdp.feasible else 0.0
            t_o = osdp.cost.throughput if osdp.feasible else 0.0
            sp = (t_o / t_f - 1) * 100 if t_f else float("inf")
            if t_f and t_o:
                speedups.append(sp)
            out(f"{family},{name},{mem},{t_f:.0f},{t_o:.0f},{sp:.1f}")
            rows.append({"family": family, "model": name, "mem": mem,
                         "fsdp": t_f, "osdp": t_o})
    if speedups:
        out(f"# avg OSDP-vs-FSDP speedup with ckpt: "
            f"{sum(speedups) / len(speedups):.1f}% "
            f"(max {max(speedups):.1f}%) — paper: avg 52.9%, max 108.3%")
    return rows


if __name__ == "__main__":
    main()
