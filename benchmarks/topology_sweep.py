"""Topology sweep — what the flat two-bandwidth device model costs.

Sweeps 64–512 devices over TPU multi-pod, GPU NVLink/IB (2- and
3-level), and mixed-memory topologies.  For each case two planners run
on the SAME hardware:

  flat  — the pre-topology model: the hierarchy collapsed to
          (ici, dci) + a pod axis (`ClusterSpec.to_flat`), full-span
          collectives priced at the bottleneck bandwidth, uniform
          per-device memory (the worst device's), TP priced on ici
          unconditionally (the legacy hybrid path);
  topo  — the hierarchical `ClusterSpec`: per-level ring pricing,
          level-k ZDP items, capacity-weighted heterogeneous sharding,
          TP/PP placed innermost/outermost.

Both plans are then re-scored under the *hierarchical* model (the
ground truth this repo can state), so the rows answer: "what did
planning against the flat model actually cost?"  Three failure classes
show up:

  * mispriced  — the flat model's bottleneck pricing picks a slower
    sharding mix (e.g. avoids full-span ZDP that is actually cheap, or
    picks a smaller batch);
  * misplaced  — the flat hybrid path puts TP across a node boundary
    (charged ici, pays IB) or cannot express rack-level ZDP@k;
  * infeasible — uniform worst-device memory + even sharding rejects
    fleets a capacity-weighted plan fits.

Results land in ``BENCH_search.json`` under ``"topology"``.
``--quick`` runs the CI subset; ``--check`` asserts the headline
claims (>= 2 strict topology wins, >= 1 heterogeneous feasibility
flip) and the wall-clock ceiling.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.cluster.topology import (ClusterSpec, gpu_cluster,
                                    mixed_memory_fleet, tpu_multipod)
from repro.configs import DeviceInfo, MeshConfig, OSDPConfig, get_arch, \
    get_shape
from repro.core.cost_model import CostEnv, PlanEvaluator, ZDP_POD
from repro.core.descriptions import ModelDescription, describe
from repro.core.hybrid import hybrid_step_time
from repro.core.search import schedule, search_hybrid, slice_description

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"
CEILING_S = 120.0          # --check wall-clock ceiling (whole sweep)
EPS = 1e-6                 # strict-win threshold


def _translate_modes(decisions, true_spec: ClusterSpec):
    """Map a flat planner's decisions onto the true spec's mode names:
    the flat 'data' axis is the innermost level, so ZDP_POD means
    'shard the innermost level only' = level-1 ZDP."""
    if true_spec.depth <= 2:
        return decisions
    out = {}
    for name, d in decisions.items():
        modes = tuple(true_spec.span_mode(1) if m == ZDP_POD else m
                      for m in d.modes)
        out[name] = dataclasses.replace(d, modes=modes)
    return out


def _true_cost(desc: ModelDescription, decisions, batch: int,
               spec: ClusterSpec, checkpointing: bool):
    """Score a plan under the hierarchical ground-truth model."""
    env = CostEnv(spec.device, cluster=spec, checkpointing=checkpointing)
    decisions = _translate_modes(decisions, spec)
    ev = PlanEvaluator.for_decisions(desc, env, decisions)
    return ev.plan_cost(ev.modes_from_decisions(decisions), batch)


# --- data-parallel (schedule) cases ------------------------------------------

def _run_schedule_case(name: str, desc: ModelDescription,
                       spec: ClusterSpec, limit_bytes: float,
                       batches: List[int], checkpointing: bool = True,
                       out=print) -> dict:
    flat_dev, flat_mesh = spec.to_flat()
    # the flat model cannot see per-group memory: it must assume every
    # device is the worst one (the only safe uniform assumption)
    flat_limit = min(limit_bytes, spec.min_hbm) if spec.groups \
        else limit_bytes
    flat_env = CostEnv(flat_dev, flat_mesh, checkpointing=checkpointing)
    topo_env = CostEnv(spec.device, cluster=spec,
                       checkpointing=checkpointing)
    t0 = time.perf_counter()
    flat = schedule(desc, flat_env, OSDPConfig(
        memory_limit_bytes=flat_limit), batch_candidates=batches)
    topo = schedule(desc, topo_env, OSDPConfig(
        memory_limit_bytes=limit_bytes), batch_candidates=batches)
    dt = time.perf_counter() - t0

    # ground truth: both plans re-scored under the hierarchy.  The
    # flat plan keeps its own batch choice; an infeasible flat search
    # contributes zero throughput (it would refuse to run).
    true_flat = _true_cost(desc, flat.decisions, flat.batch_size, spec,
                           checkpointing)
    true_topo = _true_cost(desc, topo.decisions, topo.batch_size, spec,
                           checkpointing)
    limit = spec.memory_limit(limit_bytes)
    flat_ok = flat.feasible and true_flat.memory <= limit * (1 + 1e-9)
    topo_ok = topo.feasible and true_topo.memory <= limit * (1 + 1e-9)
    thr_flat = true_flat.throughput if flat_ok else 0.0
    thr_topo = true_topo.throughput if topo_ok else 0.0
    row = {
        "kind": "schedule", "cluster": spec.summary(),
        "model": desc.model.name, "n_devices": spec.n_devices,
        "flat_feasible": bool(flat_ok), "topo_feasible": bool(topo_ok),
        "flat_batch": flat.batch_size if flat_ok else 0,
        "topo_batch": topo.batch_size if topo_ok else 0,
        "flat_tok_s": round(thr_flat, 1), "topo_tok_s": round(thr_topo, 1),
        "topo_win": bool(thr_topo > thr_flat * (1 + EPS)),
        "feasibility_flip": bool(topo_ok and not flat_ok),
        "seconds": round(dt, 3),
    }
    out(f"{name},{desc.model.name},{spec.n_devices},"
        f"{thr_flat:.0f},{thr_topo:.0f},"
        f"win={row['topo_win']},flip={row['feasibility_flip']}")
    return row


# --- hybrid (3D placement) cases ---------------------------------------------

def _run_hybrid_case(name: str, desc: ModelDescription,
                     spec: ClusterSpec, limit_bytes: float,
                     batch: int, checkpointing: bool = True,
                     out=print) -> dict:
    flat_dev, _ = spec.to_flat()
    # legacy hybrid path: no topology — TP priced on ici whatever it
    # spans (DeviceInfo.devices_per_node withheld, as pre-PR)
    flat_dev = dataclasses.replace(flat_dev, devices_per_node=0)
    osdp = OSDPConfig(memory_limit_bytes=limit_bytes,
                      checkpointing=checkpointing)
    t0 = time.perf_counter()
    flat = search_hybrid(desc, flat_dev, spec.n_devices, osdp,
                         batch_candidates=[batch])
    topo = search_hybrid(desc, spec.device, spec.n_devices, osdp,
                         batch_candidates=[batch], cluster=spec)
    dt = time.perf_counter() - t0

    def true_throughput(plan) -> Tuple[float, Tuple[int, int, int]]:
        f = plan.factorization
        fac = (f.dp, f.tp, f.pp)
        if not plan.feasible:
            return 0.0, fac
        try:
            data_spec = spec.consume_inner(f.tp).consume_outer(f.pp)
        except ValueError:
            return 0.0, fac          # placement impossible on the fabric
        sub = slice_description(desc, f.tp, f.pp)
        inner = _true_cost(sub, plan.decisions, plan.batch_size,
                           data_spec, checkpointing)
        t = hybrid_step_time(inner.time, desc, spec.device,
                             plan.batch_size, f, plan.micro, spec)
        tokens = plan.batch_size * desc.shape.seq_len
        return (tokens / t if t > 0 else 0.0), fac

    thr_flat, fac_flat = true_throughput(flat)
    thr_topo, fac_topo = true_throughput(topo)
    row = {
        "kind": "hybrid", "cluster": spec.summary(),
        "model": desc.model.name, "n_devices": spec.n_devices,
        "flat_factorization": list(fac_flat),
        "topo_factorization": list(fac_topo),
        "flat_tok_s": round(thr_flat, 1), "topo_tok_s": round(thr_topo, 1),
        "topo_win": bool(thr_topo > thr_flat * (1 + EPS)),
        "feasibility_flip": False,
        "seconds": round(dt, 3),
    }
    out(f"{name},{desc.model.name},{spec.n_devices},"
        f"{thr_flat:.0f},{thr_topo:.0f},"
        f"flat_f={fac_flat},topo_f={fac_topo},win={row['topo_win']}")
    return row


# --- the sweep ---------------------------------------------------------------

def _cases(quick: bool, device: Optional[str] = None):
    """(name, runner) pairs; each runner returns a result row."""
    dev = DeviceInfo.preset(device) if device else DeviceInfo()
    a100 = DeviceInfo.preset("a100-80g")
    h100 = DeviceInfo.preset("h100-sxm")
    cases = []

    # 4 TPU pods x 64 chips: flat bottleneck pricing vs per-level
    # rings.  On this shallow, mildly-skewed hierarchy both planners
    # land the same plan (an honest tie row: collapsing depth 2 to
    # (ici, dci) loses pricing accuracy but not the argmin here)
    spec_tpu = tpu_multipod(4, 64, dev)
    cases.append(("tpu-4x64-llama405", lambda out: _run_schedule_case(
        "tpu-4x64-llama405",
        describe(get_arch("llama3-405b"), get_shape("train_4k")),
        spec_tpu, 128 * 2**30, [256, 512], out=out)))

    # 8 nodes x 8 H100 on a 3-level NVLink/IB/spine fabric: the flat
    # model cannot express rack-level (ZDP@2) sharding at all
    spec_spine = gpu_cluster(64, 8, device=h100, nvlink_bw=450e9,
                             ib_bw=50e9, spine_nodes=8, spine_bw=12.5e9)
    cases.append(("gpu-512-arctic", lambda out: _run_schedule_case(
        "gpu-512-arctic",
        describe(get_arch("arctic-480b"), get_shape("train_4k")),
        spec_spine, 72 * 2**30, [512, 1024], out=out)))

    # 2 A100 servers: the legacy hybrid TP-pricing bug (tp across IB
    # charged at NVLink rate)
    spec_2srv = ClusterSpec.from_device(
        dataclasses.replace(a100, dci_bw=12.5e9), 16)
    cases.append(("a100-2x8-hybrid", lambda out: _run_hybrid_case(
        "a100-2x8-hybrid",
        describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k")),
        spec_2srv, 24 * 2**30, 32, out=out)))

    # mixed-generation fleet: 128 x 24 GiB + 128 x 80 GiB — uniform
    # worst-device planning rejects it, capacity-weighted fits it
    spec_mixed = mixed_memory_fleet(128, 24, 128, 80, pod_size=64,
                                    device=dev)
    cases.append(("mixed-24-80-arctic", lambda out: _run_schedule_case(
        "mixed-24-80-arctic",
        describe(get_arch("arctic-480b"), get_shape("train_4k")),
        spec_mixed, spec_mixed.min_hbm, [256], out=out)))

    if not quick:
        # 8 nodes x 8 A100, nodes paired under oversubscribed leaf
        # switches (depth 3): rack-level ZDP@2 is inexpressible in the
        # flat model
        spec_ib = gpu_cluster(8, 8, device=a100, nvlink_bw=300e9,
                              ib_bw=25e9, spine_nodes=2, spine_bw=6e9)
        cases.append(("gpu-8x8-dbrx", lambda out: _run_schedule_case(
            "gpu-8x8-dbrx",
            describe(get_arch("dbrx-132b"), get_shape("train_4k")),
            spec_ib, 44 * 2**30, [64, 128, 256], out=out)))

        # 64 H100 hybrid on NVLink/IB: TP must stay inside the node
        spec_h100 = gpu_cluster(8, 8, device=h100, nvlink_bw=450e9,
                                ib_bw=50e9)
        cases.append(("h100-8x8-hybrid", lambda out: _run_hybrid_case(
            "h100-8x8-hybrid",
            describe(get_arch("dbrx-132b"), get_shape("train_4k")),
            spec_h100, 76 * 2**30, 128, out=out)))
    return cases


def main(out=print, quick: bool = False, check: bool = False,
         json_path: Optional[Path] = None,
         device: Optional[str] = None) -> dict:
    path = Path(json_path) if json_path else JSON_PATH
    out("case,model,n_devices,flat_tok_s,topo_tok_s,notes")
    t0 = time.perf_counter()
    rows: Dict[str, dict] = {}
    for name, runner in _cases(quick, device):
        rows[name] = runner(out)
    elapsed = time.perf_counter() - t0

    wins = sum(1 for r in rows.values() if r["topo_win"])
    flips = sum(1 for r in rows.values() if r["feasibility_flip"])
    out(f"# {len(rows)} cases, {wins} topology wins, {flips} "
        f"feasibility flips, {elapsed:.1f}s")

    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    doc["topology"] = {"rows": rows, "wins": wins,
                       "feasibility_flips": flips,
                       "quick": quick,
                       "seconds": round(elapsed, 3)}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    out(f"# wrote {path}")

    if check:
        if wins < 2:
            raise SystemExit(
                f"topology-aware planning won only {wins} cases (< 2)")
        if flips < 1:
            raise SystemExit("no heterogeneous feasibility flip")
        if elapsed > CEILING_S:
            raise SystemExit(
                f"sweep took {elapsed:.1f}s (ceiling {CEILING_S:.0f}s)")
        out("# check passed: >= 2 wins, >= 1 flip, within ceiling")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI subset (4 cases, stacked descriptions)")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline claims and the ceiling")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="base DeviceInfo preset for the TPU / "
                         "mixed-memory fleets (tpu-v5e, tpu-v4, "
                         "a100-80g, h100-sxm)")
    a = ap.parse_args()
    main(quick=a.quick, check=a.check, json_path=a.json, device=a.device)
