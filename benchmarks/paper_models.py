"""The paper's experimental models (Table 1) + hardware environments.

Three minGPT-style families:
  N&D  narrow & deep   — 48–96 layers, hidden 1024–1536  (GPT-2/BERT/T5)
  W&S  wide & shallow  — 2–4 layers, hidden 6144–12288   (GPT-3-like)
  I&C  inconsistent    — 24–96 layers, mixed hidden      (Swin-like)

I&C layers vary per-layer, which ModelConfig (homogeneous) cannot
express — those are built directly as per-layer ModelDescriptions,
which is all the cost model and search engine need.

Hardware environments mirror §4.1: one server with 8 RTX TITAN over
PCIe3 (Fig. 5) and two A100 servers linked at 100 Gb (Fig. 6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import (DENSE, DeviceInfo, MeshConfig, ModelConfig,
                                ShapeConfig)
from repro.core.descriptions import (ACT_BYTES, ModelDescription,
                                     OperatorDesc, describe)

# --- hardware (the paper's DI) ------------------------------------------------

RTX_TITAN_8 = DeviceInfo(
    name="8x-rtx-titan-pcie3",
    peak_flops=65e12,          # fp16 tensor-core, realistic sustained
    hbm_bytes=24 * 2**30,
    hbm_bw=672e9,
    ici_bw=12e9,               # PCIe 3.0 x16
    dci_bw=12e9,
    alpha=5e-6,
    mxu_efficiency=0.45,
)

A100_2SERVER = DeviceInfo(
    name="2x8-a100-100gb",
    peak_flops=312e12,
    hbm_bytes=40 * 2**30,
    hbm_bw=1555e9,
    ici_bw=300e9,              # NVLink within server
    dci_bw=12.5e9,             # 100 Gb between servers
    alpha=5e-6,
    mxu_efficiency=0.45,
)

MESH_8GPU = MeshConfig((8, 1), ("data", "model"))
MESH_2SERVER = MeshConfig((2, 8, 1), ("pod", "data", "model"))


def paper_shape(batch: int, seq: int = 1024) -> ShapeConfig:
    return ShapeConfig(f"paper_b{batch}", seq, batch, "train")


def _gpt(name: str, layers: int, hidden: int) -> ModelConfig:
    heads = max(8, hidden // 64)
    return ModelConfig(
        name=name, family=DENSE, n_layers=layers, d_model=hidden,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * hidden,
        vocab_size=50257, act="gelu", norm="layernorm", rope="none",
        tie_embeddings=True, source="[minGPT]",
    )


# Table 1 rows (several configs per family)
ND_MODELS: List[ModelConfig] = [
    _gpt("nd-48x1024", 48, 1024),    # 1.3B-ish
    _gpt("nd-64x1280", 64, 1280),
    _gpt("nd-96x1536", 96, 1536),    # 2.9B-ish
]
WS_MODELS: List[ModelConfig] = [
    _gpt("ws-2x6144", 2, 6144),
    _gpt("ws-3x8192", 3, 8192),
    _gpt("ws-4x12288", 4, 12288),    # 4B-ish
]

# I&C: per-layer inconsistent hidden sizes (Swin-style stages)
IC_SPECS: List[Tuple[str, List[int]]] = [
    ("ic-24", [1024] * 8 + [2048] * 8 + [4096] * 8),
    ("ic-48", [1024] * 16 + [2048] * 16 + [3072] * 16),
    ("ic-96", [1024] * 48 + [1536] * 32 + [4096] * 16),
]


def ic_description(name: str, hiddens: List[int],
                   shape: ShapeConfig) -> ModelDescription:
    """Per-layer op list with varying hidden sizes (I&C family)."""
    V = 50304
    ops: List[OperatorDesc] = []
    d0 = hiddens[0]
    ops.append(OperatorDesc("embed.tok", V * d0, 0.0, d0 * ACT_BYTES))
    for i, d in enumerate(hiddens):
        qkv = 3 * d * d
        ops.append(OperatorDesc(f"layer{i}.attn_qkv", qkv, 2.0 * qkv,
                                3 * d * ACT_BYTES, splittable=True))
        ops.append(OperatorDesc(f"layer{i}.attn_out", d * d, 2.0 * d * d,
                                d * ACT_BYTES, splittable=True))
        ops.append(OperatorDesc(f"layer{i}.ffn_w1", 4 * d * d, 8.0 * d * d,
                                4 * d * ACT_BYTES, splittable=True))
        ops.append(OperatorDesc(f"layer{i}.ffn_w2", 4 * d * d, 8.0 * d * d,
                                d * ACT_BYTES, splittable=True))
        ops.append(OperatorDesc(f"layer{i}.norms", 4 * d, 0.0, 0.0,
                                decidable=False))
    resident = sum(hiddens) * ACT_BYTES + d0 * ACT_BYTES
    cfg = _gpt(name, len(hiddens), max(hiddens))
    return ModelDescription(cfg, shape, ops, resident)


def nd_ws_description(cfg: ModelConfig, shape: ShapeConfig,
                      per_layer: bool = True) -> ModelDescription:
    return describe(cfg, shape, per_layer=per_layer)


ALL_FAMILIES: Dict[str, list] = {
    "N&D": ND_MODELS,
    "W&S": WS_MODELS,
    "I&C": IC_SPECS,
}
