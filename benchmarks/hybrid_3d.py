"""Fig. 5/6 hybrid rows — PP, TP, 3D parallelism, and 3D+OSDP.

The paper compares OSDP against GPipe (PP), Megatron-LM (TP),
DeepSpeed 3D, and demonstrates compatibility by replacing the DP
dimension of 3D with OSDP ("3D+OSDP", its strongest configuration).
This module reproduces that comparison analytically with the same
(alpha, beta, gamma) machinery the OSDP search uses:

  TP  — per-layer params/tp; 2 activation all-reduces per layer
        (Megatron column+row pairs), comm = 4 (tp-1)/tp * act_bytes.
  PP  — layers split into `pp` stages, GPipe microbatching: bubble
        (pp-1)/(m+pp-1); stage-boundary activation sends.
  3D  — sweep all (dp, tp, pp) factorizations of the device count;
        inside each, the DP dimension is either plain DP, FSDP, or the
        OSDP search (= "3D+OSDP"); report the best per strategy.

Per the paper, hybrid rows tune the combination and report the best.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from benchmarks.fig5_end_to_end import _descriptions
from benchmarks.paper_models import (A100_2SERVER, MESH_2SERVER, MESH_8GPU,
                                     RTX_TITAN_8, paper_shape)
from repro.configs.base import DeviceInfo, MeshConfig, OSDPConfig
from repro.core.cost_model import CostEnv, plan_cost, uniform_plan, DP
from repro.core.descriptions import ModelDescription
from repro.core.search import schedule

ACT_BYTES = 2


def _factorizations(n: int) -> List[Tuple[int, int, int]]:
    out = []
    for tp in (1, 2, 4, 8):
        for pp in (1, 2, 4, 8):
            if n % (tp * pp) == 0:
                out.append((n // (tp * pp), tp, pp))
    return out


def _act_tokens(desc: ModelDescription, batch: int) -> float:
    return batch * desc.shape.seq_len


def hybrid_time(desc: ModelDescription, device: DeviceInfo, n_dev: int,
                batch: int, dp: int, tp: int, pp: int,
                dp_mode: str, mem_gib: float,
                micro: int = 8) -> Tuple[float, float, bool]:
    """(step_seconds, per-device bytes, feasible) for one (dp,tp,pp)."""
    d = desc.model.d_model
    L = max(1, desc.model.n_layers)
    if pp > L:
        return float("inf"), float("inf"), False
    mesh = MeshConfig((dp, 1), ("data", "model"))
    env = CostEnv(device, mesh, checkpointing=False, include_tp=False)

    # the DP dimension: DP / FSDP / OSDP over a 1/(tp*pp) model slice.
    scale = 1.0 / (tp * pp)
    ops = [dataclasses.replace(
        op, param_count=int(op.param_count * scale),
        flops_per_token=op.flops_per_token * scale,
        act_bytes_per_token=op.act_bytes_per_token * scale)
        for op in desc.operators]
    sub = dataclasses.replace(desc, operators=ops,
                              resident_act_bytes_per_token=(
                                  desc.resident_act_bytes_per_token * scale))
    lim = mem_gib * 2**30
    if dp_mode == "OSDP":
        res = schedule(sub, env, OSDPConfig(
            memory_limit_bytes=lim, operator_splitting=True,
            allow_pod_hierarchical=False),
            batch_candidates=[batch])
        if not res.feasible:
            return float("inf"), float("inf"), False
        base_t, mem = res.cost.time, res.cost.memory
    else:
        mode = "ZDP" if dp_mode == "FSDP" else "DP"
        plan = uniform_plan(sub, mode)
        c = plan_cost(sub, plan, batch, env)
        base_t, mem = c.time, c.memory
        if mem > lim:
            return float("inf"), float("inf"), False

    # TP activation collectives: 2 all-reduces/layer of (b_local, s, d)
    b_local = max(1, batch // dp)
    act = b_local * desc.shape.seq_len * d * ACT_BYTES
    t_tp = 0.0
    if tp > 1:
        t_tp = 2 * L * 2 * (tp - 1) / tp * act / device.ici_bw

    # PP: bubble + stage-boundary sends (GPipe, `micro` microbatches)
    t = base_t + t_tp
    if pp > 1:
        bubble = (pp - 1) / (micro + pp - 1)
        t = t / (1 - bubble)
        t += (pp - 1) * micro * (act / micro) / device.ici_bw
    return t, mem, True


def best_hybrid(desc: ModelDescription, device: DeviceInfo, n_dev: int,
                batch: int, dp_mode: str, mem_gib: float
                ) -> Tuple[float, Optional[Tuple[int, int, int]]]:
    best, best_cfg = float("inf"), None
    for dp, tp, pp in _factorizations(n_dev):
        if dp == n_dev and dp_mode != "OSDP":
            continue          # pure DP covered by the flat strategies
        t, _, ok = hybrid_time(desc, device, n_dev, batch, dp, tp, pp,
                               dp_mode, mem_gib)
        if ok and t < best:
            best, best_cfg = t, (dp, tp, pp)
    return best, best_cfg


def main(out=print) -> List[dict]:
    out("# hybrid parallelism (paper Fig.5/6 PP/TP/3D rows):"
        " throughput tokens/s, best (dp,tp,pp) per strategy")
    out("env,family,model,TP,PP,3D,3D+OSDP,cfg_3d_osdp")
    rows = []
    for env_name, device, n_dev in (("8gpu", RTX_TITAN_8, 8),
                                    ("2server", A100_2SERVER, 16)):
        shape = paper_shape(64)
        tokens = shape.seq_len * shape.global_batch
        for family, name, desc in _descriptions(shape):
            res = {}
            for label, (mode, force) in {
                    "TP": ("DP", (1, 8, 1) if n_dev == 8 else (1, 8, 2)),
                    "PP": ("DP", (1, 1, 8)),
                    "3D": ("FSDP", None),
                    "3D+OSDP": ("OSDP", None)}.items():
                if force:
                    dp, tp, pp = force
                    t, _, ok = hybrid_time(desc, device, n_dev, 64, dp, tp,
                                           pp, mode, 16)
                    res[label] = (tokens / t if ok else 0.0, force)
                else:
                    t, cfg = best_hybrid(desc, device, n_dev, 64, mode, 16)
                    res[label] = (tokens / t if t < float("inf") else 0.0,
                                  cfg)
            out(f"{env_name},{family},{name},"
                f"{res['TP'][0]:.0f},{res['PP'][0]:.0f},{res['3D'][0]:.0f},"
                f"{res['3D+OSDP'][0]:.0f},{res['3D+OSDP'][1]}")
            rows.append({"env": env_name, "model": name, **{
                k: v[0] for k, v in res.items()}})
    good = [r for r in rows if r["3D"] > 0 and r["3D+OSDP"] > 0]
    if good:
        sp = [r["3D+OSDP"] / r["3D"] - 1 for r in good]
        out(f"# 3D+OSDP vs 3D: avg {100 * sum(sp) / len(sp):.1f}% "
            f"max {100 * max(sp):.1f}% (paper: avg 31%, max 73%)")
    return rows


if __name__ == "__main__":
    main()
