"""Fig. 5/6 hybrid rows — PP, TP, 3D parallelism, and 3D+OSDP.

Thin client of the core hybrid subsystem: the factorization sweep, the
TP/PP cost terms, and the DP-dimension OSDP search all live in
`repro.core.hybrid` + `repro.core.search.search_hybrid`; this script
only picks the strategies and formats the rows.

  TP       — forced (dp=1, tp=8[, pp]) with replicated DP
  PP       — forced (dp=1, tp=1, pp=8) with replicated DP
  3D       — factorization sweep, DP dimension forced to ZDP (FSDP);
             pure-DP factorizations excluded (covered by the flat
             Fig. 5 strategies)
  3D+OSDP  — factorization sweep, DP dimension = the OSDP search
             (the paper's strongest configuration)

Per the paper, hybrid rows tune the combination and report the best.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from benchmarks.fig5_end_to_end import _descriptions
from benchmarks.paper_models import A100_2SERVER, RTX_TITAN_8, paper_shape
from repro.configs.base import DeviceInfo
from repro.core.api import search_hybrid
from repro.core.descriptions import ModelDescription
from repro.core.hybrid import Factorization, HybridPlan, factorizations


def best_hybrid(desc: ModelDescription, device: DeviceInfo, n_dev: int,
                batch: int, mem_gib: float, *,
                force_mode: Optional[str] = None,
                candidates: Optional[Sequence[Factorization]] = None,
                ) -> HybridPlan:
    return search_hybrid(
        desc, n_devices=n_dev, device=device, memory_limit_gib=mem_gib,
        checkpointing=False, force_mode=force_mode,
        operator_splitting=force_mode is None,
        batch_candidates=[batch], candidates=candidates)


def main(out=print) -> List[dict]:
    out("# hybrid parallelism (paper Fig.5/6 PP/TP/3D rows):"
        " throughput tokens/s, best (dp,tp,pp) per strategy")
    out("env,family,model,TP,PP,3D,3D+OSDP,cfg_3d_osdp")
    rows = []
    for env_name, device, n_dev in (("8gpu", RTX_TITAN_8, 8),
                                    ("2server", A100_2SERVER, 16)):
        shape = paper_shape(64)
        # TP/PP capped at the per-server device count (8 in both
        # environments): the TP cost term charges intra-server
        # bandwidth, so cross-server TP would be grossly under-costed.
        # Non-trivial factorizations: pure DP is covered by the flat
        # Fig. 5 strategies, so the 3D row excludes it (as the paper's
        # hybrid baselines do); 3D+OSDP keeps it — dp=n with the OSDP
        # search *is* plain OSDP, a legal point of its space.
        sweep = factorizations(n_dev, max_tp=8, max_pp=8)
        non_pure = [f for f in sweep if not f.is_pure_dp]
        strategies = {
            "TP": dict(force_mode="DP", candidates=[
                Factorization(1, 8, 1) if n_dev == 8
                else Factorization(1, 8, 2)]),
            "PP": dict(force_mode="DP",
                       candidates=[Factorization(1, 1, 8)]),
            "3D": dict(force_mode="ZDP", candidates=non_pure),
            "3D+OSDP": dict(candidates=sweep),
        }
        for family, name, desc in _descriptions(shape):
            res = {}
            for label, kw in strategies.items():
                plan = best_hybrid(desc, device, n_dev, 64, 16, **kw)
                res[label] = (plan.cost.throughput if plan.feasible
                              else 0.0, plan)
            cfg = res["3D+OSDP"][1]
            cfg_str = ((cfg.dp, cfg.tp, cfg.pp) if cfg.feasible else None)
            out(f"{env_name},{family},{name},"
                f"{res['TP'][0]:.0f},{res['PP'][0]:.0f},{res['3D'][0]:.0f},"
                f"{res['3D+OSDP'][0]:.0f},{cfg_str}")
            rows.append({"env": env_name, "model": name, **{
                k: v[0] for k, v in res.items()}})
    good = [r for r in rows if r["3D"] > 0 and r["3D+OSDP"] > 0]
    if good:
        sp = [r["3D+OSDP"] / r["3D"] - 1 for r in good]
        out(f"# 3D+OSDP vs 3D: avg {100 * sum(sp) / len(sp):.1f}% "
            f"max {100 * max(sp):.1f}% (paper: avg 31%, max 73%)")
    return rows


if __name__ == "__main__":
    main()
