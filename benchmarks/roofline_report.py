"""§Roofline — the 3-term table from the dry-run records.

Reads dryrun_records.json (produced by `python -m repro.launch.dryrun
--all --both-meshes --out dryrun_records.json`) and prints, per
(arch x shape) on the single-pod mesh: compute / memory / collective
seconds, the dominant term, MODEL_FLOPS/HLO_FLOPs, and a one-line
what-would-move-it note.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

from repro.configs.base import DeviceInfo
from repro.roofline.analysis import analytic_roofline, roofline

NOTES = {
    "compute": "raise MXU utilization: larger per-device batch or fuse "
               "small ops",
    "memory": "cut HBO traffic: better fusion/remat policy, bf16 "
              "master-weights offload",
    "collective": "reduce gathered bytes: move ops ZDP->DP/ZDP_POD where "
                  "memory allows, overlap collectives with compute",
}


def main(out=print, path: Optional[str] = None) -> List[dict]:
    path = path or os.environ.get("DRYRUN_RECORDS", "dryrun_records.json")
    if not os.path.exists(path):
        out(f"# {path} not found — run the dry-run first; skipping")
        return []
    with open(path) as f:
        records = json.load(f)
    dev = DeviceInfo()
    out("# raw_* terms parse compiled HLO (scan bodies counted ONCE by "
        "XLA cost analysis — undercounts deep stacks); ana_* terms are "
        "scan-aware cost-model values used for dominance. hbm = "
        "memory_analysis args+temps (correct either way).")
    out("arch,shape,mesh,raw_compute_s,raw_memory_s,raw_collective_s,"
        "ana_compute_s,ana_memory_s,ana_collective_s,dominant,"
        "hbm_gib_per_dev")
    rows = []
    for rec in records:
        if rec["mesh"] != "16x16":
            continue
        t = roofline(rec, dev)
        ana = analytic_roofline(rec, dev)
        mem = rec.get("memory_analysis", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        dominant = max(ana, key=ana.get).replace("_s", "")
        out(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
            f"{t.compute_s:.4f},{t.memory_s:.4f},{t.collective_s:.4f},"
            f"{ana['compute_s']:.4f},{ana['memory_s']:.4f},"
            f"{ana['collective_s']:.4f},{dominant},{hbm:.2f}")
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "terms": t, "ana": ana, "dominant": dominant,
                     "hbm_gib": hbm})
    if rows:
        worst = max(rows, key=lambda r: r["hbm_gib"])
        coll = max(rows, key=lambda r: r["ana"]["collective_s"]
                   / max(1e-12, r["ana"]["compute_s"]))
        out(f"# worst memory pressure: {worst['arch']} x {worst['shape']}"
            f" ({worst['hbm_gib']:.0f} GiB/dev)")
        out(f"# most collective-bound: {coll['arch']} x {coll['shape']}")
    return rows


if __name__ == "__main__":
    main()
