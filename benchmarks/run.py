"""Benchmark harness — one module per paper table/figure.

  table1   model-family statistics (paper Table 1 + assigned archs)
  fig5/6   end-to-end throughput: DP / FSDP / OSDP(-base/+hier)
  fig7     operator splitting: per-op memory & time vs granularity
  fig8     OSDP with vs without splitting
  fig9     checkpointing interaction (OSDP vs FSDP under remat)
  search   search-engine timing (paper: 9–307 s)
  topology flat vs hierarchical ClusterSpec planning (64–512 devices)
  overlap  serial vs two-resource timeline (comm/compute overlap) planning
  roofline §Roofline table from dry-run records (if present)

`python -m benchmarks.run [section ...] [--device PRESET] [--overlap F]`
— no section args runs everything; `--device` forwards a DeviceInfo
preset (tpu-v5e, tpu-v4, a100-80g, h100-sxm) to the sections that take
one; `--overlap` forwards an extra uniform overlap factor to the
overlap sweep.
"""
from __future__ import annotations

import sys
import time


def main(argv=None) -> None:
    argv = list(argv if argv is not None else sys.argv[1:])
    device = None
    if "--device" in argv:
        i = argv.index("--device")
        if i + 1 >= len(argv):
            raise SystemExit("--device needs a preset name "
                             "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
        device = argv[i + 1]
        del argv[i:i + 2]
    overlap = None
    if "--overlap" in argv:
        i = argv.index("--overlap")
        if i + 1 >= len(argv):
            raise SystemExit("--overlap needs a factor in [0, 1]")
        overlap = float(argv[i + 1])
        del argv[i:i + 2]
    args = argv or [
        "table1", "fig5", "hybrid3d", "fig7", "fig8", "fig9", "search",
        "topology", "overlap", "auto_g", "roofline"]
    from benchmarks import (auto_granularity, fig5_end_to_end,
                            fig7_operator_splitting,
                            fig8_splitting_throughput, fig9_checkpointing,
                            hybrid_3d, overlap_sweep, roofline_report,
                            search_time, table1_models, topology_sweep)
    sections = {
        "table1": table1_models.main,
        "fig5": fig5_end_to_end.main,     # includes fig6
        "hybrid3d": hybrid_3d.main,       # Fig.5/6 PP/TP/3D/3D+OSDP rows
        "fig7": fig7_operator_splitting.main,
        "fig8": fig8_splitting_throughput.main,
        "fig9": fig9_checkpointing.main,
        "search": search_time.main,
        "topology": topology_sweep.main,
        "overlap": overlap_sweep.main,
        "auto_g": auto_granularity.main,  # beyond-paper (§4.3 future work)
        "roofline": roofline_report.main,
    }
    takes_device = {"search", "topology", "overlap"}
    for name in args:
        fn = sections.get(name)
        if fn is None:
            print(f"# unknown section {name!r}; known: {sorted(sections)}")
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        kwargs = {}
        if device and name in takes_device:
            kwargs["device"] = device
        if overlap is not None and name == "overlap":
            kwargs["overlap"] = overlap
        fn(**kwargs)
        print(f"# [{name}] done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
