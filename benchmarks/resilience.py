"""Resilience: recovery time, goodput under faults, crash-safe resume.

Three row families, recorded in ``BENCH_search.json`` under
``"resilience"``:

  * **serve-loss rows** (executed on the host): the `ServeSupervisor`
    drives a reduced model through an injected device-group loss
    mid-run.  Headline assert: **zero lost acknowledged requests** —
    every request reaches exactly one terminal state, results
    acknowledged before the loss are preserved verbatim (never re-run),
    and in-flight + queued work is re-admitted on the replanned
    engine.  Recovery time (drain -> rescore -> replan -> new engine)
    is measured per loss.

  * **train-recovery row** (executed + planner): training with an
    injected mid-save crash AND a device loss.  Planning runs at full
    scale (phi4 on a 4-pod TPU fleet) where the loss of two pods makes
    the stale plan INFEASIBLE while the re-searched plan fits — the
    supervisor records both verdicts; execution runs the reduced model
    on the host, resuming from the newest atomic checkpoint each time.

  * **retry-goodput rows** (executed): the same transiently-failing
    request stream served with and without the engine's bounded
    retry/backoff.  Assert: retries recover >= the no-retry goodput
    (completed requests and useful tokens both).

``--quick`` shrinks the workloads for CI; ``--check`` asserts the
three headline claims above plus the wall-clock ceiling.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"
CEILING_S = 420.0          # --check wall-clock ceiling (whole run)

# the planning-scale flip config: phi4 at 26 GiB on 4 pods of 16 is
# feasible with a mixed DP/ZDP_POD plan; after losing 2 pods the stale
# plan needs ~35 GiB (its ZDP shards double) while a fresh full-ZDP
# search still fits (~24 GiB)
FLIP_ARCH = "phi4-mini-3.8b"
FLIP_LIMIT_GIB = 26.0


def _built(arch: str, shape: str = "decode_32k", seq: int = 0,
           batch: int = 0):
    import dataclasses
    import jax
    from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                               get_shape, reduced)
    from repro.models.registry import build_model

    cfg = reduced(get_arch(arch))
    shp = get_shape(shape)
    if seq or batch:
        shp = dataclasses.replace(shp, seq_len=seq or shp.seq_len,
                                  global_batch=batch or shp.global_batch)
    run = RunConfig(model=cfg, shape=shp,
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))
    return cfg, built, params


def _requests(cfg, n_req: int, prompt_len: int, n_new: int):
    from repro.serving.engine import Request
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (n_req, prompt_len)).astype(np.int32)
    return [Request(i, prompts[i], n_new) for i in range(n_req)]


def _serve_loss_row(arch: str, quick: bool, out) -> dict:
    from repro.cluster.topology import gpu_cluster
    from repro.core.api import rescore_serve, search_serve
    from repro.resilience import DeviceGroupLoss, FaultSchedule
    from repro.resilience.supervisor import ServeSupervisor
    from repro.serving.engine import ContinuousEngine

    cfg, built, params = _built(arch)
    n_req, slots, n_new = (8, 2, 4) if quick else (16, 3, 8)
    prompt_len = 8
    reqs = _requests(cfg, n_req, prompt_len, n_new)
    cluster = gpu_cluster(4, 8)
    loss_step = (n_req // 2) * (n_new + 1) // 2    # mid-run

    def plan_fn(cl):
        return search_serve(cfg, prompt_len=prompt_len, decode_len=n_new,
                            cluster=cl, memory_limit_gib=16.0,
                            max_slots=8)

    def engine_factory(plan, cl):
        return ContinuousEngine(built, params, max_slots=slots,
                                cache_len=prompt_len + n_new)

    def rescore_fn(plan, cl):
        return rescore_serve(cfg, plan, cluster=cl, memory_limit_gib=16.0)

    sup = ServeSupervisor(plan_fn, engine_factory, cluster,
                          rescore_fn=rescore_fn,
                          print_fn=lambda *a: None)
    faults = FaultSchedule(
        device_losses=(DeviceGroupLoss(at_step=loss_step, level="rack"),))
    t0 = time.perf_counter()
    run = sup.run(reqs, seed=0, faults=faults)
    wall = time.perf_counter() - t0

    rids = sorted(r.rid for r in run.results)
    zero_lost = (rids == list(range(n_req))
                 and all(r.status == "OK" for r in run.results))
    rec = run.recoveries[0]
    row = {
        "requests": n_req, "slots": slots, "loss_step": rec.step,
        "lost": rec.description,
        "devices_before": rec.n_devices_before,
        "devices_after": rec.n_devices_after,
        "stale_plan_feasible": rec.stale_feasible,
        "replanned": rec.replanned,
        "requeued": rec.requeued,
        "acked_before_loss": n_req - rec.requeued,
        "zero_lost_acknowledged": zero_lost,
        "recovery_ms": round(rec.recovery_s * 1e3, 1),
        "completed": run.stats.completed,
        "useful_tokens": run.stats.useful_tokens,
        "wall_s": round(wall, 3),
    }
    out(f"serve-loss,{arch},{n_req}req,{rec.description},"
        f"requeued={rec.requeued},recovery={row['recovery_ms']}ms,"
        f"{'ZERO-LOST' if zero_lost else 'LOST-WORK'}")
    return row


def _train_recovery_row(quick: bool, out, tmp_dir: str) -> dict:
    from repro.checkpoint import io as ckpt_io
    from repro.cluster.topology import tpu_multipod
    from repro.configs import get_arch, get_shape
    from repro.core.api import evaluate_plan, osdp
    from repro.resilience import (CheckpointCrash, DeviceGroupLoss,
                                  FaultSchedule)
    from repro.resilience.supervisor import TrainSupervisor
    from repro.train.loop import train

    _, built, _ = _built("qwen1.5-0.5b", shape="train_4k", seq=32,
                         batch=2)
    target = 6 if quick else 10
    # crash_step must land on a ckpt_every=2 boundary to fire
    loss_step, crash_step = (4, 2) if quick else (7, 4)
    cluster = tpu_multipod(4, 16)
    model = get_arch(FLIP_ARCH)
    shape = get_shape("train_4k")
    healthy = osdp(model, shape, cluster=cluster,
                   memory_limit_gib=FLIP_LIMIT_GIB)

    def train_fn(faults):
        return train(built, target, ckpt_dir=tmp_dir, ckpt_every=2,
                     keep_last=2, resume=True, log_every=0,
                     faults=faults, print_fn=lambda *a: None)

    def plan_fn(cl):
        return osdp(model, shape, cluster=cl,
                    memory_limit_gib=FLIP_LIMIT_GIB)

    def stale_fit_fn(cl):
        cost = evaluate_plan(model, healthy.decisions, shape, cluster=cl)
        return cost.memory <= cl.memory_limit(FLIP_LIMIT_GIB * 2**30)

    sup = TrainSupervisor(train_fn, plan_fn, cluster, ckpt_dir=tmp_dir,
                          stale_fit_fn=stale_fit_fn,
                          print_fn=lambda *a: None)
    faults = FaultSchedule(
        device_losses=(DeviceGroupLoss(at_step=loss_step, level="pod",
                                       ways=2),),
        ckpt_crashes=(CheckpointCrash(at_step=crash_step,
                                      after_leaves=1),))
    t0 = time.perf_counter()
    run = sup.run(faults=faults)
    wall = time.perf_counter() - t0
    n_leaves = ckpt_io.verify(tmp_dir)     # final checkpoint intact

    loss = next(r for r in run.recoveries if r.kind == "device_loss")
    crash = next(r for r in run.recoveries if r.kind == "checkpoint_crash")
    healthy_feasible = (healthy.search.feasible
                        if healthy.search else True)
    row = {
        "arch_planned": FLIP_ARCH, "limit_gib": FLIP_LIMIT_GIB,
        "target_steps": target,
        "reached_step": run.result.start_step + run.result.steps,
        "recoveries": len(run.recoveries),
        "ckpt_crash_step": crash.step,
        "loss_step": loss.step, "lost": loss.description,
        "devices_before": loss.n_devices_before,
        "devices_after": loss.n_devices_after,
        "healthy_plan_feasible": healthy_feasible,
        "stale_plan_feasible": loss.stale_feasible,
        "replan_feasible": loss.replan_feasible,
        "resumed_from_step": loss.resumed_from_step,
        "recovery_ms": round(loss.recovery_s * 1e3, 1),
        "final_ckpt_leaves_verified": n_leaves,
        "wall_s": round(wall, 3),
    }
    out(f"train-recovery,{FLIP_ARCH},{loss.description},"
        f"stale={'ok' if loss.stale_feasible else 'INFEASIBLE'},"
        f"replan={'ok' if loss.replan_feasible else 'INFEASIBLE'},"
        f"resumed@{loss.resumed_from_step},"
        f"reached={row['reached_step']}/{target}")
    return row


def _retry_goodput_row(arch: str, quick: bool, out) -> dict:
    from repro.resilience import FaultSchedule, TransientFailures
    from repro.serving.engine import ContinuousEngine

    cfg, built, params = _built(arch)
    n_req, slots, n_new = (8, 2, 4) if quick else (16, 3, 8)
    prompt_len = 8
    reqs = _requests(cfg, n_req, prompt_len, n_new)
    faults = FaultSchedule(seed=7, transient=TransientFailures(0.35))

    def serve(max_retries: int):
        eng = ContinuousEngine(built, params, max_slots=slots,
                               cache_len=prompt_len + n_new,
                               max_retries=max_retries, backoff_steps=2)
        return eng.run(reqs, seed=0, faults=faults)

    _, s_retry = serve(2)
    _, s_none = serve(0)
    row = {
        "requests": n_req, "slots": slots, "transient_p": 0.35,
        "retry_completed": s_retry.completed,
        "retry_useful_tokens": s_retry.useful_tokens,
        "retry_retries": s_retry.retries,
        "retry_failed": s_retry.failed,
        "retry_goodput_tok_per_step": round(
            s_retry.goodput_tokens_per_step, 3),
        "noretry_completed": s_none.completed,
        "noretry_useful_tokens": s_none.useful_tokens,
        "noretry_failed": s_none.failed,
        "noretry_goodput_tok_per_step": round(
            s_none.goodput_tokens_per_step, 3),
        "retry_recovers": (
            s_retry.completed >= s_none.completed
            and s_retry.useful_tokens >= s_none.useful_tokens),
    }
    out(f"retry-goodput,{arch},p=0.35,"
        f"retry={s_retry.completed}/{n_req} ok "
        f"({s_retry.retries} retries),"
        f"noretry={s_none.completed}/{n_req} ok,"
        f"{'RECOVERS' if row['retry_recovers'] else 'WORSE'}")
    return row


def main(out=print, quick: bool = False, check: bool = False,
         json_path: Optional[Path] = None) -> dict:
    import tempfile
    path = Path(json_path) if json_path else JSON_PATH
    t0 = time.perf_counter()
    rows: Dict[str, dict] = {}

    serve_archs = ("qwen1.5-0.5b",) if quick \
        else ("qwen1.5-0.5b", "mamba2-2.7b")
    out("row,detail")
    for arch in serve_archs:
        rows[f"serve-loss-{arch}"] = _serve_loss_row(arch, quick, out)
    with tempfile.TemporaryDirectory() as tmp:
        rows["train-recovery"] = _train_recovery_row(quick, out, tmp)
    for arch in serve_archs:
        rows[f"retry-goodput-{arch}"] = _retry_goodput_row(
            arch, quick, out)
    elapsed = time.perf_counter() - t0

    zero_lost = sum(1 for r in rows.values()
                    if r.get("zero_lost_acknowledged"))
    recovers = sum(1 for r in rows.values() if r.get("retry_recovers"))
    tr = rows["train-recovery"]
    out(f"# {len(rows)} rows, {zero_lost} zero-lost serve rows, "
        f"{recovers} retry-recovers rows, {elapsed:.1f}s")

    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    doc["resilience"] = {"rows": rows, "zero_lost_rows": zero_lost,
                         "retry_recovers_rows": recovers,
                         "quick": quick, "seconds": round(elapsed, 3)}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    out(f"# wrote {path}")

    if check:
        if zero_lost < len(serve_archs):
            raise SystemExit(
                f"only {zero_lost}/{len(serve_archs)} serve rows kept "
                f"zero lost acknowledged requests")
        if not (tr["healthy_plan_feasible"]
                and tr["stale_plan_feasible"] is False
                and tr["replan_feasible"]):
            raise SystemExit(
                "train-recovery row lost its feasibility flip: "
                f"healthy={tr['healthy_plan_feasible']} "
                f"stale={tr['stale_plan_feasible']} "
                f"replan={tr['replan_feasible']}")
        if tr["reached_step"] != tr["target_steps"]:
            raise SystemExit(
                f"training stopped at {tr['reached_step']} of "
                f"{tr['target_steps']} after recovery")
        if tr["resumed_from_step"] is None:
            raise SystemExit("device loss did not resume from a "
                             "checkpoint")
        if recovers < len(serve_archs):
            raise SystemExit(
                f"retry/backoff recovered goodput on only {recovers}"
                f"/{len(serve_archs)} rows")
        if elapsed > CEILING_S:
            raise SystemExit(
                f"run took {elapsed:.1f}s (ceiling {CEILING_S:.0f}s)")
        out("# check passed: zero-lost serving, train flip + resume, "
            "retry goodput, within ceiling")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI subset (smaller workloads)")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline claims and the ceiling")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    a = ap.parse_args()
    main(quick=a.quick, check=a.check, json_path=a.json)
