"""Fig. 8 — OSDP throughput with vs without operator splitting.

Same families as Fig. 5 under 8G/16G; reports the fraction of
operators the plan actually split (paper: ~25% N&D, 100% W&S, ~50%
I&C) and the throughput delta (paper: +3%..+92%).
"""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.fig5_end_to_end import _descriptions
from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8, paper_shape
from repro.configs.base import OSDPConfig
from repro.core.cost_model import CostEnv
from repro.core.search import schedule


def main(out=print) -> List[dict]:
    shape = paper_shape(8)
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False)
    out("family,model,mem_gib,no_split,with_split,delta_pct,frac_split_ops")
    rows = []
    for mem in (8, 16):
        lim = mem * 2**30
        for family, name, desc in _descriptions(shape):
            base = schedule(desc, env, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=False,
                allow_pod_hierarchical=False), batch_candidates=(8, 16, 32, 64, 128, 256))
            split = schedule(desc, env, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                default_slice_granularity=4,
                allow_pod_hierarchical=False), batch_candidates=(8, 16, 32, 64, 128, 256))
            t0 = base.cost.throughput if base.feasible else 0.0
            t1 = max(split.cost.throughput if split.feasible else 0.0, t0)
            n_split = sum(1 for d in split.decisions.values()
                          if d.split > 1 and d.uniform() is None)
            n_dec = max(1, sum(1 for d in split.decisions.values()))
            delta = (t1 / t0 - 1) * 100 if t0 else float("inf")
            out(f"{family},{name},{mem},{t0:.0f},{t1:.0f},{delta:.1f},"
                f"{n_split / n_dec:.2f}")
            rows.append({"family": family, "model": name, "mem": mem,
                         "no_split": t0, "with_split": t1})
    return rows


if __name__ == "__main__":
    main()
