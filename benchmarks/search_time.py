"""Search-engine timing (paper §3.2: 9–307 s for 98–194 operators).

Times dfs / knapsack / greedy at paper-scale per-layer granularity
and on the largest assigned architecture, plus solution-quality
cross-check (dfs is exact; others within tolerance).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8
from repro.configs import SINGLE_POD_MESH, DeviceInfo, OSDPConfig, get_arch, \
    get_shape
from repro.core.cost_model import CostEnv
from repro.core.descriptions import describe
from repro.core.search import search_plan


def main(out=print) -> List[dict]:
    out("case,n_ops,solver,seconds,step_time_ms,feasible")
    rows = []
    cases = [
        ("nd-96-perlayer", describe(get_arch("phi4-mini-3.8b"),
                                    get_shape("train_4k"), per_layer=True),
         CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False), 8 * 2**30,
         8),
        ("llama3-405b", describe(get_arch("llama3-405b"),
                                 get_shape("train_4k")),
         CostEnv(DeviceInfo(), SINGLE_POD_MESH), 64 * 2**30, 256),
        ("arctic-480b", describe(get_arch("arctic-480b"),
                                 get_shape("train_4k")),
         CostEnv(DeviceInfo(), SINGLE_POD_MESH), 16 * 2**30, 256),
    ]
    for name, desc, env, lim, batch in cases:
        for solver in ("dfs", "knapsack", "greedy"):
            osdp = OSDPConfig(search=solver, memory_limit_bytes=lim,
                              operator_splitting=True,
                              default_slice_granularity=4)
            t0 = time.perf_counter()
            res = search_plan(desc, batch, env, osdp)
            dt = time.perf_counter() - t0
            out(f"{name},{desc.n_operators},{solver},{dt:.3f},"
                f"{res.cost.time * 1e3:.2f},{res.feasible}")
            rows.append({"case": name, "solver": solver, "seconds": dt,
                         "time_ms": res.cost.time * 1e3})
    out("# paper DFS: 9-307 s; ours is branch-and-bound exact + pruned")
    return rows


if __name__ == "__main__":
    main()
