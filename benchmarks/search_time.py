"""Search-engine timing (paper §3.2: 9–307 s for 98–194 operators).

Times the three solvers (dfs / knapsack / greedy) at paper-scale
per-layer granularity — including the two largest assigned
architectures, llama3-405b (885 per-layer operators) and arctic-480b
(353) — plus a full n_devices=64 `search_hybrid` factorization sweep.

Results are written to ``BENCH_search.json`` at the repo root so the
planner-latency trajectory is tracked across PRs:

    {"schema": 1,
     "baseline": {case: {"seconds": ..., "solvers": {...}}},  # pre-PR2
     "current":  {case: {...}},                               # this tree
     "speedup":  {case: baseline_seconds / current_seconds}}

The ``baseline`` section is measured once against the pre-optimization
engine and committed; ``--record current`` (the default) refreshes only
the ``current`` section, so speedups always compare against the same
committed reference.  ``--quick`` runs a small case set for CI smoke
(``--check`` then fails the run if any case exceeds its generous
wall-clock ceiling).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8
from repro.configs import SINGLE_POD_MESH, DeviceInfo, OSDPConfig, get_arch, \
    get_shape
from repro.configs.base import DENSE, ModelConfig, ShapeConfig
from repro.core.cost_model import CostEnv
from repro.core.descriptions import describe
from repro.core.search import search_hybrid, search_plan

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"
SOLVERS = ("dfs", "knapsack", "greedy")

# generous wall-clock ceilings (seconds) for --check; ~20x headroom over
# the optimized engine so CI only trips on a real regression
CEILINGS = {
    "nd-96-perlayer": 15.0,
    "selective-remat": 60.0,
    "llama3-405b": 30.0,
    "arctic-480b": 30.0,
    "hybrid-16dev": 60.0,
    "hybrid-64dev": 120.0,
}


def _gpt(name: str, layers: int, hidden: int) -> ModelConfig:
    heads = max(8, hidden // 64)
    return ModelConfig(
        name=name, family=DENSE, n_layers=layers, d_model=hidden,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * hidden,
        vocab_size=50257, act="gelu", norm="layernorm", rope="none",
        tie_embeddings=True)


def _search_plan_cases(quick: bool, device: Optional[DeviceInfo] = None):
    """(name, desc, env, memory_limit_bytes, global_batch, checkpointing)
    tuples.

    The llama3-405b / arctic-480b limits sit between the all-DP and
    all-ZDP+split memory of the per-layer description, so every solver
    does real work (cover search + repair) instead of short-circuiting.
    The selective-remat case times the 4-mode axis (DP/ZDP x
    remat/no-remat per slice) at per-layer granularity — the widest
    decision space the engine searches.
    """
    cases = [
        ("nd-96-perlayer", describe(get_arch("phi4-mini-3.8b"),
                                    get_shape("train_4k"), per_layer=True),
         CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False), 8 * 2**30,
         8, False),
        ("selective-remat", describe(get_arch("phi4-mini-3.8b"),
                                     get_shape("train_4k"),
                                     per_layer=True),
         CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False), 16 * 2**30,
         8, "selective"),
    ]
    dev = device or DeviceInfo()
    if not quick:
        cases += [
            ("llama3-405b", describe(get_arch("llama3-405b"),
                                     get_shape("train_4k"), per_layer=True),
             CostEnv(dev, SINGLE_POD_MESH), 240 * 2**30, 256,
             True),
            ("arctic-480b", describe(get_arch("arctic-480b"),
                                     get_shape("train_4k"), per_layer=True),
             CostEnv(dev, SINGLE_POD_MESH), 80 * 2**30, 256,
             True),
        ]
    return cases


def _run_search_plan_case(name, desc, env, lim, batch, ckpt, out) -> dict:
    solvers: Dict[str, dict] = {}
    total = 0.0
    for solver in SOLVERS:
        osdp = OSDPConfig(search=solver, memory_limit_bytes=lim,
                          operator_splitting=True,
                          default_slice_granularity=4,
                          checkpointing=ckpt)
        t0 = time.perf_counter()
        res = search_plan(desc, batch, env, osdp)
        dt = time.perf_counter() - t0
        total += dt
        out(f"{name},{desc.n_operators},{solver},{dt:.3f},"
            f"{res.cost.time * 1e3:.2f},{res.feasible},{res.nodes_visited}")
        solvers[solver] = {"seconds": round(dt, 6),
                           "step_time_ms": round(res.cost.time * 1e3, 3),
                           "feasible": res.feasible,
                           "nodes_visited": res.nodes_visited}
    return {"seconds": round(total, 6), "n_operators": desc.n_operators,
            "solvers": solvers}


def _run_hybrid_case(name, desc, device, n_devices, lim, batch, out,
                     checkpointing=True) -> dict:
    osdp = OSDPConfig(search="dfs", memory_limit_bytes=lim,
                      operator_splitting=True,
                      default_slice_granularity=4,
                      allow_pod_hierarchical=False,
                      checkpointing=checkpointing)
    t0 = time.perf_counter()
    plan = search_hybrid(desc, device, n_devices, osdp,
                         batch_candidates=[batch])
    dt = time.perf_counter() - t0
    f = plan.factorization
    out(f"{name},{desc.n_operators},hybrid,{dt:.3f},"
        f"{plan.cost.time * 1e3:.2f},{plan.feasible},"
        f"dp={f.dp}/tp={f.tp}/pp={f.pp}")
    return {"seconds": round(dt, 6), "n_operators": desc.n_operators,
            "n_devices": n_devices, "feasible": plan.feasible,
            "factorization": [f.dp, f.tp, f.pp],
            "throughput_tok_s": round(plan.cost.throughput, 1),
            "swept": len(plan.swept)}


def _measure(quick: bool, out,
             device: Optional[DeviceInfo] = None) -> Dict[str, dict]:
    out("case,n_ops,solver,seconds,step_time_ms,feasible,work")
    results: Dict[str, dict] = {}
    for name, desc, env, lim, batch, ckpt in _search_plan_cases(quick,
                                                                device):
        results[name] = _run_search_plan_case(name, desc, env, lim, batch,
                                              ckpt, out)
    if quick:
        desc = describe(_gpt("nd-48x1024", 48, 1024),
                        ShapeConfig("paper_b64", 1024, 64, "train"),
                        per_layer=True)
        results["hybrid-16dev"] = _run_hybrid_case(
            "hybrid-16dev", desc, RTX_TITAN_8, 16, 16 * 2**30, 64, out,
            checkpointing=False)
    else:
        # 480B over 64 chips has a ~120 GiB/device state floor even fully
        # sharded, so the limit is set where most factorizations are live
        # (24 feasible sweep points) and the inner searches do real work.
        desc = describe(get_arch("arctic-480b"), get_shape("train_4k"),
                        per_layer=True)
        results["hybrid-64dev"] = _run_hybrid_case(
            "hybrid-64dev", desc, device or DeviceInfo(), 64,
            192 * 2**30, 64, out)
    return results


def _merge(path: Path, record: str, results: Dict[str, dict],
           quick: bool) -> dict:
    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    section = doc.setdefault(record, {})
    section.update(results)
    base, cur = doc.get("baseline", {}), doc.get("current", {})
    doc["speedup"] = {
        case: round(base[case]["seconds"] / max(cur[case]["seconds"], 1e-9),
                    2)
        for case in base if case in cur}
    doc["quick"] = quick
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc


def main(out=print, quick: bool = False, record: str = "current",
         check: bool = False, json_path: Optional[Path] = None,
         device: Optional[str] = None) -> dict:
    path = Path(json_path) if json_path else JSON_PATH
    results = _measure(quick, out,
                       DeviceInfo.preset(device) if device else None)
    doc = _merge(path, record, results, quick)
    out(f"# wrote {path}")
    if doc.get("speedup"):
        for case, x in sorted(doc["speedup"].items()):
            out(f"# speedup[{case}] = {x:.2f}x")
    out("# paper DFS: 9-307 s; ours is branch-and-bound exact + pruned")
    if check:
        slow = [(c, r["seconds"], CEILINGS[c]) for c, r in results.items()
                if c in CEILINGS and r["seconds"] > CEILINGS[c]]
        if slow:
            raise SystemExit(
                "perf-smoke regression: " + ", ".join(
                    f"{c} took {s:.1f}s (ceiling {lim:.0f}s)"
                    for c, s, lim in slow))
        out("# perf-smoke: all cases within ceilings")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small case set for CI smoke")
    ap.add_argument("--record", choices=("baseline", "current"),
                    default="current",
                    help="which BENCH_search.json section to update")
    ap.add_argument("--check", action="store_true",
                    help="fail if any case exceeds its wall-clock ceiling")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="DeviceInfo preset for the large-model cases "
                         "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
    a = ap.parse_args()
    main(quick=a.quick, record=a.record, check=a.check, json_path=a.json,
         device=a.device)
