"""Fleet serving: SLO-aware multi-replica planning vs uniform replication.

Two row families, recorded in ``BENCH_search.json`` under ``"fleet"``:

  * **plan rows** (cost model): `search_fleet` partitions a
    heterogeneous `mixed_memory_fleet` into replica groups for a
    two-class workload (latency-sensitive interactive + long batch)
    under each strategy.  The SLO-aware plan isolates the classes onto
    the device groups that fit them; the uniform baseline replicates
    one identical plan (bounded by the smallest device's HBM) and
    routes every class everywhere.

  * **sim rows** (executed on the host): both fleet shapes serve the
    SAME seeded Poisson trace through real `ContinuousEngine` replicas
    on the deterministic tick clock.  Headline asserts: the SLO-aware
    fleet strictly beats uniform replication on goodput-under-SLO
    (tokens from requests that met their class SLO, per tick) AND on
    the interactive class's p99 ttft; replaying the SLO fleet
    reproduces its report fingerprint byte-for-byte.

Mechanically, the sim is a scale model of the plan: each planned
replica group becomes one reduced-model engine replica tagged with the
group's routed classes, the plan's routing table drives the simulator's
weighted join-shortest-queue router, and per-class admission caps are
recomputed with the planner's 2x-occupancy rule at sim scale.

``--quick`` shrinks the horizon for CI; ``--check`` asserts the
headline claims above plus the wall-clock ceiling.
"""
from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path
from typing import Dict, List, Optional

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"
CEILING_S = 420.0          # --check wall-clock ceiling (whole run)

PLAN_ARCH = "qwen1.5-0.5b"
SIM_ARCH = "qwen1.5-0.5b"

# analytic mix (rates in requests/s at plan scale): an interactive
# class that only meets its ttft/tpot SLOs on the high-HBM group, and
# a long batch class that fits the low-HBM group
PLAN_CLASSES = dict(
    interactive=dict(prompt_len=128, decode_len=32, arrival_rate=8.0,
                     ttft_slo=0.05, tpot_slo=0.02),
    batch=dict(prompt_len=2048, decode_len=256, arrival_rate=0.5),
)

# sim mix (rates in requests/tick at engine scale): same class names,
# same shape skew — batch requests occupy a slot ~8x longer
SIM_CLASSES = dict(
    interactive=dict(prompt_len=8, decode_len=6, arrival_rate=0.5),
    batch=dict(prompt_len=16, decode_len=32, arrival_rate=0.2),
)
SIM_SLO_TICKS = {"interactive": (2.0, 2.5), "batch": (60.0, 3.0)}
# engine-step deadlines: queue-stuck or straggling requests TIME OUT
# (uniform replication admits doomed batch work that burns slots;
# the SLO fleet's admission caps reject it at the router instead)
SIM_DEADLINE_TICKS = {"interactive": 30, "batch": 90}
SIM_SLOTS = 4
SIM_CACHE_LEN = 48


def _mix(spec: Dict[str, dict]):
    from repro.core.cost_model import RequestClass, RequestClassMix
    return RequestClassMix(tuple(
        RequestClass(name, **kw) for name, kw in sorted(spec.items())))


def _cluster():
    from repro.cluster.topology import mixed_memory_fleet
    return mixed_memory_fleet(8, 4.0, 8, 16.0, pod_size=4)


def _plan_row(strategy: str, out) -> tuple:
    from repro.configs import get_arch
    from repro.core.api import search_fleet

    plan = search_fleet(get_arch(PLAN_ARCH), mix=_mix(PLAN_CLASSES),
                        cluster=_cluster(), memory_limit_gib=4.0,
                        replica_candidates=(1, 2, 4),
                        strategy=strategy)
    row = {
        "model": PLAN_ARCH, "strategy": strategy,
        "feasible": plan.feasible,
        "n_groups": len(plan.groups),
        "n_replicas": plan.n_replicas,
        "slo_attained": plan.slo_attained,
        "n_slo_attained": plan.n_slo_attained,
        "throughput_tok_s": round(plan.throughput, 1),
        "goodput_tok_s": round(plan.goodput, 1),
        "admission": plan.admission,
        "groups": [{
            "name": g.name, "replicas": g.n_replicas,
            "devices_per_replica": g.devices_per_replica,
            "classes": list(g.classes),
            "slots_per_device": g.plan.slots_per_device,
            "capacity_tok_s": round(g.capacity_tokens_per_s, 1),
        } for g in plan.groups],
        "search_s": round(plan.search_seconds, 3),
    }
    out(f"plan,{strategy},{len(plan.groups)}groups,"
        f"{plan.n_replicas}replicas,"
        f"slo={plan.n_slo_attained}/{len(plan.mix)},"
        f"goodput={plan.goodput:.0f}tok/s")
    return plan, row


def _sim_admission(plan, mix) -> Dict[str, int]:
    """The planner's 2x-occupancy admission rule recomputed at sim
    scale: cap = 2 * (sim replicas serving the class) * slots * the
    class's slot share among the classes it is colocated with."""
    caps: Dict[str, int] = {}
    for c in mix.classes:
        occ = 0.0
        for g in plan.groups:
            if c.name not in g.classes:
                continue
            sub = mix.subset(g.classes)
            occ += 1 * SIM_SLOTS * sub.slot_share(c.name)
        caps[c.name] = max(1, math.ceil(2.0 * occ))
    return caps


def _make_fleet(plan, uniform_n: int):
    """Scale model of a plan: one engine per planned group (uniform:
    `uniform_n` identical engines), all at SIM_SLOTS slots."""
    import jax
    from repro.configs import (MeshConfig, OSDPConfig, RunConfig,
                               get_arch, get_shape, reduced)
    from repro.models.registry import build_model
    from repro.serving.engine import ContinuousEngine
    from repro.serving.simulator import SimReplica, fleet_replicas

    cfg = reduced(get_arch(SIM_ARCH))
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))

    def make(_group=None):
        return ContinuousEngine(built, params, max_slots=SIM_SLOTS,
                                cache_len=SIM_CACHE_LEN, max_queue=64)

    if plan is not None:
        return fleet_replicas(plan, make, max_replicas_per_group=1)
    return [SimReplica(f"uniform/{j}", "uniform", make())
            for j in range(uniform_n)]


def _sim_row(name: str, replicas, mix, arrivals, *, routing, admission,
             seed: int, out) -> dict:
    from repro.serving.simulator import TrafficSimulator

    t0 = time.perf_counter()
    sim = TrafficSimulator(replicas, mix, routing=routing,
                           admission=admission,
                           deadline_ticks=SIM_DEADLINE_TICKS,
                           slo_ticks=SIM_SLO_TICKS, seed=seed)
    rep = sim.run(arrivals)
    wall = time.perf_counter() - t0
    row = {
        "fleet": name, "replicas": len(replicas),
        "slots_per_replica": SIM_SLOTS,
        "arrivals": len(arrivals), "ticks": rep.ticks,
        "completed": rep.completed,
        "goodput_tok_per_tick": round(rep.goodput_tokens_per_tick, 3),
        "slo_good_tokens": sum(c.slo_good_tokens
                               for c in rep.per_class.values()),
        "slo_goodput_tok_per_tick": round(
            rep.slo_goodput_tokens_per_tick, 3),
        "slo_attainment": round(rep.slo_attainment, 4),
        "classes": {n: c.row() for n, c in rep.per_class.items()},
        "fingerprint": rep.fingerprint(),
        "wall_s": round(wall, 3),
    }
    it = rep.per_class["interactive"]
    out(f"sim,{name},{len(replicas)}x{SIM_SLOTS}slots,"
        f"{len(arrivals)}req/{rep.ticks}ticks,"
        f"slo_good_tokens={row['slo_good_tokens']},"
        f"interactive_p99_ttft={it.ttft_p99:.1f}ticks,"
        f"attain={row['slo_attainment']}")
    return row


def main(out=print, quick: bool = False, check: bool = False,
         json_path: Optional[Path] = None) -> dict:
    from repro.serving.simulator import poisson_arrivals

    path = Path(json_path) if json_path else JSON_PATH
    t0 = time.perf_counter()
    rows: Dict[str, dict] = {}

    out("row,detail")
    slo_plan, rows["plan-slo"] = _plan_row("slo", out)
    _, rows["plan-uniform"] = _plan_row("uniform", out)

    sim_mix = _mix(SIM_CLASSES)
    horizon = 60 if quick else 160
    arrivals = poisson_arrivals(sim_mix, horizon=horizon, seed=11)

    slo_fleet = _make_fleet(slo_plan, 0)
    rows["sim-slo"] = _sim_row(
        "slo", slo_fleet, sim_mix, arrivals,
        routing=slo_plan.routing,
        admission=_sim_admission(slo_plan, sim_mix), seed=0, out=out)
    n_uniform = len(slo_fleet)
    rows["sim-uniform"] = _sim_row(
        "uniform", _make_fleet(None, n_uniform), sim_mix, arrivals,
        routing=None, admission=None, seed=0, out=out)

    # replay: a fresh fleet + simulator must reproduce the fingerprint
    replay = _sim_row(
        "slo-replay", _make_fleet(slo_plan, 0), sim_mix, arrivals,
        routing=slo_plan.routing,
        admission=_sim_admission(slo_plan, sim_mix), seed=0,
        out=lambda *a: None)
    rows["sim-slo"]["replay_identical"] = (
        replay["fingerprint"] == rows["sim-slo"]["fingerprint"])
    elapsed = time.perf_counter() - t0

    # both fleets serve the identical arrival trace, so total
    # SLO-good tokens is the fair goodput comparison (per-tick rates
    # would penalize whichever fleet's drain tail runs longer)
    s, u = rows["sim-slo"], rows["sim-uniform"]
    slo_wins = (
        s["slo_good_tokens"] > u["slo_good_tokens"]
        and (s["classes"]["interactive"]["ttft_p99_ticks"]
             < u["classes"]["interactive"]["ttft_p99_ticks"]))
    out(f"# {len(rows)} rows, slo_beats_uniform={slo_wins}, "
        f"replay={'OK' if s['replay_identical'] else 'MISMATCH'}, "
        f"{elapsed:.1f}s")

    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    doc["fleet"] = {"rows": rows, "slo_beats_uniform": slo_wins,
                    "quick": quick, "seconds": round(elapsed, 3)}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    out(f"# wrote {path}")

    if check:
        if not rows["plan-slo"]["feasible"]:
            raise SystemExit("SLO-aware fleet plan infeasible")
        if (rows["plan-slo"]["n_slo_attained"]
                < rows["plan-uniform"]["n_slo_attained"]):
            raise SystemExit("uniform plan attains more SLOs than the "
                             "SLO-aware plan")
        if not s["replay_identical"]:
            raise SystemExit("simulator replay fingerprint mismatch")
        if not slo_wins:
            raise SystemExit(
                "SLO-aware fleet did not strictly beat uniform: "
                f"slo_good_tokens {s['slo_good_tokens']} vs "
                f"{u['slo_good_tokens']}, interactive p99 "
                f"ttft {s['classes']['interactive']['ttft_p99_ticks']} "
                f"vs {u['classes']['interactive']['ttft_p99_ticks']}")
        if elapsed > CEILING_S:
            raise SystemExit(
                f"run took {elapsed:.1f}s (ceiling {CEILING_S:.0f}s)")
        out("# check passed: feasible SLO plan, replay identical, "
            "strict SLO-over-uniform win, within ceiling")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI subset (shorter traffic horizon)")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline claims and the ceiling")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    a = ap.parse_args()
    main(quick=a.quick, check=a.check, json_path=a.json)
