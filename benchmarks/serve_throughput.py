"""Serving throughput: static vs continuous vs planned.

Two row families, recorded in ``BENCH_search.json`` under ``"serving"``:

  * **engine rows** (executed on the host): a reduced model serves a
    mixed-length request set twice — with the legacy static batch
    engine (arrival-order groups, lockstep decode to the group's
    longest request) and with the continuous engine at the SAME slot
    count.  Both engines issue batched decode steps of identical
    shape, so the deterministic metric is *decode steps per useful
    token*: continuous batching must strictly beat static on every
    mixed row (finished slots are re-admitted from the queue instead
    of idling until the group's stragglers drain).  Wall clock is
    reported for reference but never asserted.

  * **planner rows** (cost model): `search_serve` against full-size
    models and device presets.  The headline assert is the
    feasibility flip — a (model, memory-limit) pair the unplanned
    (1,1)-mesh DP engine cannot fit, served by the searched
    sharding + admission plan.

``--quick`` shrinks the engine workload for CI; ``--check`` asserts
>= 3 strict continuous-over-static engine wins, >= 1 feasibility
flip, and the wall-clock ceiling.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"
CEILING_S = 420.0          # --check wall-clock ceiling (whole run)

ENGINE_ARCHS = ("qwen1.5-0.5b", "mamba2-2.7b", "hymba-1.5b")


def _mixed_lengths(n_req: int, long_new: int, short_new: int) -> List[int]:
    """Every 4th request decodes long, the rest short — the skew that
    makes lockstep batching idle 3/4 of its slots."""
    return [long_new if i % 4 == 0 else short_new for i in range(n_req)]


def _run_engine_row(arch: str, quick: bool, out) -> dict:
    import jax
    from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                               get_shape, reduced)
    from repro.models.registry import build_model
    from repro.serving.engine import ContinuousEngine, Engine, Request

    cfg = reduced(get_arch(arch))
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(0))

    n_req, slots = (8, 2) if quick else (16, 4)
    prompt_len = 16
    long_new, short_new = (24, 4) if quick else (48, 6)
    news = _mixed_lengths(n_req, long_new, short_new)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (n_req, prompt_len)).astype(np.int32)
    useful = sum(news)
    cache_len = prompt_len + long_new

    # static: arrival-order groups of `slots`, lockstep to the longest
    t0 = time.perf_counter()
    eng = Engine(built, params, cache_len=cache_len)
    static_steps = static_prefills = 0
    for g0 in range(0, n_req, slots):
        grp = list(range(g0, min(g0 + slots, n_req)))
        n_max = max(news[i] for i in grp)
        eng.generate(prompts[grp], n_max)
        static_prefills += 1
        static_steps += n_max
    static_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ce = ContinuousEngine(built, params, max_slots=slots,
                          cache_len=cache_len)
    results, stats = ce.run([Request(i, prompts[i], news[i])
                             for i in range(n_req)])
    cont_s = time.perf_counter() - t0
    assert stats.useful_tokens == useful and stats.completed == n_req

    row = {
        "requests": n_req, "slots": slots, "useful_tokens": useful,
        "static_decode_steps": static_steps,
        "continuous_decode_steps": stats.decode_steps,
        "static_tok_per_step": round(useful / static_steps, 3),
        "continuous_tok_per_step": round(useful / stats.decode_steps, 3),
        "continuous_win": stats.decode_steps < static_steps,
        "static_wall_s": round(static_s, 3),
        "continuous_wall_s": round(cont_s, 3),
        "mean_ttft_ms": round(
            1e3 * float(np.mean([r.ttft_s for r in results])), 2),
        "mean_latency_ms": round(
            1e3 * float(np.mean([r.latency_s for r in results])), 2),
    }
    out(f"{arch},{n_req},{slots},{static_steps},{stats.decode_steps},"
        f"{row['static_tok_per_step']},{row['continuous_tok_per_step']},"
        f"{'WIN' if row['continuous_win'] else 'tie'}")
    return row


def _planner_row(name: str, arch: str, limit_gib: float, n_devices: int,
                 device_preset: Optional[str], prompt_len: int,
                 decode_len: int, out) -> dict:
    from repro.configs import DeviceInfo, get_arch
    from repro.core.api import search_serve

    cfg = get_arch(arch)
    device = (DeviceInfo.preset(device_preset)
              if device_preset else None)
    naive = search_serve(cfg, prompt_len=prompt_len,
                         decode_len=decode_len, n_devices=1,
                         memory_limit_gib=limit_gib, device=device,
                         force_mode="DP", max_slots=64)
    plan = search_serve(cfg, prompt_len=prompt_len,
                        decode_len=decode_len, n_devices=n_devices,
                        memory_limit_gib=limit_gib, device=device)
    flip = (not naive.feasible) and plan.feasible
    n_zdp = sum(1 for d in plan.decisions.values()
                if d.uniform() not in ("DP", None))
    row = {
        "model": arch, "limit_gib": limit_gib, "n_devices": n_devices,
        "device": device_preset or "tpu-v5e",
        "naive_feasible": naive.feasible,
        "planned_feasible": plan.feasible,
        "feasibility_flip": flip,
        "zdp_ops": n_zdp,
        "concurrency": plan.max_concurrency,
        "slots_per_device": plan.slots_per_device,
        "tpot_ms": round(plan.cost.tpot * 1e3, 3),
        "ttft_ms": round(plan.cost.ttft * 1e3, 3),
        "throughput_tok_s": round(plan.cost.throughput, 1),
        "memory_gib": round(plan.cost.memory / 2**30, 2),
    }
    out(f"{name},{arch},{n_devices}dev@{limit_gib:.0f}GiB,"
        f"naive={'ok' if naive.feasible else 'OOM'},"
        f"planned={'ok' if plan.feasible else 'OOM'},"
        f"conc={plan.max_concurrency},"
        f"{'FLIP' if flip else '-'}")
    return row


def main(out=print, quick: bool = False, check: bool = False,
         json_path: Optional[Path] = None) -> dict:
    path = Path(json_path) if json_path else JSON_PATH
    t0 = time.perf_counter()
    rows: Dict[str, dict] = {}

    out("arch,requests,slots,static_steps,cont_steps,"
        "static_tok/step,cont_tok/step,verdict")
    for arch in ENGINE_ARCHS:
        rows[f"engine-{arch}"] = _run_engine_row(arch, quick, out)

    out("case,model,fleet,naive,planned,concurrency,flip")
    rows["plan-llama3-405b"] = _planner_row(
        "plan-llama3-405b", "llama3-405b", 16.0, 256, None, 512, 128, out)
    rows["plan-dbrx-132b"] = _planner_row(
        "plan-dbrx-132b", "dbrx-132b", 80.0, 8, "a100-80g", 512, 128, out)
    rows["plan-qwen1.5-0.5b"] = _planner_row(
        "plan-qwen1.5-0.5b", "qwen1.5-0.5b", 4.0, 1, None, 128, 64, out)
    elapsed = time.perf_counter() - t0

    wins = sum(1 for r in rows.values() if r.get("continuous_win"))
    flips = sum(1 for r in rows.values() if r.get("feasibility_flip"))
    out(f"# {len(rows)} rows, {wins} continuous wins, {flips} "
        f"feasibility flips, {elapsed:.1f}s")

    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    doc["serving"] = {"rows": rows, "engine_wins": wins,
                      "feasibility_flips": flips, "quick": quick,
                      "seconds": round(elapsed, 3)}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    out(f"# wrote {path}")

    if check:
        if wins < 3:
            raise SystemExit(
                f"continuous batching won only {wins} engine rows (< 3)")
        if flips < 1:
            raise SystemExit("no serving feasibility flip")
        if elapsed > CEILING_S:
            raise SystemExit(
                f"run took {elapsed:.1f}s (ceiling {CEILING_S:.0f}s)")
        out("# check passed: >= 3 continuous wins, >= 1 flip, "
            "within ceiling")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI subset (smaller request sets)")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline claims and the ceiling")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    a = ap.parse_args()
    main(quick=a.quick, check=a.check, json_path=a.json)
