"""Solver optimality audit: every search backend vs the exact ILP.

OSDP's claim is *optimality* of the searched plan, but dfs / knapsack /
greedy are engineered solvers whose bounds were asserted nowhere
against ground truth (ROADMAP item 4).  This benchmark re-solves a
model-zoo x memory-limit x batch grid with all four backends and
scores each against the ``search="ilp"`` oracle (``repro.core.ilp``):

    gap(solver) = step_time(solver) / step_time(ilp) - 1

recording per-row gaps, decision identity, and effort (nodes, seconds)
into the ``"solver_audit"`` section of ``BENCH_search.json``.

``--check`` (CI gate) asserts the audit table:

  * all four backends agree on feasibility, row by row;
  * the ilp proves optimality on every row (no time budget given);
  * dfs is *exact*: gap == 0 and decisions byte-identical to the ilp
    on every row where its node budget does not truncate — i.e. all
    legacy (2/3-mode) rows.  On selective-remat rows the 4-mode dfs is
    budget-capped by design (PR 3, 10k nodes: the unbudgeted search
    does not terminate in minutes on problems the MILP closes in
    milliseconds) and carries a real, bounded gap — the audit records
    it instead of leaving it folklore;
  * knapsack's quantization gap and greedy's heuristic gap stay under
    their ceilings;
  * no solver beats the proven optimum (gap >= 0 up to evaluator
    repair noise).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, Optional

from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8
from repro.configs import (SINGLE_POD_MESH, DeviceInfo, OSDPConfig,
                           SOLVERS, get_arch, get_shape)
from repro.core.cost_model import CostEnv
from repro.core.descriptions import describe
from repro.core.search import search_plan

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_search.json"

# --check ceilings on the relative step-time gap vs the ilp optimum.
# dfs: exact on legacy rows (asserted == 0 there); the selective rows
# run it budget-truncated, where the measured gap is ~2.3% — ceiling 5%.
# knapsack's gap is its quantization loss (~0.5% legacy, ~2.1% on the
# adaptive-quantum selective rows; exactness on the *quantized* problem
# is asserted solver-level in tests/test_solver_oracle.py); greedy is
# the unbounded heuristic, measured 8.8% worst-case on the grid.
GAP_CEILINGS = {"dfs": 0.05, "knapsack": 0.03, "greedy": 0.10}
# the ilp is exact w.r.t. the solvers' per-slice item model, which is
# itself a (slightly optimistic) approximation of the PlanEvaluator —
# a heuristic's different cover can evaluate up to ~0.1% cheaper
# through the evaluator, so "nobody beats the optimum" is asserted to
# this model-vs-evaluator tolerance, not to float epsilon
EVAL_TOL = 2e-3


def _grid(quick: bool, device: Optional[DeviceInfo] = None):
    """(row_name, desc, env, limit_bytes, batch, checkpointing) rows.

    Limits sit between the all-DP and all-ZDP+split memory of each
    description so the cover solves do real work; the 8 GiB phi4 row
    and the 16 GiB selective row are the committed BENCH quick cases.
    """
    dev = device or DeviceInfo()
    phi4 = describe(get_arch("phi4-mini-3.8b"), get_shape("train_4k"),
                    per_layer=True)
    env8 = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False)
    qwen = describe(get_arch("qwen1.5-0.5b"), get_shape("train_4k"))
    envq = CostEnv(dev, SINGLE_POD_MESH, checkpointing=False)
    rows = [
        # the committed BENCH quick case (8 GiB is below the fully-
        # sharded floor: an infeasible-agreement + fallback-identity row)
        ("phi4-perlayer@8g-b8", phi4, env8, 8 * 2**30, 8, False),
        # inside the [20.3, 24] GiB feasibility window: real cover work
        ("phi4-perlayer@21g-b8", phi4, env8, 21 * 2**30, 8, False),
        ("phi4-perlayer@22.5g-b8", phi4, env8,
         int(22.5 * 2**30), 8, False),
        # the committed selective quick case: the 4-mode axis, where
        # the budget-truncated dfs carries a real gap
        ("phi4-perlayer@16g-b8-sel", phi4, env8, 16 * 2**30, 8,
         "selective"),
        # qwen's window is narrow ([2.22, 2.60] GiB at b256) — two
        # frontier rows where the cover is tight
        ("qwen0.5@2.3g-b256", qwen, envq, int(2.3 * 2**30), 256, False),
        ("qwen0.5@2.45g-b256", qwen, envq, int(2.45 * 2**30), 256,
         False),
    ]
    if not quick:
        mamba = describe(get_arch("mamba2-2.7b"), get_shape("train_4k"))
        dbrx = describe(get_arch("dbrx-132b"), get_shape("train_4k"))
        env_on = CostEnv(dev, SINGLE_POD_MESH, checkpointing=True)
        rows += [
            (f"mamba2@{g}g-b256", mamba, envq, g * 2**30, 256, False)
            for g in (11, 12)
        ] + [
            ("dbrx@16g-b256", dbrx, env_on, 16 * 2**30, 256, True),
            ("phi4-perlayer@21g-b16", phi4, env8, 21 * 2**30, 16, False),
            ("phi4-perlayer@12g-b8-sel", phi4, env8, 12 * 2**30, 8,
             "selective"),
            ("llama3-perlayer@240g-b256",
             describe(get_arch("llama3-405b"), get_shape("train_4k"),
                      per_layer=True), env_on, 240 * 2**30, 256, True),
            ("arctic-perlayer@80g-b256",
             describe(get_arch("arctic-480b"), get_shape("train_4k"),
                      per_layer=True), env_on, 80 * 2**30, 256, True),
        ]
    return rows


def _run_row(name, desc, env, lim, batch, ckpt, out) -> dict:
    per: Dict[str, dict] = {}
    results = {}
    for solver in SOLVERS:
        cfg = OSDPConfig(search=solver, memory_limit_bytes=lim,
                         operator_splitting=True,
                         default_slice_granularity=4,
                         checkpointing=ckpt)
        t0 = time.perf_counter()
        res = search_plan(desc, batch, env, cfg)
        dt = time.perf_counter() - t0
        results[solver] = res
        per[solver] = {"seconds": round(dt, 6),
                       "step_time_ms": round(res.cost.time * 1e3, 3),
                       "feasible": res.feasible,
                       "nodes_visited": res.nodes_visited}
    ref = results["ilp"]
    per["ilp"]["proven_optimal"] = bool(ref.proven_optimal)
    per["ilp"]["backend"] = ref.solver_backend
    if ref.lower_bound is not None:
        per["ilp"]["cover_lower_bound"] = round(float(ref.lower_bound), 9)
    for solver in SOLVERS:
        res = results[solver]
        gap = (res.cost.time / ref.cost.time - 1.0
               if ref.feasible and res.feasible else None)
        per[solver]["gap"] = (round(gap, 9) if gap is not None else None)
        per[solver]["decisions_identical"] = \
            res.decisions == ref.decisions
        out(f"{name},{solver},{per[solver]['seconds']:.3f},"
            f"{per[solver]['step_time_ms']:.2f},{res.feasible},"
            f"{per[solver]['gap']},{per[solver]['decisions_identical']}")
    return {"selective": ckpt == "selective", "n_operators":
            desc.n_operators, "solvers": per}


def _merge(path: Path, rows: Dict[str, dict], quick: bool,
           seconds: float) -> dict:
    doc = {"schema": 1}
    if path.exists():
        doc = json.loads(path.read_text())
    worst = {
        s: max((r["solvers"][s]["gap"] or 0.0) for r in rows.values())
        for s in SOLVERS}
    doc["solver_audit"] = {
        "quick": quick,
        "seconds": round(seconds, 3),
        "rows": rows,
        "worst_gap": {s: round(g, 9) for s, g in worst.items()},
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc


def _check(rows: Dict[str, dict], out) -> None:
    errors = []
    for name, row in rows.items():
        per = row["solvers"]
        ref = per["ilp"]
        if not ref["proven_optimal"]:
            errors.append(f"{name}: ilp did not prove optimality")
        feas = {s: per[s]["feasible"] for s in SOLVERS}
        if len(set(feas.values())) != 1:
            errors.append(f"{name}: feasibility disagreement {feas}")
            continue
        for s in SOLVERS:
            gap = per[s]["gap"]
            if gap is None:
                continue
            if gap < -EVAL_TOL:
                errors.append(
                    f"{name}: {s} beats the proven optimum by "
                    f"{-gap:.2e} — ilp reference is broken")
            if gap > GAP_CEILINGS.get(s, 0.0):
                errors.append(
                    f"{name}: {s} gap {gap:.4%} exceeds ceiling "
                    f"{GAP_CEILINGS.get(s, 0.0):.0%}")
        # exactness: dfs (and its decisions) wherever its budget does
        # not truncate — every non-selective row
        if not row["selective"]:
            if per["dfs"]["gap"] not in (None, 0.0):
                errors.append(
                    f"{name}: dfs gap {per['dfs']['gap']} != 0 on a "
                    f"legacy row — dfs is supposed to be exact here")
            if not per["dfs"]["decisions_identical"]:
                errors.append(
                    f"{name}: ilp decisions differ from dfs on a row "
                    f"where both are exact (canonical decode broke)")
    if errors:
        raise SystemExit("solver audit failed:\n  " + "\n  ".join(errors))
    out("# solver audit: all gap/identity assertions hold")


def main(out=print, quick: bool = False, check: bool = False,
         json_path: Optional[Path] = None,
         device: Optional[str] = None) -> dict:
    path = Path(json_path) if json_path else JSON_PATH
    out("row,solver,seconds,step_time_ms,feasible,gap,decisions==ilp")
    t0 = time.perf_counter()
    rows = {}
    for name, desc, env, lim, batch, ckpt in _grid(
            quick, DeviceInfo.preset(device) if device else None):
        rows[name] = _run_row(name, desc, env, lim, batch, ckpt, out)
    doc = _merge(path, rows, quick, time.perf_counter() - t0)
    out(f"# wrote {path}")
    for s, g in sorted(doc["solver_audit"]["worst_gap"].items()):
        out(f"# worst_gap[{s}] = {g:.4%}")
    if check:
        _check(rows, out)
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small grid for CI smoke")
    ap.add_argument("--check", action="store_true",
                    help="fail on any gap/identity assertion")
    ap.add_argument("--json", type=Path, default=None,
                    help=f"output path (default {JSON_PATH})")
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="DeviceInfo preset for the zoo rows")
    a = ap.parse_args()
    main(quick=a.quick, check=a.check, json_path=a.json, device=a.device)
