"""Beyond-paper: auto slice granularity vs the paper's fixed g=4.

The paper (§4.3) fixes g=4 and names per-operator granularity tuning
as future work. `auto_granularity` picks per-op g from the cost model
(alpha latency vs gathered-slice bytes at a ring-rate shadow price).
This bench compares feasibility/throughput across the Table-1 families
and the assigned architectures.
"""
from __future__ import annotations

from benchmarks.fig5_end_to_end import _descriptions
from benchmarks.paper_models import MESH_8GPU, RTX_TITAN_8, paper_shape
from repro.configs import (DeviceInfo, SINGLE_POD_MESH, OSDPConfig,
                           get_arch, get_shape)
from repro.core.cost_model import CostEnv
from repro.core.descriptions import describe
from repro.core.search import auto_granularity, schedule, search_plan


def main(out=print):
    shape = paper_shape(8)
    env = CostEnv(RTX_TITAN_8, MESH_8GPU, checkpointing=False)
    out("case,fixed_g4_tput,auto_g_tput,delta_pct")
    cands = (8, 16, 32, 64, 128, 256)
    for mem in (8,):
        lim = mem * 2**30
        for family, name, desc in _descriptions(shape):
            fixed = schedule(desc, env, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                default_slice_granularity=4,
                allow_pod_hierarchical=False), batch_candidates=cands)
            auto = schedule(desc, env, OSDPConfig(
                memory_limit_bytes=lim, operator_splitting=True,
                auto_granularity=True,
                allow_pod_hierarchical=False), batch_candidates=cands)
            t0 = fixed.cost.throughput if fixed.feasible else 0.0
            t1 = auto.cost.throughput if auto.feasible else 0.0
            d = (t1 / t0 - 1) * 100 if t0 else (float("inf") if t1 else 0.0)
            out(f"{family}/{name},{t0:.0f},{t1:.0f},{d:.1f}")
    # per-op chosen granularities on the biggest assigned arch
    desc = describe(get_arch("llama3-405b"), get_shape("train_4k"))
    env2 = CostEnv(DeviceInfo(), SINGLE_POD_MESH)
    osdp = OSDPConfig(operator_splitting=True, auto_granularity=True)
    out("# llama3-405b auto granularities (op: g)")
    for op in desc.decidable():
        if op.splittable:
            out(f"#   {op.name}: g={auto_granularity(op, env2, osdp)}")


if __name__ == "__main__":
    main()
