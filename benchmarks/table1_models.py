"""Table 1 — model-family statistics (layers, operators, hidden,
params) for the paper families + the 10 assigned architectures."""
from __future__ import annotations

from benchmarks.paper_models import (IC_SPECS, ND_MODELS, WS_MODELS,
                                     ic_description, nd_ws_description,
                                     paper_shape)
from repro.configs import ARCHS, get_shape
from repro.core.descriptions import describe


def main(out=print):
    shape = paper_shape(8)
    out("family,model,layers,operators,hidden,params_B")
    for fam, cfgs in (("N&D", ND_MODELS), ("W&S", WS_MODELS)):
        for cfg in cfgs:
            desc = nd_ws_description(cfg, shape)
            out(f"{fam},{cfg.name},{cfg.n_layers},{desc.n_operators},"
                f"{cfg.d_model},{cfg.param_count() / 1e9:.2f}")
    for name, hiddens in IC_SPECS:
        desc = ic_description(name, hiddens, shape)
        out(f"I&C,{name},{len(hiddens)},{desc.n_operators},"
            f"{min(hiddens)}-{max(hiddens)},"
            f"{desc.total_params / 1e9:.2f}")
    out("# assigned architectures")
    for name, cfg in sorted(ARCHS.items()):
        desc = describe(cfg, get_shape("train_4k"))
        out(f"{cfg.family},{name},{cfg.n_layers},{desc.n_operators},"
            f"{cfg.d_model},{cfg.param_count() / 1e9:.2f}")


if __name__ == "__main__":
    main()
