"""Mixed-precision AdamW whose states inherit the OSDP plan.

ZeRO semantics fall out of sharding: each parameter's fp32 master copy
and the (m, v) moments are elementwise functions of the (possibly
ZDP-sharded) parameter, so pinning their shardings to the parameter's
sharding makes DP operators keep replicated states (the paper's DP
memory cost) and ZDP operators keep 1/N states — no optimizer-specific
communication is ever needed (the reduce-scatter of gradients into the
param sharding is inserted by GSPMD in the backward pass).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    master: Dict[str, jax.Array]   # fp32 copies
    m: Dict[str, jax.Array]
    v: Dict[str, jax.Array]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params: Dict[str, jax.Array]) -> AdamWState:
    f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), f32, zeros,
                      jax.tree.map(jnp.copy, zeros))


def _decay_mask(path: str) -> float:
    """No weight decay on norms / biases / 1-D tensors by convention."""
    skip = ("norm", "bias", "A_log", "/D", "dt_bias", "mask")
    return 0.0 if any(s in path for s in skip) else 1.0


def apply_update(cfg: AdamWConfig, params: Dict[str, jax.Array],
                 grads: Dict[str, jax.Array], state: AdamWState,
                 lr_scale: jax.Array
                 ) -> Tuple[Dict[str, jax.Array], AdamWState, Dict]:
    step = state.step + 1
    # global grad-norm clip (fp32)
    g32 = {k: g.astype(jnp.float32) for k, g in grads.items()}
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in g32.values()))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    new_p, new_master, new_m, new_v = {}, {}, {}, {}
    for k in params:
        g = g32[k] * scale
        m = cfg.b1 * state.m[k] + (1 - cfg.b1) * g
        v = cfg.b2 * state.v[k] + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = state.master[k]
        master = master - lr * (upd + cfg.weight_decay * _decay_mask(k)
                                * master)
        new_master[k], new_m[k], new_v[k] = master, m, v
        new_p[k] = master.astype(params[k].dtype)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_master, new_m, new_v), metrics


def state_shardings(param_shardings: Dict[str, jax.sharding.NamedSharding],
                    replicated) -> AdamWState:
    """Optimizer-state sharding tree mirroring the params."""
    return AdamWState(
        step=replicated,
        master=dict(param_shardings),
        m=dict(param_shardings),
        v=dict(param_shardings),
    )
