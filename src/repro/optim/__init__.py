from repro.optim.adamw import (AdamWConfig, AdamWState, apply_update,  # noqa
                               init_state, state_shardings)
from repro.optim.schedule import warmup_cosine  # noqa: F401
