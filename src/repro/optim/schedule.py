"""Warmup + cosine LR schedule (scale factor in [0, 1])."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup: int = 100, total: int = 10_000,
                  min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, warmup)
    prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
