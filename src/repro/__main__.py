"""The `repro` command: dispatch to the launchers.

  python -m repro calibrate --out profile.json
  python -m repro train --arch qwen1.5-0.5b --steps 10 --reduced
  python -m repro serve --arch qwen1.5-0.5b
  python -m repro dryrun --arch llama3-405b --shape train_4k
  python -m repro perf-probe --arch llama3-405b --shape train_4k

Each subcommand is the matching `repro.launch.<name>` module; the
module is only imported after dispatch so `python -m repro calibrate`
can still set XLA_FLAGS before jax loads.
"""
from __future__ import annotations

import importlib
import sys

COMMANDS = {
    "calibrate": "repro.launch.calibrate",
    "train": "repro.launch.train",
    "serve": "repro.launch.serve",
    "dryrun": "repro.launch.dryrun",
    "perf-probe": "repro.launch.perf_probe",
}


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        names = ", ".join(sorted(COMMANDS))
        print(f"usage: python -m repro <command> [args]\n"
              f"commands: {names}")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd not in COMMANDS:
        print(f"unknown command {cmd!r}; known: {sorted(COMMANDS)}",
              file=sys.stderr)
        return 2
    mod = importlib.import_module(COMMANDS[cmd])
    return mod.main(rest)


if __name__ == "__main__":
    sys.exit(main())
