"""Sharded checkpointing without external deps — crash-safe.

Layout: <dir>/step_<N>/
    manifest.json              — tree structure, shapes, dtypes, step,
                                 per-leaf CRC32 + byte counts
    <escaped-leaf-path>.npy    — one file per leaf (params + optimizer)

Crash safety: `save` writes the whole step into ``step_<N>.tmp`` and
atomically renames it into place only after every leaf and the
manifest are on disk — a writer killed mid-step leaves at most a
``.tmp`` directory that `latest_step` never selects, so the newest
*visible* checkpoint is always complete.  Every leaf carries a CRC32
in the manifest; `restore` rejects truncated or corrupted leaves with
`CheckpointCorruptError` instead of silently restoring garbage.

Arrays are fetched via `jax.device_get` (gathers sharded arrays to
host) and restored with `device_put` against the target shardings —
correct for CPU/dev runs; a production deployment would swap the
.npy store for a per-shard object store using the same manifest.

`crash_after_leaves` is the fault-injection hook
(`repro.resilience.faults.CheckpointCrash`): the save raises
`CheckpointCrashError` after writing that many leaf files, exactly
like a process kill mid-write — tests and benchmarks use it to prove
the atomic protocol.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointCrashError(RuntimeError):
    """Injected mid-write crash (fault injection only — a real crash
    simply kills the process at the same point)."""


class CheckpointCorruptError(RuntimeError):
    """A checkpoint leaf failed validation (missing file, truncated
    bytes, or CRC mismatch)."""


def _esc(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.@-]", "__", path)


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    else:
        out[prefix] = tree
    return out


def _storable(arr: np.ndarray) -> np.ndarray:
    """numpy can't round-trip ml_dtypes: store bf16 as raw uint16."""
    return arr.view(np.uint16) if str(arr.dtype) == "bfloat16" else arr


def save(ckpt_dir: str, step: int, tree: Any, *,
         keep_last: int = 0,
         crash_after_leaves: Optional[int] = None) -> str:
    """Atomically write one checkpoint step; returns its directory.

    `keep_last > 0` prunes older completed steps down to the newest
    `keep_last` after the rename (retention).  `crash_after_leaves`
    injects a mid-write crash for fault testing (see module docs).
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.isdir(tmp):        # stale debris from an earlier crash
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (path, leaf) in enumerate(flat.items()):
        if crash_after_leaves is not None and i >= crash_after_leaves:
            err = CheckpointCrashError(
                f"injected crash writing step {step} after {i} leaves "
                f"(tmp dir {tmp} left behind)")
            err.step = step       # lets a supervisor consume the event
            raise err
        arr = np.asarray(jax.device_get(leaf))
        fn = _esc(path) + ".npy"
        stored = _storable(arr)
        np.save(os.path.join(tmp, fn), stored)
        manifest["leaves"][path] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(stored).tobytes()),
            "nbytes": int(stored.nbytes)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    if keep_last > 0:
        prune(ckpt_dir, keep_last)
    return final


def prune(ckpt_dir: str, keep_last: int) -> List[int]:
    """Delete all but the newest `keep_last` completed steps (and any
    stale ``.tmp`` debris); returns the deleted step numbers."""
    steps = completed_steps(ckpt_dir)
    doomed = steps[:-keep_last] if keep_last > 0 else []
    for s in doomed:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
    for name in os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else []:
        if name.endswith(".tmp") and _STEP_RE.match(name[:-4]):
            shutil.rmtree(os.path.join(ckpt_dir, name))
    return doomed


def completed_steps(ckpt_dir: str) -> List[int]:
    """Sorted step numbers of *complete* checkpoints: a final-named
    directory whose manifest made it to disk (the atomic rename
    guarantees the two coincide; the manifest check additionally
    guards legacy partially-written dirs)."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.isfile(
                os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = completed_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_leaf(d: str, path: str, meta: Dict[str, Any]) -> np.ndarray:
    fp = os.path.join(d, meta["file"])
    if not os.path.isfile(fp):
        raise CheckpointCorruptError(
            f"checkpoint {d}: leaf {path!r} is missing its data file "
            f"{meta['file']}")
    try:
        arr = np.load(fp)
    except Exception as e:
        raise CheckpointCorruptError(
            f"checkpoint {d}: leaf {path!r} is unreadable "
            f"({type(e).__name__}: {e}) — the file is truncated or "
            f"corrupt") from e
    if "nbytes" in meta and int(arr.nbytes) != int(meta["nbytes"]):
        raise CheckpointCorruptError(
            f"checkpoint {d}: leaf {path!r} has {arr.nbytes} bytes, "
            f"manifest recorded {meta['nbytes']} (truncated write)")
    if "crc32" in meta:
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != int(meta["crc32"]):
            raise CheckpointCorruptError(
                f"checkpoint {d}: leaf {path!r} failed its CRC32 check "
                f"({crc:#010x} != {int(meta['crc32']):#010x}) — the "
                f"data is corrupt; refusing to restore garbage")
    if meta["dtype"] == "bfloat16":
        import ml_dtypes
        arr = arr.view(ml_dtypes.bfloat16)
    return arr


def verify(ckpt_dir: str, step: Optional[int] = None) -> int:
    """Validate every leaf of a checkpoint (CRC + sizes) without
    restoring it; returns the number of leaves checked.  Raises
    `CheckpointCorruptError` on the first bad leaf."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    for path, meta in manifest["leaves"].items():
        _load_leaf(d, path, meta)
    return len(manifest["leaves"])


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` is given (same structure), leaves
    are device_put with those shardings.  Corrupt or truncated leaves
    raise `CheckpointCorruptError` (see `verify`)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out: Dict[str, Any] = {}
    for path, leaf in flat_like.items():
        if path not in manifest["leaves"]:
            raise CheckpointCorruptError(
                f"checkpoint {d}: leaf {path!r} absent from the "
                f"manifest (tree structure changed?)")
        meta = manifest["leaves"][path]
        arr = _load_leaf(d, path, meta)
        assert list(arr.shape) == list(leaf.shape), (path, arr.shape,
                                                     leaf.shape)
        if path in flat_sh and flat_sh[path] is not None:
            out[path] = jax.device_put(arr, flat_sh[path])
        else:
            out[path] = jax.device_put(arr)
    return _unflatten(out, like), step


def _unflatten(flat: Dict[str, Any], like: Any, prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten(flat, like[k],
                              f"{prefix}/{k}" if prefix else k)
                for k in like}
    if isinstance(like, (list, tuple)):
        vals = [_unflatten(flat, v, f"{prefix}/{i}")
                for i, v in enumerate(like)]
        return type(like)(vals) if not hasattr(like, "_fields") \
            else type(like)(*vals)
    return flat[prefix]
