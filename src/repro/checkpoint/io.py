"""Sharded checkpointing without external deps.

Layout: <dir>/step_<N>/
    manifest.json              — tree structure, shapes, dtypes, step
    <escaped-leaf-path>.npy    — one file per leaf (params + optimizer)

Arrays are fetched via `jax.device_get` (gathers sharded arrays to
host) and restored with `device_put` against the target shardings —
correct for CPU/dev runs; a production deployment would swap the
.npy store for a per-shard object store using the same manifest.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _esc(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.@-]", "__", path)


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    else:
        out[prefix] = tree
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = _esc(path) + ".npy"
        dtype = str(arr.dtype)
        if dtype == "bfloat16":       # numpy can't round-trip ml_dtypes
            np.save(os.path.join(d, fn), arr.view(np.uint16))
        else:
            np.save(os.path.join(d, fn), arr)
        manifest["leaves"][path] = {
            "file": fn, "shape": list(arr.shape), "dtype": dtype}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
             if n.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` is given (same structure), leaves
    are device_put with those shardings."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out: Dict[str, Any] = {}
    for path, leaf in flat_like.items():
        meta = manifest["leaves"][path]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert list(arr.shape) == list(leaf.shape), (path, arr.shape,
                                                     leaf.shape)
        if path in flat_sh and flat_sh[path] is not None:
            out[path] = jax.device_put(arr, flat_sh[path])
        else:
            out[path] = jax.device_put(arr)
    return _unflatten(out, like), step


def _unflatten(flat: Dict[str, Any], like: Any, prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten(flat, like[k],
                              f"{prefix}/{k}" if prefix else k)
                for k in like}
    if isinstance(like, (list, tuple)):
        vals = [_unflatten(flat, v, f"{prefix}/{i}")
                for i, v in enumerate(like)]
        return type(like)(vals) if not hasattr(like, "_fields") \
            else type(like)(*vals)
    return flat[prefix]
