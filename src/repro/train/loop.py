"""Training loop: jit-compiled step with OSDP shardings + microbatching.

`make_train_step(built, ...)` returns (step_fn, init_fn) where step_fn
is `jit(step, in_shardings=..., out_shardings=..., donate...)` — the
same callable the dry-run lowers for the production meshes and the
smoke tests execute on CPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import io as ckpt_io
from repro.configs.base import RunConfig
from repro.data.synthetic import Dataset
from repro.models.registry import Built, input_shardings
from repro.optim import (AdamWConfig, AdamWState, apply_update, init_state,
                         state_shardings, warmup_cosine)


def loss_and_grads(model, params, batch, microbatch: int = 0):
    """Optionally microbatched (gradient-accumulated) value+grad."""
    if microbatch <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    n = microbatch
    split = lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:])
    mb = jax.tree.map(split, batch)

    def body(carry, b):
        acc_loss, acc_grads = carry
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, b)
        acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
        return (acc_loss + loss, acc_grads), metrics

    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
    (loss, grads), metrics = jax.lax.scan(body, (jnp.zeros(()), zero_grads),
                                          mb)
    grads = jax.tree.map(lambda g: g / n, grads)
    last = jax.tree.map(lambda m: m[-1], metrics)
    return loss / n, last, grads


def _bucket_grads(grads, bucket_bytes: int):
    """Greedily pack gradient leaves (tree order) into ~`bucket_bytes`
    buckets and pass each bucket through one `optimization_barrier`.

    Identity on values; the barrier makes each bucket an independently
    schedulable unit, so XLA can launch a bucket's gradient all-reduce
    as soon as the backward walk has produced its leaves instead of
    batching every reduction behind the full backward pass — the async
    all-reduce half of the overlap the timeline cost model prices.
    """
    leaves, treedef = jax.tree.flatten(grads)
    out, bucket, size = [], [], 0
    for g in leaves:
        bucket.append(g)
        size += g.size * jnp.dtype(g.dtype).itemsize
        if size >= bucket_bytes:
            out.extend(jax.lax.optimization_barrier(tuple(bucket)))
            bucket, size = [], 0
    if bucket:
        out.extend(jax.lax.optimization_barrier(tuple(bucket)))
    return jax.tree.unflatten(treedef, out)


def make_train_step(built: Built, opt_cfg: Optional[AdamWConfig] = None,
                    total_steps: int = 10_000, warmup: int = 100,
                    donate: bool = True) -> Tuple[Callable, Callable]:
    opt_cfg = opt_cfg or AdamWConfig()
    model = built.model
    run = built.run
    micro = run.microbatch
    overlap = built.pset_abstract.overlap

    def step(params, opt_state: AdamWState, batch):
        loss, metrics, grads = loss_and_grads(model, params, batch, micro)
        if overlap is not None and overlap.bucket_bytes > 0:
            grads = _bucket_grads(grads, overlap.bucket_bytes)
        lr_scale = warmup_cosine(opt_state.step + 1, warmup, total_steps)
        params, opt_state, opt_metrics = apply_update(
            opt_cfg, params, grads, opt_state, lr_scale)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    if built.mesh is None:
        def init(key):
            params = built.init(key)
            return params, init_state(params)
        return jax.jit(step, donate_argnums=(0, 1) if donate else ()), init

    mesh = built.mesh
    psh = built.shardings
    repl = NamedSharding(mesh, P())
    osh = state_shardings(psh, repl)

    def init(key):
        params = built.init(key)
        params = {k: jax.device_put(v, psh[k]) for k, v in params.items()}
        opt = init_state(params)
        opt = jax.tree.map(jax.device_put, opt, osh)
        return params, opt
    # batch shardings ride on the input ShapeDtypeStructs / arrays
    step_jit = jax.jit(
        step,
        in_shardings=(psh, osh, None),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return step_jit, init


@dataclass
class TrainResult:
    steps: int
    losses: list
    tokens_per_s: float
    final_metrics: Dict[str, float] = field(default_factory=dict)
    start_step: int = 0


def restore_or_init(built: Built, ckpt_dir: Optional[str], *,
                    seed: int = 0,
                    opt_cfg: Optional[AdamWConfig] = None,
                    warmup: int = 100, total_steps: int = 10_000,
                    print_fn=print):
    """(step_fn, params, opt_state, start_step): resume from the
    latest *valid* checkpoint under `ckpt_dir` when one exists, else
    a fresh init — what `launch/train.py --resume` and the resilience
    supervisor call after a crash or a replan.  Checkpoint validation
    (CRC + sizes) happens inside `checkpoint.io.restore`; a corrupt
    latest step raises `CheckpointCorruptError` rather than silently
    restoring garbage."""
    step_fn, init_fn = make_train_step(built, opt_cfg, warmup=warmup,
                                       total_steps=total_steps)
    params, opt_state = init_fn(jax.random.PRNGKey(seed))
    start_step = 0
    if ckpt_dir and ckpt_io.latest_step(ckpt_dir) is not None:
        (params, opt_state), start_step = ckpt_io.restore(
            ckpt_dir, (params, opt_state))
        print_fn(f"restored checkpoint at step {start_step}")
    return step_fn, params, opt_state, start_step


def train(built: Built, n_steps: int, *, seed: int = 0,
          opt_cfg: Optional[AdamWConfig] = None,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
          keep_last: int = 0, resume: bool = False,
          log_every: int = 10, batch_override: Optional[int] = None,
          seq_override: Optional[int] = None, warmup: int = 100,
          total_steps: int = 10_000, faults=None,
          print_fn=print) -> TrainResult:
    """Single-host training driver (CPU smoke / example scale).

    `resume=True` makes `n_steps` the TOTAL step target: a restored
    run skips its already-completed steps (restoring at step >=
    `n_steps` trains nothing).  The default (False) keeps the legacy
    semantics — train `n_steps` more from wherever the restore landed.

    `keep_last > 0` prunes checkpoint retention to the newest N
    completed steps.  `faults` (a `resilience.faults.FaultSchedule`)
    injects device losses (raising `DeviceLost` at the scheduled
    step — progress since the last checkpoint is lost, exactly like
    the real failure) and checkpoint-write crashes
    (`CheckpointCrashError` mid-save).
    """
    step_fn, params, opt_state, start_step = restore_or_init(
        built, ckpt_dir, seed=seed, opt_cfg=opt_cfg, warmup=warmup,
        total_steps=total_steps, print_fn=print_fn)
    ds = Dataset(built.run.model, built.run.shape, seed=seed)
    target = n_steps if resume else start_step + n_steps
    if resume and start_step >= target:
        print_fn(f"nothing to do: restored step {start_step} >= "
                 f"target {target}")
        return TrainResult(0, [], 0.0, {}, start_step)

    def save(step: int) -> None:
        crash = (faults.checkpoint_crash_at(step)
                 if faults is not None else None)
        ckpt_io.save(ckpt_dir, step, (params, opt_state),
                     keep_last=keep_last,
                     crash_after_leaves=(crash.after_leaves
                                         if crash else None))

    losses = []
    t0 = time.perf_counter()
    tokens = 0
    metrics = {}
    for s in range(start_step, target):
        if faults is not None:
            ev = faults.device_loss_at(s)
            if ev is not None:
                from repro.resilience.faults import DeviceLost
                raise DeviceLost(ev, s)
        batch = {k: jnp.asarray(v) for k, v in ds.global_batch(
            s, batch=batch_override, seq=seq_override).items()}
        tokens += int(np.prod(batch["labels"].shape))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and (s % log_every == 0 or s == target - 1):
            print_fn(f"step {s:5d} loss {loss:.4f} "
                     f"gnorm {float(metrics['grad_norm']):.3f}")
        if ckpt_dir and ckpt_every and (s + 1) % ckpt_every == 0:
            save(s + 1)
    dt = time.perf_counter() - t0
    if ckpt_dir:
        save(target)
    return TrainResult(target - start_step, losses, tokens / dt,
                       {k: float(v) for k, v in metrics.items()},
                       start_step)
