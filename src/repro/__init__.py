"""OSDP: Optimal Sharded Data Parallel — JAX/TPU reproduction.

Paper: Jiang, Fu, Miao, Nie, Cui — IJCAI 2023 (10.24963/IJCAI.2023/238).
See README.md / DESIGN.md / EXPERIMENTS.md.
"""
__version__ = "1.0.0"
