"""Deterministic fleet traffic simulator.

Drives real `ContinuousEngine` replicas through a class-aware router
on a discrete tick clock — one tick = one engine iteration per busy
replica (the engines' own deterministic engine-step clock; zero
wall-clock dependence).  Arrivals are a seeded Poisson process (or an
explicit trace) injected between engine steps via the incremental
`submit`/`step` session API, so every serving claim the planner makes
analytically (per-class ttft/tpot tails, goodput, SLO attainment,
admission limits) is measured under load and replayable byte-for-byte
from (fleet, arrivals, seed).

Determinism mechanics:

  * **Poisson thinning** — `poisson_arrivals` draws each class's
    candidate arrivals at a fixed cap rate and keeps candidate `i` iff
    a pure hash of (seed, class, i) falls below `rate_scale /
    cap_scale`.  The kept process is Poisson at the target rate, and a
    lower-rate arrival set is a *subset* of a higher-rate one (same
    seed) — which is what makes "more load never improves latency" a
    per-request testable property rather than a statistical claim.
  * **tick clock** — requests are timestamped by the global tick at
    submission, first token, and completion; ttft/tpot are measured in
    ticks.  The host clock is never read.
  * **deterministic routing** — join-shortest-queue over the replicas
    the routing table allows for the class, load weighted by the
    routing fraction, ties broken by replica order.
  * **SLO-aware admission** — per-class outstanding caps (from
    `FleetPlan.admission`) reject excess arrivals at the router before
    they ever occupy a queue slot.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import RequestClassMix
from repro.serving.engine import (FAILED, INVALID, OK, REJECTED,
                                  TIMED_OUT, ContinuousEngine, Request,
                                  RequestResult, ServeStats)


def _unit_hash(*parts) -> float:
    """Deterministic uniform [0, 1) from arbitrary identifiers (the
    same idiom as `resilience.faults`)."""
    key = ":".join(str(p) for p in parts).encode()
    h = hashlib.blake2b(key, digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


def _class_seed(seed: int, name: str) -> List[int]:
    h = hashlib.blake2b(f"{seed}:{name}".encode(),
                        digest_size=8).digest()
    return [seed, int.from_bytes(h, "big") % 2 ** 32]


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Arrival:
    """One request arrival: the tick it enters the fleet, its class,
    and a stable identity (`uid`) that survives rate re-scaling — the
    monotonicity tests compare the same uid across load levels."""

    step: int
    cls: str
    uid: str


def poisson_arrivals(mix: RequestClassMix, horizon: int, seed: int = 0,
                     rate_scale: float = 1.0,
                     cap_scale: float = 16.0) -> List[Arrival]:
    """Seeded per-class Poisson arrivals over [0, horizon) ticks.

    Class `c` arrives at `c.arrival_rate * rate_scale` requests/tick
    (the mix's `arrival_rate` is interpreted per tick here; callers
    map real seconds to ticks via the plan's analytic step time).
    Thinning construction: candidates at `c.arrival_rate * cap_scale`,
    kept iff hash(seed, class, i) < rate_scale / cap_scale — so for a
    fixed seed the arrival set at a lower `rate_scale` is a subset of
    the set at any higher one."""
    if horizon < 1:
        raise ValueError("horizon must be >= 1 tick")
    if not 0.0 < rate_scale <= cap_scale:
        raise ValueError(f"need 0 < rate_scale <= cap_scale "
                         f"({rate_scale} vs {cap_scale})")
    accept = rate_scale / cap_scale
    out: List[Arrival] = []
    for c in mix.classes:
        rng = np.random.default_rng(_class_seed(seed, c.name))
        base = c.arrival_rate * cap_scale
        t, i = 0.0, 0
        while True:
            t += rng.exponential(1.0 / base)
            if t >= horizon:
                break
            if _unit_hash(seed, c.name, i) < accept:
                out.append(Arrival(int(t), c.name, f"{c.name}#{i}"))
            i += 1
    out.sort(key=lambda a: (a.step, a.cls, a.uid))
    return out


def trace_arrivals(trace: Sequence[Tuple[int, str]]) -> List[Arrival]:
    """Explicit (tick, class) pairs — replayed traces."""
    out = [Arrival(int(t), cls, f"{cls}#t{i}")
           for i, (t, cls) in enumerate(trace)]
    out.sort(key=lambda a: (a.step, a.cls, a.uid))
    return out


# ---------------------------------------------------------------------------
# fleet + per-request bookkeeping
# ---------------------------------------------------------------------------

@dataclass
class SimReplica:
    """One serving replica: a live engine plus routing metadata.
    `classes` empty = serves every class (the uniform baseline)."""

    name: str
    group: str
    engine: ContinuousEngine
    classes: Tuple[str, ...] = ()

    def serves(self, cls: str) -> bool:
        return not self.classes or cls in self.classes


@dataclass
class RequestTrace:
    """Tick-clock record of one simulated request."""

    rid: int
    uid: str
    cls: str
    replica: str              # "" when rejected at the router
    submit_tick: int
    first_token_tick: int = -1
    finish_tick: int = -1
    status: str = ""
    n_generated: int = 0
    tokens: Optional[np.ndarray] = None
    engine_result: Optional[RequestResult] = None

    @property
    def ok(self) -> bool:
        return self.status == OK

    @property
    def ttft_ticks(self) -> float:
        if self.first_token_tick < 0:
            return math.inf
        return float(self.first_token_tick - self.submit_tick)

    @property
    def tpot_ticks(self) -> float:
        """Mean ticks per token after the first (0 for one-token
        requests; inf when no token was ever produced)."""
        if self.first_token_tick < 0 or self.finish_tick < 0:
            return math.inf
        if self.n_generated <= 1:
            return 0.0
        return ((self.finish_tick - self.first_token_tick)
                / (self.n_generated - 1))


def _pct(xs: List[float], q: float) -> float:
    if not xs:
        return math.inf
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclass
class ClassReport:
    """Measured per-class tails and terminal-state counts."""

    name: str
    arrived: int
    completed: int
    rejected: int
    timed_out: int
    failed: int
    invalid: int
    ok_tokens: int
    slo_good_tokens: int      # tokens of OK requests that met the SLO
    ttft_p50: float
    ttft_p99: float
    tpot_p50: float
    tpot_p99: float
    slo_attainment: float

    def row(self) -> Dict[str, object]:
        return {
            "class": self.name, "arrived": self.arrived,
            "completed": self.completed, "rejected": self.rejected,
            "timed_out": self.timed_out, "failed": self.failed,
            "ok_tokens": self.ok_tokens,
            "slo_good_tokens": self.slo_good_tokens,
            "ttft_p50_ticks": round(self.ttft_p50, 3),
            "ttft_p99_ticks": round(self.ttft_p99, 3),
            "tpot_p50_ticks": round(self.tpot_p50, 4),
            "tpot_p99_ticks": round(self.tpot_p99, 4),
            "slo_attainment": round(self.slo_attainment, 4),
        }


@dataclass
class FleetReport:
    """One simulation's outcome: per-request traces, per-class tails,
    per-replica engine stats, and aggregate goodput."""

    ticks: int
    requests: List[RequestTrace]
    per_class: Dict[str, ClassReport]
    replica_stats: Dict[str, ServeStats]

    @property
    def ok_tokens(self) -> int:
        return sum(t.n_generated for t in self.requests if t.ok)

    @property
    def completed(self) -> int:
        return sum(1 for t in self.requests if t.ok)

    @property
    def goodput_tokens_per_tick(self) -> float:
        return self.ok_tokens / max(self.ticks, 1)

    @property
    def slo_goodput_tokens_per_tick(self) -> float:
        """Tokens from requests that completed *within their SLO*, per
        tick — the serving-literature goodput that an SLO-aware plan
        optimizes (raw token throughput can reward starving the
        latency-sensitive class)."""
        return sum(r.slo_good_tokens
                   for r in self.per_class.values()) / max(self.ticks, 1)

    @property
    def slo_attainment(self) -> float:
        """Arrived-weighted mean attainment across classes."""
        arrived = sum(r.arrived for r in self.per_class.values())
        if arrived == 0:
            return 0.0
        return sum(r.slo_attainment * r.arrived
                   for r in self.per_class.values()) / arrived

    def fingerprint(self) -> str:
        """Digest of every deterministic per-request field — two runs
        are byte-identical iff their fingerprints match."""
        h = hashlib.blake2b(digest_size=16)
        for t in sorted(self.requests, key=lambda t: t.rid):
            h.update(f"{t.rid}|{t.uid}|{t.cls}|{t.replica}|{t.status}|"
                     f"{t.submit_tick}|{t.first_token_tick}|"
                     f"{t.finish_tick}|".encode())
            if t.tokens is not None:
                h.update(np.asarray(t.tokens, np.int32).tobytes())
        return h.hexdigest()


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

class TrafficSimulator:
    """Discrete-event fleet simulation over live engine replicas.

    Each tick: (1) arrivals due at the tick are routed — the per-class
    admission cap may REJECT at the router, otherwise
    join-shortest-queue picks a replica and the request is submitted
    into its open engine session; (2) every replica with pending work
    runs one engine iteration.  The loop is pure data + seeded RNG:
    same (replicas, mix, routing, admission, arrivals, seed) -> the
    same `FleetReport.fingerprint()`.

    `routing` maps class -> {replica group: weight} (defaults to every
    replica whose `classes` allow the class, weight 1).  `admission`
    caps a class's outstanding (queued + in-flight) requests fleet-
    wide, `deadline_ticks` bounds a request's lifetime on its
    replica's engine-step clock, and `slo_ticks` maps class ->
    (ttft, tpot) tick budgets scored in each `ClassReport`."""

    def __init__(self, replicas: Sequence[SimReplica],
                 mix: RequestClassMix, *,
                 routing: Optional[Dict[str, Dict[str, float]]] = None,
                 admission: Optional[Dict[str, int]] = None,
                 deadline_ticks: Optional[Dict[str, int]] = None,
                 slo_ticks: Optional[Dict[str, Tuple[float, float]]]
                 = None,
                 seed: int = 0):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        self.mix = mix
        self.routing = routing
        self.admission = admission or {}
        self.deadline_ticks = deadline_ticks or {}
        self.slo_ticks = slo_ticks or {}
        self.seed = int(seed)
        for c in mix.classes:
            if not any(r.serves(c.name) for r in self.replicas):
                raise ValueError(f"no replica serves class {c.name!r}")

    # -- routing --------------------------------------------------------------

    def _targets(self, cls: str) -> List[Tuple[SimReplica, float]]:
        if self.routing is not None and cls in self.routing:
            weights = self.routing[cls]
            out = [(r, weights[r.group]) for r in self.replicas
                   if weights.get(r.group, 0.0) > 0.0 and r.serves(cls)]
            if out:
                return out
        return [(r, 1.0) for r in self.replicas if r.serves(cls)]

    def _pick(self, cls: str) -> SimReplica:
        """Join-shortest-queue, weighted by the routing fraction."""
        best, best_key = None, None
        for i, (r, w) in enumerate(self._targets(cls)):
            key = (r.engine.load / w, i)
            if best_key is None or key < best_key:
                best, best_key = r, key
        return best

    def _prompt(self, cls: str, uid: str) -> np.ndarray:
        c = self.mix[cls]
        vocab = self.replicas[0].engine.built.model.cfg.vocab_size
        rng = np.random.default_rng(_class_seed(self.seed, uid))
        return rng.integers(0, vocab, c.prompt_len).astype(np.int32)

    # -- the loop -------------------------------------------------------------

    def run(self, arrivals: Sequence[Arrival],
            max_ticks: int = 200_000) -> FleetReport:
        arrivals = sorted(arrivals,
                          key=lambda a: (a.step, a.cls, a.uid))
        for a in arrivals:
            _ = self.mix[a.cls]     # unknown classes fail fast
        for i, rep in enumerate(self.replicas):
            rep.engine.start(seed=self.seed + i)
        traces: List[RequestTrace] = []
        by_rid: Dict[Tuple[str, int], RequestTrace] = {}
        outstanding: Dict[str, int] = {c.name: 0
                                       for c in self.mix.classes}
        tick = 0
        idx = 0
        try:
            while idx < len(arrivals) or any(r.engine.pending
                                             for r in self.replicas):
                if tick >= max_ticks:
                    break
                while idx < len(arrivals) \
                        and arrivals[idx].step <= tick:
                    a = arrivals[idx]
                    idx += 1
                    rid = len(traces)
                    tr = RequestTrace(rid=rid, uid=a.uid, cls=a.cls,
                                      replica="", submit_tick=tick)
                    traces.append(tr)
                    cap = self.admission.get(a.cls)
                    if cap is not None and outstanding[a.cls] >= cap:
                        tr.status = REJECTED
                        tr.finish_tick = tick
                        continue
                    rep = self._pick(a.cls)
                    tr.replica = rep.name
                    dl = self.deadline_ticks.get(a.cls)
                    req = Request(
                        rid, self._prompt(a.cls, a.uid),
                        self.mix[a.cls].decode_len,
                        deadline_steps=(None if dl is None else
                                        rep.engine.engine_step + dl))
                    res = rep.engine.submit(req)
                    if res is not None:     # INVALID / backpressure
                        self._record(tr, res, tick)
                    else:
                        outstanding[a.cls] += 1
                        by_rid[(rep.name, rid)] = tr
                for rep in self.replicas:
                    if not rep.engine.pending:
                        continue
                    admitted, finished = rep.engine.step()
                    for rid in admitted:
                        tr = by_rid.get((rep.name, rid))
                        if tr is not None and tr.first_token_tick < 0:
                            tr.first_token_tick = tick
                    for res in finished:
                        tr = by_rid.pop((rep.name, res.rid), None)
                        if tr is not None:
                            outstanding[tr.cls] -= 1
                            self._record(tr, res, tick)
                tick += 1
        finally:
            stats = {}
            for rep in self.replicas:
                if rep.engine.active:
                    _, st = rep.engine.finish()
                    stats[rep.name] = st
        per_class = {c.name: self._class_report(c.name, traces)
                     for c in self.mix.classes}
        return FleetReport(ticks=tick, requests=traces,
                           per_class=per_class, replica_stats=stats)

    @staticmethod
    def _record(tr: RequestTrace, res: RequestResult,
                tick: int) -> None:
        tr.status = res.status
        tr.finish_tick = tick
        tr.n_generated = res.n_generated
        tr.tokens = np.asarray(res.tokens, np.int32)
        tr.engine_result = res

    def _class_report(self, name: str,
                      traces: List[RequestTrace]) -> ClassReport:
        mine = [t for t in traces if t.cls == name]
        ok = [t for t in mine if t.ok]
        ttfts = [t.ttft_ticks for t in ok]
        tpots = [t.tpot_ticks for t in ok]
        slo = self.slo_ticks.get(name)
        attained = good_tokens = 0
        for t in ok:
            if slo is None or (t.ttft_ticks <= slo[0]
                               and t.tpot_ticks <= slo[1]):
                attained += 1
                good_tokens += t.n_generated
        count = lambda s: sum(1 for t in mine if t.status == s)
        return ClassReport(
            name=name, arrived=len(mine), completed=len(ok),
            rejected=count(REJECTED), timed_out=count(TIMED_OUT),
            failed=count(FAILED), invalid=count(INVALID),
            ok_tokens=sum(t.n_generated for t in ok),
            slo_good_tokens=good_tokens,
            ttft_p50=_pct(ttfts, 50), ttft_p99=_pct(ttfts, 99),
            tpot_p50=_pct(tpots, 50), tpot_p99=_pct(tpots, 99),
            slo_attainment=(attained / len(mine) if mine else 0.0))


def fleet_replicas(plan, make_engine, *,
                   max_replicas_per_group: int = 0
                   ) -> List[SimReplica]:
    """Instantiate `SimReplica`s for a `FleetPlan`: one engine per
    planned replica (capped per group when simulating a scale model of
    a large fleet), tagged with the group's routed classes so the
    router honors the plan."""
    out: List[SimReplica] = []
    for g in plan.groups:
        n = g.n_replicas
        if max_replicas_per_group:
            n = min(n, max_replicas_per_group)
        for j in range(n):
            out.append(SimReplica(
                name=f"{g.name}/{j}", group=g.name,
                engine=make_engine(g), classes=tuple(g.classes)))
    return out
