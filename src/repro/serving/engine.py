"""Batched serving engine: prefill + decode over the OSDP-sharded model.

`make_serve_step(built, cache_len)` returns the jit'd one-token decode
used by the decode dry-run shapes; `Engine` is the host-side loop that
serves batched requests (prefill once, decode N tokens, greedy or
temperature sampling) for the examples and tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.registry import Built


def make_serve_step(built: Built) -> Callable:
    """jit'd (params, caches, tokens, t[, positions3]) -> (logits, caches)."""
    model = built.model

    def serve_step(params, caches, tokens, t, positions3=None):
        return model.decode_step(params, caches, tokens, t,
                                 positions3=positions3)

    return jax.jit(serve_step, donate_argnums=(1,))


def make_prefill_step(built: Built) -> Callable:
    model = built.model

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return jax.jit(prefill_step)


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


@dataclass
class Engine:
    built: Built
    params: Dict[str, jax.Array]
    temperature: float = 0.0
    _prefill: Callable = field(init=False)
    _decode: Callable = field(init=False)

    def __post_init__(self):
        self._prefill = make_prefill_step(self.built)
        self._decode = make_serve_step(self.built)

    def generate(self, prompts: np.ndarray, n_new: int,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, S) int32 token ids."""
        cfg = self.built.model.cfg
        assert cfg.is_decoder, "encoder-only models cannot decode"
        B, S = prompts.shape
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits[:, -1], key)
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, caches, tok,
                                          jnp.int32(S + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, 0], sub)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        toks = np.concatenate(out, axis=1) if out else np.zeros((B, 0), int)
        return GenerationResult(
            toks, t1 - t0, t2 - t1,
            B * n_new / max(t2 - t1, 1e-9))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        cfg = self.built.model.cfg
        logits = logits[..., :cfg.vocab_size].astype(jnp.float32)
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            key, logits / self.temperature, -1).astype(jnp.int32)[:, None]
