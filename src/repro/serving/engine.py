"""Serving engines: static batching and continuous batching.

`make_serve_step(built)` returns the jit'd one-token decode used by the
decode dry-run shapes; `Engine` is the legacy static-batch loop
(prefill once, decode N tokens for everyone, no admission).

`ContinuousEngine` is the production loop the OSDP serving search
plans for (`repro.core.api.search_serve`):

  * a FIFO **request queue** feeds a fixed set of **slots** — the
    KV/SSM cache is allocated once at ``(max_slots, cache_len)`` and
    never reshaped;
  * **admission** is bounded by the searched KV budget: a request is
    admitted only when a slot is free (``max_slots`` comes from
    ``ServePlan.max_slots_per_device``);
  * **prefill/decode interleaving**: each engine iteration first
    prefills one queued request per free slot (batch 1, written into
    the slot with a donated ``dynamic_update_slice``), then decodes
    every live slot one token with a per-slot position vector —
    sequences at different depths share one batched decode step;
  * per-request **latency stats** (queue wait, TTFT, per-token rate,
    completion) are recorded on the host clock, plus a deterministic
    engine-step clock for benchmarks.

Slots whose request finished keep decoding garbage until re-admission
overwrites their cache — their outputs are ignored, and the admission
prefill rewrites every cache leaf of the slot, so no masking state is
needed on the device.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Built


def make_serve_step(built: Built) -> Callable:
    """jit'd (params, caches, tokens, t[, positions3]) -> (logits, caches).

    `t` may be a scalar (lockstep batch) or a (B,) vector (continuous
    batching: every slot decodes at its own position)."""
    model = built.model

    def serve_step(params, caches, tokens, t, positions3=None):
        return model.decode_step(params, caches, tokens, t,
                                 positions3=positions3)

    return jax.jit(serve_step, donate_argnums=(1,))


def make_prefill_step(built: Built,
                      cache_len: Optional[int] = None) -> Callable:
    """jit'd prefill; `cache_len` sizes the emitted KV cache (free
    slots after the prompt let decode append instead of rolling)."""
    model = built.model

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)

    return jax.jit(prefill_step)


def _sample(cfg, logits: jax.Array, key, temperature: float) -> jax.Array:
    logits = logits[..., :cfg.vocab_size].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return jax.random.categorical(
        key, logits / temperature, -1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# static batching (legacy engine)
# ---------------------------------------------------------------------------

@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


@dataclass
class Engine:
    """Static batching: one prefill, then every sequence decodes the
    same number of tokens in lockstep.  `cache_len` (>= prompt length)
    sizes the KV cache; default keeps the legacy prompt-length rolling
    cache."""

    built: Built
    params: Dict[str, jax.Array]
    temperature: float = 0.0
    cache_len: Optional[int] = None
    _prefill: Callable = field(init=False)
    _decode: Callable = field(init=False)

    def __post_init__(self):
        self._prefill = make_prefill_step(self.built, self.cache_len)
        self._decode = make_serve_step(self.built)

    def generate(self, prompts: np.ndarray, n_new: int,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, S) int32 token ids."""
        cfg = self.built.model.cfg
        assert cfg.is_decoder, "encoder-only models cannot decode"
        B, S = prompts.shape
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits[:, -1], key)
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, caches, tok,
                                          jnp.int32(S + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, 0], sub)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        toks = np.concatenate(out, axis=1) if out else np.zeros((B, 0), int)
        return GenerationResult(
            toks, t1 - t0, t2 - t1,
            B * n_new / max(t2 - t1, 1e-9))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        return _sample(self.built.model.cfg, logits, key, self.temperature)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Request:
    """One serving request: a prompt and a decode budget."""

    rid: int
    prompt: np.ndarray            # (S,) int32 token ids
    max_new_tokens: int

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        p = np.asarray(self.prompt)
        if p.ndim != 1 or p.size < 1:
            raise ValueError("prompt must be a non-empty 1-D token array")


@dataclass
class RequestResult:
    """Per-request output + latency accounting (host-clock seconds
    relative to `ContinuousEngine.run`'s start, plus the deterministic
    engine-step clock)."""

    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n_generated,) int32
    t_enqueued: float
    t_admitted: float
    t_first_token: float
    t_finished: float
    admitted_at_step: int
    finished_at_step: int

    @property
    def n_generated(self) -> int:
        return int(len(self.tokens))

    @property
    def queue_wait_s(self) -> float:
        return self.t_admitted - self.t_enqueued

    @property
    def ttft_s(self) -> float:
        """Time to first token, queue wait included."""
        return self.t_first_token - self.t_enqueued

    @property
    def latency_s(self) -> float:
        return self.t_finished - self.t_enqueued


@dataclass
class ServeStats:
    """Aggregate engine counters for one `run`."""

    wall_s: float
    prefill_steps: int
    decode_steps: int
    slots: int
    useful_tokens: int
    completed: int

    @property
    def tokens_per_s(self) -> float:
        return self.useful_tokens / max(self.wall_s, 1e-9)

    @property
    def slot_utilization(self) -> float:
        """Useful decoded tokens / decoded slot-steps: 1.0 means no
        slot ever decoded a finished or empty sequence."""
        produced = self.decode_steps * self.slots
        # the admission prefill also produces one token per request
        return ((self.useful_tokens - self.prefill_steps)
                / max(produced, 1))


class ContinuousEngine:
    """Continuous batching over a fixed slot pool (see module docs)."""

    def __init__(self, built: Built, params: Dict[str, jax.Array],
                 max_slots: int, cache_len: int,
                 temperature: float = 0.0):
        cfg = built.model.cfg
        assert cfg.is_decoder, "encoder-only models cannot decode"
        if max_slots < 1 or cache_len < 1:
            raise ValueError("need max_slots >= 1 and cache_len >= 1")
        self.built = built
        self.params = params
        self.max_slots = int(max_slots)
        self.cache_len = int(cache_len)
        self.temperature = float(temperature)
        self._prefill = make_prefill_step(built, self.cache_len)
        self._decode = make_serve_step(built)

        def insert(caches, one, slot):
            return jax.tree_util.tree_map(
                lambda big, new: jax.lax.dynamic_update_slice_in_dim(
                    big, new.astype(big.dtype), slot, axis=1),
                caches, one)

        self._insert = jax.jit(insert, donate_argnums=(0,))

    def _mrope_positions(self, t_vec: np.ndarray) -> Optional[jax.Array]:
        if self.built.model.cfg.rope != "mrope":
            return None
        return jnp.broadcast_to(
            jnp.asarray(t_vec, jnp.int32)[:, None, None],
            (len(t_vec), 1, 3))

    def run(self, requests: Sequence[Request], seed: int = 0
            ) -> Tuple[List[RequestResult], ServeStats]:
        """Serve `requests` (FIFO) to completion; returns per-request
        results in completion order plus aggregate stats."""
        cfg = self.built.model.cfg
        B = self.max_slots
        for r in requests:
            if len(r.prompt) > self.cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} exceeds "
                    f"cache_len {self.cache_len}")
        caches = self.built.model.init_caches(B, self.cache_len)
        queue = deque(requests)
        key = jax.random.PRNGKey(seed)

        slot_req: List[Optional[Request]] = [None] * B
        slot_t = np.zeros(B, np.int32)         # next decode position
        slot_left = np.zeros(B, np.int64)      # tokens still to decode
        slot_toks: List[List[int]] = [[] for _ in range(B)]
        slot_admit: List[Tuple[float, float, int]] = [(0.0, 0.0, 0)] * B
        last_tok = np.zeros((B, 1), np.int32)
        results: List[RequestResult] = []
        prefill_steps = decode_steps = engine_step = useful = 0
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0

        def finish(slot: int) -> None:
            req = slot_req[slot]
            t_adm, t_first, step_adm = slot_admit[slot]
            results.append(RequestResult(
                rid=req.rid, prompt_len=len(req.prompt),
                tokens=np.asarray(slot_toks[slot], np.int32),
                t_enqueued=0.0, t_admitted=t_adm, t_first_token=t_first,
                t_finished=now(), admitted_at_step=step_adm,
                finished_at_step=engine_step))
            slot_req[slot] = None
            slot_toks[slot] = []

        while queue or any(r is not None for r in slot_req):
            # --- admission: one prefill per free slot ------------------------
            for slot in range(B):
                if not queue:
                    break
                if slot_req[slot] is not None:
                    continue
                req = queue.popleft()
                t_adm = now()
                S = len(req.prompt)
                logits, one = self._prefill(
                    self.params,
                    {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]})
                caches = self._insert(caches, one, slot)
                key, sub = jax.random.split(key)
                tok = np.asarray(_sample(cfg, logits[:, -1], sub,
                                         self.temperature))
                prefill_steps += 1
                engine_step += 1
                useful += 1
                slot_req[slot] = req
                slot_t[slot] = S
                slot_left[slot] = req.max_new_tokens - 1
                slot_toks[slot] = [int(tok[0, 0])]
                slot_admit[slot] = (t_adm, now(), engine_step)
                last_tok[slot] = tok[0]
                if slot_left[slot] == 0:
                    finish(slot)

            active = [i for i in range(B) if slot_req[i] is not None]
            if not active:
                continue
            # --- one batched decode step at per-slot positions ---------------
            pos3 = self._mrope_positions(slot_t)
            kw = {} if pos3 is None else {"positions3": pos3}
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(last_tok),
                jnp.asarray(slot_t), **kw)
            key, sub = jax.random.split(key)
            toks = np.asarray(_sample(cfg, logits[:, 0], sub,
                                      self.temperature))
            decode_steps += 1
            engine_step += 1
            for i in active:
                slot_toks[i].append(int(toks[i, 0]))
                slot_t[i] += 1
                slot_left[i] -= 1
                last_tok[i] = toks[i]
                useful += 1
                if slot_left[i] == 0:
                    finish(i)

        jax.block_until_ready(caches)
        stats = ServeStats(
            wall_s=now(), prefill_steps=prefill_steps,
            decode_steps=decode_steps, slots=B, useful_tokens=useful,
            completed=len(results))
        return results, stats
