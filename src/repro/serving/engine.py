"""Serving engines: static batching and continuous batching.

`make_serve_step(built)` returns the jit'd one-token decode used by the
decode dry-run shapes; `Engine` is the legacy static-batch loop
(prefill once, decode N tokens for everyone, no admission).

`ContinuousEngine` is the production loop the OSDP serving search
plans for (`repro.core.api.search_serve`):

  * a FIFO **request queue** feeds a fixed set of **slots** — the
    KV/SSM cache is allocated once at ``(max_slots, cache_len)`` and
    never reshaped;
  * **admission** is bounded by the searched KV budget: a request is
    admitted only when a slot is free (``max_slots`` comes from
    ``ServePlan.max_slots_per_device``);
  * **prefill/decode interleaving**: each engine iteration first
    prefills one queued request per free slot (batch 1, written into
    the slot with a donated ``dynamic_update_slice``), then decodes
    every live slot one token with a per-slot position vector —
    sequences at different depths share one batched decode step;
  * per-request **latency stats** (queue wait, TTFT, per-token rate,
    completion) are recorded on the host clock, plus a deterministic
    engine-step clock for benchmarks.

Slots whose request finished keep decoding garbage until re-admission
overwrites their cache — their outputs are ignored, and the admission
prefill rewrites every cache leaf of the slot, so no masking state is
needed on the device.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Built


def make_serve_step(built: Built) -> Callable:
    """jit'd (params, caches, tokens, t[, positions3]) -> (logits, caches).

    `t` may be a scalar (lockstep batch) or a (B,) vector (continuous
    batching: every slot decodes at its own position)."""
    model = built.model

    def serve_step(params, caches, tokens, t, positions3=None):
        return model.decode_step(params, caches, tokens, t,
                                 positions3=positions3)

    return jax.jit(serve_step, donate_argnums=(1,))


def make_prefill_step(built: Built,
                      cache_len: Optional[int] = None) -> Callable:
    """jit'd prefill; `cache_len` sizes the emitted KV cache (free
    slots after the prompt let decode append instead of rolling)."""
    model = built.model

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)

    return jax.jit(prefill_step)


def _sample(cfg, logits: jax.Array, key, temperature: float) -> jax.Array:
    logits = logits[..., :cfg.vocab_size].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return jax.random.categorical(
        key, logits / temperature, -1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# static batching (legacy engine)
# ---------------------------------------------------------------------------

@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


@dataclass
class Engine:
    """Static batching: one prefill, then every sequence decodes the
    same number of tokens in lockstep.  `cache_len` (>= prompt length)
    sizes the KV cache; default keeps the legacy prompt-length rolling
    cache."""

    built: Built
    params: Dict[str, jax.Array]
    temperature: float = 0.0
    cache_len: Optional[int] = None
    _prefill: Callable = field(init=False)
    _decode: Callable = field(init=False)

    def __post_init__(self):
        self._prefill = make_prefill_step(self.built, self.cache_len)
        self._decode = make_serve_step(self.built)

    def generate(self, prompts: np.ndarray, n_new: int,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, S) int32 token ids."""
        cfg = self.built.model.cfg
        assert cfg.is_decoder, "encoder-only models cannot decode"
        B, S = prompts.shape
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits[:, -1], key)
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, caches, tok,
                                          jnp.int32(S + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, 0], sub)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        toks = np.concatenate(out, axis=1) if out else np.zeros((B, 0), int)
        return GenerationResult(
            toks, t1 - t0, t2 - t1,
            B * n_new / max(t2 - t1, 1e-9))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        return _sample(self.built.model.cfg, logits, key, self.temperature)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

# terminal request states (RequestResult.status)
OK = "OK"                  # all max_new_tokens generated
INVALID = "INVALID"        # rejected at validation, never admitted
REJECTED = "REJECTED"      # queue-depth backpressure, never admitted
TIMED_OUT = "TIMED_OUT"    # deadline passed (queued or mid-decode)
FAILED = "FAILED"          # transient failures exhausted the retries


@dataclass(frozen=True)
class Request:
    """One serving request: a prompt, a decode budget, and optional
    deadlines.  `deadline_steps` is an absolute engine-step index by
    which the request must finish (the deterministic clock used by
    tests/benchmarks); `timeout_s` is the host-clock analogue.
    Validation happens at engine admission (`ContinuousEngine.run`
    returns an INVALID `RequestResult` for a bad request instead of
    raising mid-run and abandoning the other live slots)."""

    rid: int
    prompt: np.ndarray            # (S,) int32 token ids
    max_new_tokens: int
    deadline_steps: Optional[int] = None
    timeout_s: Optional[float] = None


@dataclass
class RequestResult:
    """Per-request output + latency accounting (host-clock seconds
    relative to `ContinuousEngine.run`'s start, plus the deterministic
    engine-step clock).  `status` is one of the terminal states OK /
    INVALID / REJECTED / TIMED_OUT / FAILED; only OK results carry a
    complete generation (TIMED_OUT / FAILED keep their partial tokens
    for inspection, but they do not count toward useful throughput)."""

    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n_generated,) int32
    t_enqueued: float
    t_admitted: float
    t_first_token: float
    t_finished: float
    admitted_at_step: int
    finished_at_step: int
    status: str = OK
    attempts: int = 1
    error: str = ""

    @property
    def ok(self) -> bool:
        return self.status == OK

    @property
    def n_generated(self) -> int:
        return int(len(self.tokens))

    @property
    def queue_wait_s(self) -> float:
        return self.t_admitted - self.t_enqueued

    @property
    def ttft_s(self) -> float:
        """Time to first token, queue wait included."""
        return self.t_first_token - self.t_enqueued

    @property
    def latency_s(self) -> float:
        return self.t_finished - self.t_enqueued


@dataclass
class ServeStats:
    """Aggregate engine counters for one `run`.  `completed` counts OK
    terminals only; `useful_tokens` counts tokens of still-live or OK
    requests (aborted attempts move theirs to `wasted_tokens`)."""

    wall_s: float
    prefill_steps: int
    decode_steps: int
    slots: int
    useful_tokens: int
    completed: int
    wasted_tokens: int = 0
    retries: int = 0
    rejected: int = 0
    invalid: int = 0
    timed_out: int = 0
    failed: int = 0

    @property
    def terminal(self) -> int:
        """Every request reached a terminal state — OK or not."""
        return (self.completed + self.rejected + self.invalid
                + self.timed_out + self.failed)

    @property
    def tokens_per_s(self) -> float:
        return self.useful_tokens / max(self.wall_s, 1e-9)

    @property
    def goodput_tokens_per_step(self) -> float:
        """OK-request tokens per decode step — the deterministic
        throughput metric fault benchmarks compare."""
        return self.useful_tokens / max(self.decode_steps, 1)

    @property
    def slot_utilization(self) -> float:
        """Useful decoded tokens / decoded slot-steps: 1.0 means no
        slot ever decoded a finished or empty sequence.  Clamped at 0
        — an all-wasted run (every attempt aborted or expired after
        its prefill) can drive useful below the prefill count."""
        produced = self.decode_steps * self.slots
        # the admission prefill also produces one token per request
        return max(0.0, (self.useful_tokens - self.prefill_steps)
                   / max(produced, 1))

    @property
    def completion_rate(self) -> float:
        """OK terminals / all terminals (0.0 for an empty run)."""
        return self.completed / max(self.terminal, 1)

    @property
    def tokens_per_request(self) -> float:
        """Useful tokens per OK request (0.0 when nothing completed —
        all-rejected and empty workloads must not divide by zero)."""
        if self.completed == 0:
            return 0.0
        return self.useful_tokens / self.completed


@dataclass
class _Entry:
    """One queue entry: the request plus its retry bookkeeping."""

    req: Request
    attempt: int = 1
    not_before: int = 0           # engine step gating re-admission


class ContinuousEngine:
    """Continuous batching over a fixed slot pool (see module docs).

    Hardening knobs (all off by default — the no-fault, no-deadline
    path is byte-identical to the pre-resilience engine):

      * `max_queue` — queue-depth backpressure: requests beyond
        `max_slots + max_queue` waiting at submission are REJECTED
        instead of queued (None = unbounded);
      * `max_retries` / `backoff_steps` — transiently-failed attempts
        (fault-injected, or a real shard error in production) are
        requeued with exponential backoff `backoff_steps * 2**(attempt
        - 1)` engine steps, then FAILED;
      * per-request `deadline_steps` / `timeout_s` — expired requests
        (queued or mid-decode) terminate TIMED_OUT, freeing the slot;
      * `faults` (a `resilience.faults.FaultSchedule` passed to `run`)
        injects device loss (raises `DeviceLost` carrying acknowledged
        results + requeueable pending work for the supervisor),
        transient failures, stalls, and admission pressure (graceful
        degradation: the effective slot count shrinks before memory
        does).
    """

    def __init__(self, built: Built, params: Dict[str, jax.Array],
                 max_slots: int, cache_len: int,
                 temperature: float = 0.0,
                 max_queue: Optional[int] = None,
                 max_retries: int = 2, backoff_steps: int = 2):
        cfg = built.model.cfg
        assert cfg.is_decoder, "encoder-only models cannot decode"
        if max_slots < 1 or cache_len < 1:
            raise ValueError("need max_slots >= 1 and cache_len >= 1")
        if max_retries < 0 or backoff_steps < 1:
            raise ValueError("need max_retries >= 0 and "
                             "backoff_steps >= 1")
        self.built = built
        self.params = params
        self.max_slots = int(max_slots)
        self.cache_len = int(cache_len)
        self.temperature = float(temperature)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.max_retries = int(max_retries)
        self.backoff_steps = int(backoff_steps)
        self._prefill = make_prefill_step(built, self.cache_len)
        self._decode = make_serve_step(built)

        def insert(caches, one, slot):
            return jax.tree_util.tree_map(
                lambda big, new: jax.lax.dynamic_update_slice_in_dim(
                    big, new.astype(big.dtype), slot, axis=1),
                caches, one)

        self._insert = jax.jit(insert, donate_argnums=(0,))
        self._active = False

    def _mrope_positions(self, t_vec: np.ndarray) -> Optional[jax.Array]:
        if self.built.model.cfg.rope != "mrope":
            return None
        return jnp.broadcast_to(
            jnp.asarray(t_vec, jnp.int32)[:, None, None],
            (len(t_vec), 1, 3))

    def _validate(self, req: Request) -> Optional[str]:
        """Reason the request can never be served, or None."""
        p = np.asarray(req.prompt)
        if p.ndim != 1 or p.size < 1:
            return "prompt must be a non-empty 1-D token array"
        if p.size > self.cache_len:
            return (f"prompt {p.size} exceeds cache_len "
                    f"{self.cache_len}")
        if req.max_new_tokens < 1:
            return "max_new_tokens must be >= 1"
        return None

    @staticmethod
    def _unserved(req: Request, status: str, error: str,
                  attempts: int = 0) -> RequestResult:
        p = np.asarray(req.prompt)
        return RequestResult(
            rid=req.rid, prompt_len=int(p.size) if p.ndim == 1 else 0,
            tokens=np.zeros(0, np.int32), t_enqueued=0.0,
            t_admitted=0.0, t_first_token=0.0, t_finished=0.0,
            admitted_at_step=0, finished_at_step=0, status=status,
            attempts=attempts, error=error)

    # -- incremental session API ---------------------------------------------
    #
    # `run` is submit-all-then-drain over these four primitives; the
    # fleet traffic simulator (`repro.serving.simulator`) interleaves
    # `submit` and `step` instead, injecting arrivals between engine
    # iterations on the deterministic engine-step clock.  One iteration
    # of the legacy serve loop == one `step()` call, so the refactor
    # leaves every `run` byte-identical (same RNG split order, same
    # admission order, same terminal states).

    def start(self, seed: int = 0, faults=None) -> None:
        """Open a serve session: allocate the slot caches and reset the
        per-run bookkeeping.  `submit`/`step`/`finish` require an open
        session; `start` on an open session raises."""
        from repro.resilience.faults import EMPTY_SCHEDULE
        if self._active:
            raise RuntimeError("a serve session is already open "
                               "(call finish() first)")
        B = self.max_slots
        self._faults = EMPTY_SCHEDULE if faults is None else faults
        self._results: List[RequestResult] = []
        self._n_invalid = self._n_rejected = 0
        self._queue: deque = deque()
        self._caches = self.built.model.init_caches(B, self.cache_len)
        self._key = jax.random.PRNGKey(seed)
        self._slot_req: List[Optional[Request]] = [None] * B
        self._slot_t = np.zeros(B, np.int32)    # next decode position
        self._slot_left = np.zeros(B, np.int64)  # tokens still to decode
        self._slot_toks: List[List[int]] = [[] for _ in range(B)]
        self._slot_admit: List[Tuple[float, float, int]] = \
            [(0.0, 0.0, 0)] * B
        self._slot_attempt = [1] * B
        self._slot_fail_at: List[Optional[int]] = [None] * B
        self._slot_stall = np.zeros(B, np.int64)
        self._last_tok = np.zeros((B, 1), np.int32)
        self._prefill_steps = self._decode_steps = 0
        self._engine_step = self._useful = 0
        self._wasted = self._retries = 0
        self._n_timeout = self._n_failed = 0
        self._t0 = time.perf_counter()
        self._active = True

    @property
    def active(self) -> bool:
        """A serve session is open (between `start` and `finish`)."""
        return self._active

    @property
    def engine_step(self) -> int:
        """The deterministic clock: prefills + decode steps so far."""
        return self._engine_step if self._active else 0

    @property
    def load(self) -> int:
        """Queued + in-flight requests (a router's balance signal)."""
        if not self._active:
            return 0
        return (len(self._queue)
                + sum(1 for r in self._slot_req if r is not None))

    @property
    def pending(self) -> bool:
        """Work remains: queued entries or live slots."""
        if not self._active:
            return False
        return bool(self._queue) or any(r is not None
                                        for r in self._slot_req)

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _require_active(self) -> None:
        if not self._active:
            raise RuntimeError("no open serve session (call start())")

    def submit(self, req: Request) -> Optional[RequestResult]:
        """Enqueue one request into the open session.  Returns the
        terminal `RequestResult` immediately for INVALID / REJECTED
        (backpressure) requests, None when the request was queued."""
        self._require_active()
        B = self.max_slots
        capacity = (None if self.max_queue is None
                    else B + self.max_queue)
        err = self._validate(req)
        if err is not None:
            res = self._unserved(req, INVALID, err)
            self._results.append(res)
            self._n_invalid += 1
            return res
        if capacity is not None and len(self._queue) >= capacity:
            res = self._unserved(
                req, REJECTED,
                f"backpressure: {len(self._queue)} requests already "
                f"waiting (max_slots {B} + max_queue "
                f"{self.max_queue})")
            self._results.append(res)
            self._n_rejected += 1
            return res
        self._queue.append(_Entry(req))
        return None

    def _finish_slot(self, slot: int, status: str = OK,
                     error: str = "") -> None:
        req = self._slot_req[slot]
        t_adm, t_first, step_adm = self._slot_admit[slot]
        n_tok = len(self._slot_toks[slot])
        if status != OK:
            self._useful -= n_tok
            self._wasted += n_tok
            if status == TIMED_OUT:
                self._n_timeout += 1
            elif status == FAILED:
                self._n_failed += 1
        self._results.append(RequestResult(
            rid=req.rid, prompt_len=len(req.prompt),
            tokens=np.asarray(self._slot_toks[slot], np.int32),
            t_enqueued=0.0, t_admitted=t_adm, t_first_token=t_first,
            t_finished=self._now(), admitted_at_step=step_adm,
            finished_at_step=self._engine_step, status=status,
            attempts=self._slot_attempt[slot], error=error))
        self._slot_req[slot] = None
        self._slot_toks[slot] = []

    def _abort_slot(self, slot: int) -> None:
        """Transient failure of the slot's current attempt: requeue
        with backoff, or FAILED when retries are spent."""
        req = self._slot_req[slot]
        attempt = self._slot_attempt[slot]
        if attempt <= self.max_retries:
            n_tok = len(self._slot_toks[slot])
            self._useful -= n_tok
            self._wasted += n_tok
            self._retries += 1
            self._queue.append(_Entry(
                req, attempt + 1,
                self._engine_step
                + self.backoff_steps * 2 ** (attempt - 1)))
            self._slot_req[slot] = None
            self._slot_toks[slot] = []
        else:
            self._finish_slot(slot, FAILED,
                              f"transient failure on attempt {attempt} "
                              f"(retry budget {self.max_retries} "
                              f"spent)")

    def _expired(self, req: Request) -> Optional[str]:
        if (req.deadline_steps is not None
                and self._engine_step >= req.deadline_steps):
            return (f"deadline_steps {req.deadline_steps} passed "
                    f"at engine step {self._engine_step}")
        if req.timeout_s is not None and self._now() > req.timeout_s:
            return f"timeout_s {req.timeout_s} passed"
        return None

    def _pop_admittable(self) -> Optional[_Entry]:
        """First queued entry whose backoff window opened; expires
        dead-on-arrival entries along the way.  Entries still
        backing off rotate to the tail (their FIFO position is
        already forfeit)."""
        queue = self._queue
        for _ in range(len(queue)):
            ent = queue.popleft()
            why = self._expired(ent.req)
            if why is not None:
                res = self._unserved(ent.req, TIMED_OUT,
                                     "expired in queue: " + why,
                                     attempts=ent.attempt - 1)
                res.t_finished = self._now()
                res.finished_at_step = self._engine_step
                self._results.append(res)
                self._n_timeout += 1
                continue
            if ent.not_before <= self._engine_step:
                return ent
            queue.append(ent)
        return None

    def step(self) -> Tuple[List[int], List[RequestResult]]:
        """One engine iteration: admissions (one prefill per free
        slot), then one batched decode step.  Returns (rids whose
        first token was produced this step, results that reached a
        terminal state this step).  Idle sessions no-op."""
        from repro.resilience.faults import DeviceLost
        self._require_active()
        faults = self._faults
        cfg = self.built.model.cfg
        B = self.max_slots
        queue = self._queue
        slot_req = self._slot_req
        if not queue and not any(r is not None for r in slot_req):
            return [], []
        n_before = len(self._results)
        ev = faults.device_loss_at(self._engine_step)
        if ev is not None:
            pending = [slot_req[i] for i in range(B)
                       if slot_req[i] is not None]
            pending += [e.req for e in queue]
            stats = self._session_stats()
            self._active = False
            raise DeviceLost(ev, self._engine_step,
                             results=self._results, stats=stats,
                             pending=pending)
        eff = B
        if not faults.empty:
            eff = max(1, min(B, int(math.ceil(
                B * faults.slot_factor(self._engine_step)))))
        # --- admission: one prefill per free slot ----------------------------
        admitted: List[int] = []
        n_live = sum(1 for r in slot_req if r is not None)
        for slot in range(B):
            if not queue:
                break
            if slot_req[slot] is not None:
                continue
            if n_live >= eff:
                break
            ent = self._pop_admittable()
            if ent is None:
                break
            req = ent.req
            t_adm = self._now()
            S = len(req.prompt)
            logits, one = self._prefill(
                self.params,
                {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]})
            self._caches = self._insert(self._caches, one, slot)
            self._key, sub = jax.random.split(self._key)
            tok = np.asarray(_sample(cfg, logits[:, -1], sub,
                                     self.temperature))
            self._prefill_steps += 1
            self._engine_step += 1
            self._useful += 1
            n_live += 1
            admitted.append(req.rid)
            slot_req[slot] = req
            self._slot_attempt[slot] = ent.attempt
            self._slot_fail_at[slot] = faults.fail_after_tokens(
                req.rid, ent.attempt, req.max_new_tokens)
            self._slot_stall[slot] = faults.stall_steps(req.rid)
            self._slot_t[slot] = S
            self._slot_left[slot] = req.max_new_tokens - 1
            self._slot_toks[slot] = [int(tok[0, 0])]
            self._slot_admit[slot] = (t_adm, self._now(),
                                      self._engine_step)
            self._last_tok[slot] = tok[0]
            if (self._slot_fail_at[slot] is not None
                    and len(self._slot_toks[slot])
                    >= self._slot_fail_at[slot]):
                self._abort_slot(slot)
                n_live -= 1
            elif self._slot_left[slot] == 0:
                self._finish_slot(slot)
                n_live -= 1

        active = [i for i in range(B) if slot_req[i] is not None]
        if not active:
            if queue:
                # every queued entry is backing off: burn one
                # engine step so their windows eventually open
                self._engine_step += 1
            return admitted, list(self._results[n_before:])
        # --- one batched decode step at per-slot positions -------------------
        pos3 = self._mrope_positions(self._slot_t)
        kw = {} if pos3 is None else {"positions3": pos3}
        logits, self._caches = self._decode(
            self.params, self._caches, jnp.asarray(self._last_tok),
            jnp.asarray(self._slot_t), **kw)
        self._key, sub = jax.random.split(self._key)
        toks = np.asarray(_sample(cfg, logits[:, 0], sub,
                                  self.temperature))
        self._decode_steps += 1
        self._engine_step += 1
        for i in active:
            stalled = self._slot_stall[i] > 0
            if stalled:
                # a stuck request burns the step without producing
                self._slot_stall[i] -= 1
            else:
                self._slot_toks[i].append(int(toks[i, 0]))
                self._slot_t[i] += 1
                self._slot_left[i] -= 1
                self._last_tok[i] = toks[i]
                self._useful += 1
            if (not stalled and self._slot_fail_at[i] is not None
                    and len(self._slot_toks[i])
                    >= self._slot_fail_at[i]):
                self._abort_slot(i)
            elif self._slot_left[i] == 0 and not stalled:
                self._finish_slot(i)
            else:
                why = self._expired(slot_req[i])
                if why is not None:
                    self._finish_slot(i, TIMED_OUT, why)
        return admitted, list(self._results[n_before:])

    def _session_stats(self) -> ServeStats:
        return self._stats(
            self._now(), self._prefill_steps, self._decode_steps,
            self._useful, self._results, self._wasted, self._retries,
            self._n_rejected, self._n_invalid, self._n_timeout,
            self._n_failed)

    def finish(self) -> Tuple[List[RequestResult], ServeStats]:
        """Close the session: (results in completion order, stats)."""
        self._require_active()
        jax.block_until_ready(self._caches)
        stats = self._session_stats()
        results = self._results
        self._active = False
        self._caches = None     # free the slot caches
        return results, stats

    def run(self, requests: Sequence[Request], seed: int = 0,
            faults=None) -> Tuple[List[RequestResult], ServeStats]:
        """Serve `requests` (FIFO) to a terminal state each; returns
        per-request results in completion order plus aggregate stats.

        With a `FaultSchedule`, injected failures play out
        deterministically (same seed -> same terminal states); an
        injected device loss raises `resilience.faults.DeviceLost`
        carrying the acknowledged results and the pending requests a
        supervisor must re-admit on the replanned engine."""
        self.start(seed, faults)
        for r in requests:
            self.submit(r)
        while self.pending:
            self.step()
        return self.finish()

    def _stats(self, wall_s, prefill_steps, decode_steps, useful,
               results, wasted, retries, n_rejected, n_invalid,
               n_timeout, n_failed) -> ServeStats:
        return ServeStats(
            wall_s=wall_s, prefill_steps=prefill_steps,
            decode_steps=decode_steps, slots=self.max_slots,
            useful_tokens=useful,
            completed=sum(1 for r in results if r.status == OK),
            wasted_tokens=wasted, retries=retries, rejected=n_rejected,
            invalid=n_invalid, timed_out=n_timeout, failed=n_failed)
