"""Parameter layout + sharding spec machinery.

Every model declares its parameters as `WeightSpec`s: shape, the TP
(tensor-parallel, `model` axis) placement, the ZDP axis (which dim the
OSDP plan may shard over `data`/`pod`), and the OSDP operator name the
weight belongs to. `materialize` turns specs + an OSDP plan into:

  * the param pytree (weights split into per-mode segments along the
    ZDP axis when the plan mixes modes — paper §3.3 per-slice plans),
  * a matching pytree of `NamedSharding`s,
  * per-op segment metadata the model fwd uses (`SegLayout`).

TP conventions (see DESIGN.md §6):
  * column-parallel: output dim sharded over `model` (w_q, w13, embed^T)
  * row-parallel: input dim sharded over `model`, output psum (w_o, w2)
  * experts: expert axis over `model` (expert parallelism)
  * small tensors (norms, biases, kv for replicated-kv GQA): no TP
ZDP overlays `data` (ZDP mode) or nothing (DP) on `zdp_axis`; in the
multi-pod mesh, ZDP uses ('pod','data') and ZDP_POD only 'data'.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.cluster.topology import parse_level_mode
from repro.core.cost_model import DP, ZDP, ZDP_POD, Decision


@dataclass(frozen=True)
class WeightSpec:
    """Declaration of one parameter tensor."""

    path: str                       # pytree path, e.g. "layers/ffn/w13"
    shape: Tuple[int, ...]
    op: str                         # OSDP operator this weight belongs to
    tp_axis: Optional[int] = None   # dim sharded over 'model' (None = no TP)
    zdp_axis: Optional[int] = None  # dim OSDP may shard (None = always DP)
    init: str = "normal"            # "normal" | "zeros" | "ones" | "ssm_a"
    init_scale: float = 0.02
    dtype: jnp.dtype = jnp.bfloat16
    stacked: bool = False           # leading dim is the layer axis


@dataclass
class Segment:
    """One contiguous slice of a weight along its ZDP axis."""

    mode: str
    start: int
    size: int
    key: str          # leaf name suffix ("" if single segment)
    # per-segment remat resolved from the plan's Decision.remat bits:
    # True = recompute this segment's activations, False = keep them,
    # None = inherit the run's global checkpointing default.  Segments
    # merge by sharding mode (storage), so a segment spanning slices
    # with mixed remat bits resolves to True (recompute — the
    # memory-safe direction).
    remat: Optional[bool] = None


@dataclass
class SegLayout:
    """Per-weight segmentation derived from the plan."""

    spec: WeightSpec
    segments: List[Segment]

    @property
    def is_split(self) -> bool:
        return len(self.segments) > 1


def _merge_modes(modes: Sequence[str], dim: int
                 ) -> List[Tuple[str, int, int, Tuple[int, ...]]]:
    """Merge adjacent equal-mode slices
    -> [(mode, start, size, contributing_slice_indices)].

    The slice boundaries quantize `dim` into len(modes) near-equal
    chunks, rounded to multiples of 128 where possible (MXU alignment).
    The index tuple records which plan slices actually contribute bytes
    to the merged segment (zero-width slices are excluded, so their
    remat bits cannot contaminate per-slice remat resolution).
    """
    g = len(modes)
    bounds = [0]
    for j in range(1, g):
        b = round(dim * j / g)
        if dim % 128 == 0 and dim // g >= 128:
            b = round(b / 128) * 128
        bounds.append(min(max(b, bounds[-1]), dim))
    bounds.append(dim)
    out: List[Tuple[str, int, int, Tuple[int, ...]]] = []
    for j, (m, s, e) in enumerate(zip(modes, bounds[:-1], bounds[1:])):
        if e <= s:
            continue
        if out and out[-1][0] == m:
            pm, ps, psz, pidx = out[-1]
            out[-1] = (pm, ps, psz + (e - s), pidx + (j,))
        else:
            out.append((m, s, e - s, (j,)))
    return out or [(modes[0], 0, dim, tuple(range(g)))]


def _segment_remat(decision: Optional[Decision],
                   idxs: Sequence[int]) -> Optional[bool]:
    """Resolve one merged segment's remat bit from the plan slices that
    contribute bytes to it: uniform -> that bit, mixed -> True
    (recompute is the memory-safe approximation), no explicit bits ->
    None (inherit)."""
    if decision is None or decision.remat is None:
        return None
    bits = set(decision.remat[j] for j in idxs)
    if bits == {True}:
        return True
    if bits == {False}:
        return False
    if bits == {None}:
        return None
    return True


def layout_for(spec: WeightSpec,
               decision: Optional[Decision]) -> SegLayout:
    modes = decision.modes if decision is not None else (DP,)
    if spec.zdp_axis is None or len(modes) == 1:
        mode = modes[0] if spec.zdp_axis is not None else DP
        return SegLayout(spec, [Segment(
            mode, 0, spec.shape[spec.zdp_axis]
            if spec.zdp_axis is not None else 0, "",
            _segment_remat(decision, range(len(modes))))])
    dim = spec.shape[spec.zdp_axis]
    merged = _merge_modes(list(modes), dim)
    if len(merged) == 1:
        m, _, _, idxs = merged[0]
        return SegLayout(spec, [Segment(m, 0, dim, "",
                                        _segment_remat(decision, idxs))])
    return SegLayout(spec, [Segment(m, s, z, f"@{i}",
                                    _segment_remat(decision, idxs))
                            for i, (m, s, z, idxs) in enumerate(merged)])


def data_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the data-parallel extent, outermost first
    (every axis that is not model/pipe — covers cluster-derived meshes
    whose axes are hierarchy level names).  The single definition of
    this rule; `core.plan.batch_axes` delegates here."""
    return tuple(a for a in mesh.axis_names if a not in ("model", "pipe"))


def _zdp_axes_names(mode: str, mesh: Mesh) -> Optional[Tuple[str, ...]]:
    """Mesh axes a sharding mode spreads the weight over.  ZDP takes
    the whole data extent; level-k modes (`ZDP@k` / the depth-2 alias
    ZDP_POD) take the k innermost (trailing) data axes."""
    if mode == DP:
        return None
    data_axes = data_axis_names(mesh)
    if mode == ZDP:
        return data_axes
    if mode == ZDP_POD:
        return data_axes[-1:]
    k = parse_level_mode(mode)
    if k is not None:
        return data_axes[-k:]
    raise ValueError(mode)


def segment_sharding(spec: WeightSpec, seg: Segment, seg_shape: Tuple[int, ...],
                     mesh: Mesh) -> NamedSharding:
    parts: List[Optional[object]] = [None] * len(seg_shape)
    if spec.tp_axis is not None:
        parts[spec.tp_axis] = "model"
    names = _zdp_axes_names(seg.mode, mesh)
    if names is not None and spec.zdp_axis is not None:
        n = math.prod(mesh.shape[a] for a in names)
        if seg_shape[spec.zdp_axis] % n == 0:
            parts[spec.zdp_axis] = names if len(names) > 1 else names[0]
        elif (len(names) > 1
              and seg_shape[spec.zdp_axis] % mesh.shape[names[-1]] == 0):
            # fall back to the innermost data axis (in-pod sharding)
            parts[spec.zdp_axis] = names[-1]
        # else: leave replicated (divisibility guard; cost model's saving
        # for this segment is then optimistic — flagged by tests)
    return NamedSharding(mesh, P(*parts))


def _init_array(key: jax.Array, spec: WeightSpec,
                shape: Tuple[int, ...]) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(shape, spec.dtype)
    if spec.init == "ssm_a":
        # Mamba2 A init: -exp(U[log 1, log 16]) stored as log(-A)
        u = jax.random.uniform(key, shape, jnp.float32,
                               minval=math.log(1.0), maxval=math.log(16.0))
        return u.astype(spec.dtype)
    scale = spec.init_scale
    if spec.init == "fan_in":
        fan = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(fan)
    return (jax.random.normal(key, shape, jnp.float32) * scale
            ).astype(spec.dtype)


@dataclass(frozen=True)
class OverlapConfig:
    """Runtime comm/compute overlap knobs — the executed counterpart of
    `ClusterLevel.overlap` in the cost model.

    `prefetch` is how many segment weights ahead `seg_matmul` forces
    XLA to gather: slice k+prefetch's all-gather is barrier-ordered
    before slice k's contraction, so the gather streams behind the
    matmul instead of serializing with it (0 disables).  `bucket_bytes`
    groups gradient leaves into independently-schedulable all-reduce
    buckets overlapping the remaining backward walk; smaller buckets
    start reducing earlier but pay more per-collective latency (the
    alpha term), larger ones amortize latency but expose more tail —
    the trade-off `docs/cost_model.md` §9 quantifies.  Both transforms
    are identity on values: the overlapped step computes bit-identical
    results (asserted by tests/test_overlap.py)."""

    prefetch: int = 1
    bucket_bytes: int = 4 << 20

    def __post_init__(self):
        if self.prefetch < 0:
            raise ValueError("prefetch must be >= 0")
        if self.bucket_bytes < 0:
            raise ValueError("bucket_bytes must be >= 0")


@dataclass
class ParamSet:
    """Materialized parameters + shardings + segmentation metadata."""

    params: Dict[str, jax.Array]              # flat path -> array
    shardings: Dict[str, NamedSharding]
    layouts: Dict[str, SegLayout]              # weight path -> layout
    overlap: Optional[OverlapConfig] = None    # runtime overlap knobs

    def tree(self) -> Dict[str, jax.Array]:
        return self.params

    def sharding_tree(self) -> Dict[str, NamedSharding]:
        return self.shardings

    def segments(self, path: str) -> List[Tuple[str, Segment]]:
        """[(leaf_key, segment)] for a declared weight path."""
        lay = self.layouts[path]
        return [(path + s.key, s) for s in lay.segments]

    def n_params(self) -> int:
        return sum(int(np.prod(a.shape)) for a in self.params.values())


def seg_shape(spec: WeightSpec, seg: Segment) -> Tuple[int, ...]:
    if spec.zdp_axis is None:
        return spec.shape
    shp = list(spec.shape)
    shp[spec.zdp_axis] = seg.size
    return tuple(shp)


def build_param_set(specs: Sequence[WeightSpec],
                    decisions: Optional[Dict[str, Decision]],
                    mesh: Optional[Mesh],
                    key: jax.Array,
                    abstract: bool = False,
                    overlap: Optional[OverlapConfig] = None) -> ParamSet:
    """Create params (or ShapeDtypeStructs if abstract) + shardings."""
    params: Dict[str, jax.Array] = {}
    shardings: Dict[str, NamedSharding] = {}
    layouts: Dict[str, SegLayout] = {}
    keys = jax.random.split(key, max(1, len(specs)))
    for k, spec in zip(keys, specs):
        dec = decisions.get(spec.op) if decisions else None
        lay = layout_for(spec, dec)
        layouts[spec.path] = lay
        for seg in lay.segments:
            shp = seg_shape(spec, seg)
            leaf = spec.path + seg.key
            if mesh is not None:
                shardings[leaf] = segment_sharding(spec, seg, shp, mesh)
            if abstract:
                params[leaf] = jax.ShapeDtypeStruct(shp, spec.dtype)
            else:
                params[leaf] = _init_array(k, spec, shp)
    return ParamSet(params, shardings, layouts, overlap)


# --- hybrid 3D meshes (data x model x pipe) ----------------------------------
#
# A HybridPlan executes on a 3-axis mesh: `data` carries the DP/ZDP
# decisions exactly as above, `model` carries TP, and `pipe` carries
# the GPipe stages. The pipe axis never appears in a weight's
# PartitionSpec — each stage materializes only its own layer slice
# (below), so `segment_sharding` applies unchanged on the hybrid mesh
# (ZDP resolves to ('data',) since hybrid meshes have no 'pod' axis).

def hybrid_mesh_spec(dp: int, tp: int, pp: int):
    """(shape, axes) of the 3-axis hybrid mesh for jax.make_mesh."""
    from repro.core.hybrid import Factorization
    cfg = Factorization(dp, tp, pp).mesh_config()
    return cfg.shape, cfg.axes


def stage_of_layer(layer: int, bounds: Sequence[int]) -> int:
    """Pipeline stage owning `layer` under HybridPlan.stage_bounds."""
    for s in range(len(bounds) - 1):
        if bounds[s] <= layer < bounds[s + 1]:
            return s
    raise ValueError(f"layer {layer} outside stage bounds {bounds}")


def stage_weight_specs(specs: Sequence[WeightSpec],
                       bounds: Sequence[int],
                       stage: int) -> List[WeightSpec]:
    """The per-stage view of a weight list for pipeline execution.

    Stacked weights (leading layer axis) shrink to the stage's layer
    range; unstacked weights follow the usual GPipe placement —
    embeddings on the first stage, head/final-norm on the last. When
    embeddings are tied (no separate head weight in the list), the
    embedding is also placed on the last stage so it can project
    logits there.
    """
    n_stages = len(bounds) - 1
    last = n_stages - 1
    lo, hi = bounds[stage], bounds[stage + 1]
    tied = not any(s.path.startswith("head") for s in specs
                   if not s.stacked)
    out: List[WeightSpec] = []
    for spec in specs:
        if spec.stacked:
            n = hi - lo
            if n <= 0:
                continue
            shp = (n,) + tuple(spec.shape[1:])
            out.append(dataclasses.replace(spec, shape=shp))
        elif spec.path.startswith("embed"):
            if stage == 0 or (tied and stage == last):
                out.append(spec)
        elif stage == last:
            out.append(spec)
    return out


# --- selective-remat checkpoint policy ---------------------------------------

def saved_activation_names(layouts: Dict[str, SegLayout],
                           default_remat: bool
                           ) -> Tuple[Tuple[str, ...], bool]:
    """(names whose activations the jax.checkpoint policy should save,
    whether anything remats at all) for a materialized plan.

    `seg_matmul` tags each segment's output with `checkpoint_name`:
    per-leaf names for output-dim (concat) segments, the bare weight
    path for the combined output (single-segment and input-dim-sum
    cases — where per-slice saving isn't representable, the whole
    output is saved only if every slice keeps its activations).
    Unresolved (inherit) segments follow `default_remat`.
    """
    saved: List[str] = []
    any_remat = False
    for path, lay in layouts.items():
        kept = []
        for seg in lay.segments:
            r = bool(default_remat) if seg.remat is None else seg.remat
            if r:
                any_remat = True
                kept.append(False)
            else:
                saved.append(path + seg.key)
                kept.append(True)
        if len(lay.segments) > 1 and all(kept):
            saved.append(path)    # sum-variant tag on the whole output
    return tuple(sorted(set(saved))), any_remat


# --- helpers used by model forward passes -----------------------------------

def gather_weight(params: Dict[str, jax.Array], pset: ParamSet,
                  path: str) -> jax.Array:
    """Concatenate a weight's segments back (for ops that don't exploit
    sequential slice processing). Axis accounts for the layer axis being
    consumed when called inside the scan-over-layers body."""
    segs = pset.segments(path)
    if len(segs) == 1:
        return params[segs[0][0]]
    spec = pset.layouts[path].spec
    axis = spec.zdp_axis
    if spec.stacked and params[segs[0][0]].ndim == len(spec.shape) - 1:
        axis -= 1
    return jnp.concatenate([params[k] for k, _ in segs], axis=axis)


def _prefetch_weights(ws: List[jax.Array], ahead: int) -> List[jax.Array]:
    """One-slice-ahead (or `ahead`-ahead) weight prefetch.

    Barrier-ties each segment's weight to its successor `ahead` slices
    later: `optimization_barrier` is identity on values but tells XLA
    that slice k's contraction cannot be scheduled before slice
    k+ahead's weight (i.e. its ZDP all-gather) has been issued, so the
    gather of the next slice streams behind the current matmul instead
    of serializing after it.  Numerics are untouched.
    """
    out = list(ws)
    ahead = max(1, ahead)
    for k in range(len(out) - 1):
        j = min(k + ahead, len(out) - 1)
        out[k], out[j] = jax.lax.optimization_barrier((out[k], out[j]))
    return out


def seg_matmul(x: jax.Array, params: Dict[str, jax.Array], pset: ParamSet,
               path: str, in_axis_in_weight: int) -> jax.Array:
    """Operator splitting (§3.3) over per-mode segments.

    If the split axis is the weight's *input* (contraction) dim — the
    paper's Figure 4 case — segments are processed sequentially and
    summed: y = sum_j x[..., slice_j] @ W_j. If it is the *output* dim
    (row-parallel weights, whose input dim is TP-owned), segment outputs
    are computed sequentially and concatenated. Either way only one
    gathered slice is live at a time. `in_axis_in_weight` counts within
    the per-layer weight (excluding a stacked layer axis).

    With `pset.overlap.prefetch > 0` segment weights are chained through
    `_prefetch_weights` so slice k+1's all-gather overlaps slice k's
    contraction (value-identical; scheduling only).
    """
    segs = pset.segments(path)
    spec = pset.layouts[path].spec
    # outputs are tagged with checkpoint_name so a selective-remat plan
    # compiles to a save_only_these_names policy (identity otherwise)
    if len(segs) == 1:
        return checkpoint_name(
            _contract(x, params[segs[0][0]], in_axis_in_weight), path)
    ws = [params[leaf] for leaf, _ in segs]
    if pset.overlap is not None and pset.overlap.prefetch > 0:
        ws = _prefetch_weights(ws, pset.overlap.prefetch)
    zdp_local = spec.zdp_axis - (1 if spec.stacked else 0)
    if zdp_local == in_axis_in_weight:
        # sum variant (input-dim split, Figure 4): partial sums are
        # full-size, so only the combined output carries a name
        y = None
        off = 0
        for w, (leaf, seg) in zip(ws, segs):
            xs = jax.lax.dynamic_slice_in_dim(x, off, seg.size, axis=-1)
            part = _contract(xs, w, in_axis_in_weight)
            y = part if y is None else y + part
            off += seg.size
        return checkpoint_name(y, path)
    # concat variant (output-dim split): per-segment names, so remat
    # stays a per-slice choice in the executed program
    parts = [checkpoint_name(_contract(x, w, in_axis_in_weight), leaf)
             for w, (leaf, _) in zip(ws, segs)]
    return jnp.concatenate(parts, axis=-1)


def _contract(x: jax.Array, w: jax.Array, in_axis: int) -> jax.Array:
    if w.ndim == 2 and in_axis == 0:
        return x @ w
    return jnp.tensordot(x, w, axes=((x.ndim - 1,), (in_axis,)))
