"""hubert-xlarge — HuBERT X-Large encoder. [arXiv:2106.07447]

Encoder-only (bidirectional, non-causal) transformer backbone, same
arch as wav2vec2. The conv waveform feature extractor is STUBBED per
the assignment carve-out: input_specs() supplies 1280-d frame
embeddings. Masked-prediction head over 504 k-means units.
No decode shapes (encoder-only) — see DESIGN.md §5.
"""
from repro.configs.base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family=AUDIO,
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,         # k-means targets; padded to 512 internally
    causal=False,
    encoder_only=True,
    rope="none",            # HuBERT uses conv positional embedding (stubbed
                            # into the frame embeddings); backbone is pos-free
    norm="layernorm",
    act="gelu",
    source="[arXiv:2106.07447]",
)
