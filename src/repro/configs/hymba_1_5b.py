"""hymba-1.5b — NVIDIA Hymba. [arXiv:2411.13676]

Hybrid-head architecture: attention heads and Mamba(SSM) heads run in
PARALLEL inside every layer on the same input, outputs fused via
normalized mean. Most attention is sliding-window (Hymba uses SWA in
all but three layers), which is what makes long_500k feasible.
"""
from repro.configs.base import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=HYBRID,
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,      # padded to 32256 internally (model axis = 16)
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=50,       # d_inner=3200 -> 64 ssm heads
    sliding_window=1024,
    act="swiglu",
    rope="rope",
    source="[arXiv:2411.13676]",
)
