"""Config registry: the 10 assigned architectures + input shapes.

`get_arch(name)` accepts the assignment ids (with dashes/dots).
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    AUDIO, DENSE, HYBRID, MOE, SSM, VLM,
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K, SHAPES,
    SINGLE_POD_MESH, MULTI_POD_MESH, DEVICE_PRESETS,
    ILP_BACKENDS, PRESET_CATALOG, PRESET_OVERLAP, SOLVERS,
    DeviceInfo, DevicePreset, MeshConfig, ModelConfig, OSDPConfig,
    RunConfig, ShapeConfig, reduced,
)

from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen15
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4

ARCHS = {
    c.name: c
    for c in (
        _arctic, _dbrx, _moonshot, _hymba, _qwen2vl,
        _llama3, _qwen15, _mamba2, _hubert, _phi4,
    )
}


def get_arch(name: str) -> ModelConfig:
    key = name.strip()
    if key in ARCHS:
        cfg = ARCHS[key]
    else:
        # tolerate underscore / case variants
        norm = key.lower().replace("_", "-")
        matches = [c for n, c in ARCHS.items() if n.lower() == norm]
        if not matches:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
        cfg = matches[0]
    cfg.validate()
    return cfg


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def supported_shapes(model: ModelConfig) -> list[str]:
    """Which assigned shapes run for this arch (skips per DESIGN.md §5)."""
    names = ["train_4k", "prefill_32k"]
    if model.is_decoder:
        names.append("decode_32k")
        names.append("long_500k")  # SWA/SSM path; see DESIGN.md §5
    return names
