"""phi4-mini-3.8b — Phi-4-mini. [arXiv:2412.08905]

Dense decoder: RoPE + SwiGLU + GQA, 200k vocab.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family=DENSE,
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    act="swiglu",
    rope="rope",
    source="[arXiv:2412.08905]",
)
