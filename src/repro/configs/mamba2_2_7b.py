"""mamba2-2.7b — Mamba-2 2.7B, SSD (state-space duality). [arXiv:2405.21060]

Attention-free: 64 SSD layers, d_model=2560, d_inner=5120,
ssm_state=128, head_dim=64 -> 80 SSD heads. long_500k runs natively
(decode carries only the (heads, head_dim, state) recurrent state).
"""
from repro.configs.base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family=SSM,
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                 # Mamba2 blocks have no separate FFN
    vocab_size=50280,       # padded to 50432 internally
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    rope="none",
    act="swiglu",
    source="[arXiv:2405.21060]",
)
