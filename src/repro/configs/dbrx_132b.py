"""dbrx-132b — Databricks DBRX base. [hf:databricks/dbrx-base]

Fine-grained MoE: 16 experts, top-4 routing.
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe_experts=16,
    moe_top_k=4,
    act="swiglu",
    rope="rope",
    rope_theta=500_000.0,
    source="[hf:databricks/dbrx-base]",
)
