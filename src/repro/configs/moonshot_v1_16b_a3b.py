"""moonshot-v1-16b-a3b — Moonlight-16B-A3B. [hf:moonshotai/Moonlight-16B-A3B]

Pool tags it [dense] but the assigned spec line says "MoE 64e top-6";
built exactly to the bracketed spec (64 experts, top-6, d_ff=1408
fine-grained experts). The real Moonlight adds shared experts / MLA —
intentionally not added (see DESIGN.md §5).
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family=MOE,
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe_experts=64,
    moe_top_k=6,
    act="swiglu",
    rope="rope",
    source="[hf:moonshotai/Moonlight-16B-A3B]",
)
