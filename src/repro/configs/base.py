"""Model / run configuration system.

Every assigned architecture is a `ModelConfig`; input shapes are
`ShapeConfig`s; `RunConfig` binds (arch, shape, mesh, OSDP options).
Configs are plain frozen dataclasses so they hash, print, and diff
cleanly, and so the dry-run can enumerate the full grid.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (the paper's "model description" MD)."""

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int            # query heads; 0 for attention-free (ssm)
    n_kv_heads: int         # GQA kv heads
    d_ff: int               # FFN hidden (per-expert hidden for MoE)
    vocab_size: int
    head_dim: int = 0       # 0 -> d_model // n_heads
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    rope: str = "rope"      # "rope" | "mrope" | "none"
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w halves of head_dim/2
    sliding_window: int = 0  # 0 = full attention (native); >0 native SWA
    causal: bool = True      # False for encoder-only
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel w/ MoE
    moe_capacity_factor: float = 1.25
    moe_dense_d_ff: int = 0            # dense-residual hidden (0 -> d_ff)
    # --- SSM (Mamba2 / SSD) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- misc --------------------------------------------------------------
    act: str = "swiglu"     # "swiglu" | "gelu"
    norm: str = "rmsnorm"   # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    encoder_only: bool = False
    vocab_pad_multiple: int = 256
    dtype: str = "bfloat16"
    # provenance, e.g. "[hf:Snowflake/snowflake-arctic-base]"
    source: str = ""

    # --- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != SSM

    @property
    def has_ssm(self) -> bool:
        return self.family in (SSM, HYBRID)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def is_decoder(self) -> bool:
        return not self.encoder_only

    def param_count(self) -> int:
        """Exact parameter count of the model as built (padded vocab)."""
        d, L, V = self.d_model, self.n_layers, self.padded_vocab
        nm = 2 if self.norm == "layernorm" else 1   # scale (+bias)
        if self.encoder_only:
            total = d                      # mask embedding (audio stub)
        else:
            total = V * d                  # token embedding
        if not self.tie_embeddings:
            total += V * d                 # lm head
        total += nm * d                    # final norm
        per_layer = 0
        if self.has_attention:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
            per_layer += nm * d            # attn norm
        if self.has_ssm:
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_n_heads
            # in_proj: x(z, x, B, C, dt); out_proj; A, D, dt_bias; gate norm;
            # depthwise causal conv (K=4) over (x, B, C)
            per_layer += (d * (2 * di + 2 * ns * 1 + nh) + di * d
                          + 3 * nh + di + 4 * (di + 2 * ns))
            per_layer += d                 # ssm norm
        # FFN / MoE
        ff_mult = 3 if self.act == "swiglu" else 2
        if self.is_moe:
            per_layer += self.moe_experts * ff_mult * d * self.d_ff
            per_layer += d * self.moe_experts           # router
            if self.moe_dense_residual:
                per_layer += ff_mult * d * (self.moe_dense_d_ff or self.d_ff)
        elif self.d_ff:
            per_layer += ff_mult * d * self.d_ff
        if self.d_ff or self.is_moe:
            per_layer += nm * d            # ffn norm
        return total + L * per_layer

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        ff_mult = 3 if self.act == "swiglu" else 2
        inactive_experts = self.moe_experts - self.moe_top_k
        return self.param_count() - L * inactive_experts * ff_mult * d * self.d_ff

    def validate(self) -> None:
        assert self.family in FAMILIES, self.family
        if self.has_attention:
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0, (
                f"{self.name}: GQA requires n_heads % n_kv_heads == 0")
        if self.has_ssm:
            assert self.ssm_state > 0
            assert self.ssm_d_inner % self.ssm_head_dim == 0
        if self.is_moe:
            assert 0 < self.moe_top_k <= self.moe_experts


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh."""

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def data_parallel(self) -> int:
        """Total data-parallel ways (pod x data)."""
        n = 1
        for s, a in zip(self.shape, self.axes):
            if a in ("pod", "data"):
                n *= s
        return n

    @property
    def model_parallel(self) -> int:
        for s, a in zip(self.shape, self.axes):
            if a == "model":
                return s
        return 1

    @property
    def pipeline_parallel(self) -> int:
        for s, a in zip(self.shape, self.axes):
            if a == "pipe":
                return s
        return 1

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class DeviceInfo:
    """The paper's "device information" DI — profiled hardware constants.

    Defaults are the assignment's TPU v5e targets.  This is the *flat*
    device model (one fast + one slow bandwidth); real hierarchies
    (chip -> node -> pod -> cluster, heterogeneous memory) are
    described by `repro.cluster.topology.ClusterSpec`, whose depth-2
    degenerate case reproduces this model exactly.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bytes: float = 16 * 2**30       # per-chip HBM capacity
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    dci_bw: float = 25e9                # inter-pod (pod axis) bytes/s
    alpha: float = 1e-6                 # collective latency per step (s)
    # gamma: seconds of compute per (FLOP / peak) — 1.0 means roofline;
    # real kernels run below peak, so the cost model uses this efficiency.
    mxu_efficiency: float = 0.55
    # devices sharing the fast (ici_bw) domain — lets topology-aware
    # code infer a node boundary from a flat DeviceInfo (0 = unknown:
    # the whole extent is assumed to sit on ici_bw, the legacy model)
    devices_per_node: int = 0
    # fraction of collective time the runtime can hide under compute
    # (prefetched gathers / async all-reduce).  0 keeps the serial cost
    # model — every committed golden is pinned at 0; per-preset
    # achievable values live in PRESET_OVERLAP and are opt-in via
    # `preset(name, overlap=...)` / `--overlap`.
    overlap: float = 0.0

    def link_bw(self, axis: str) -> float:
        return self.dci_bw if axis == "pod" else self.ici_bw

    @classmethod
    def preset(cls, name: str,
               overlap: Union[float, str, None] = None) -> "DeviceInfo":
        """Catalog of profiled hardware targets (`--device` on the
        launchers and benchmark CLIs).  `overlap` sets the comm/compute
        overlap factor: None keeps the serial model (0.0, the golden-
        pinned default), "auto" takes the preset's achievable value
        from the catalog, a float is used as-is."""
        try:
            dev = PRESET_CATALOG[name].info
        except KeyError:
            raise KeyError(
                f"unknown device preset {name!r}; "
                f"known: {sorted(PRESET_CATALOG)}") from None
        if overlap is None:
            return dev
        if overlap == "auto":
            overlap = PRESET_CATALOG[name].achievable_overlap
        return dataclasses.replace(dev, overlap=float(overlap))


@dataclass(frozen=True)
class DevicePreset:
    """One catalog entry: the datasheet DeviceInfo plus the per-preset
    knobs that stay out of the serial cost model.  `achievable_overlap`
    is what `--overlap auto` opts into (a bare `preset(name)` still
    prices serially — committed goldens depend on it).  Measured
    overrides do NOT live here: a fitted CalibrationProfile layers on
    top via `repro.calibrate.store`, the single override point."""

    info: "DeviceInfo"
    achievable_overlap: float


# The single source of per-device constants.  peak_flops are bf16
# dense; mxu_efficiency is the sustained fraction the cost model's
# gamma term uses (per-family empirical deratings) — the scalar a
# fitted EfficiencyCurve replaces.  achievable_overlap: how much of a
# collective the runtime's prefetched gathers / bucketed async
# all-reduce can hide under compute on that interconnect.
PRESET_CATALOG = {
    "tpu-v5e": DevicePreset(DeviceInfo(
        name="tpu-v5e", peak_flops=197e12, hbm_bytes=16 * 2**30,
        hbm_bw=819e9, ici_bw=50e9, dci_bw=25e9, alpha=1e-6,
        mxu_efficiency=0.55),
        achievable_overlap=0.7),   # ICI schedules well behind the MXU
    "tpu-v4": DevicePreset(DeviceInfo(
        name="tpu-v4", peak_flops=275e12, hbm_bytes=32 * 2**30,
        hbm_bw=1228e9, ici_bw=100e9, dci_bw=25e9, alpha=1e-6,
        mxu_efficiency=0.55),
        achievable_overlap=0.7),
    "a100-80g": DevicePreset(DeviceInfo(
        name="a100-80g", peak_flops=312e12, hbm_bytes=80 * 2**30,
        hbm_bw=2039e9, ici_bw=300e9, dci_bw=25e9, alpha=5e-6,
        mxu_efficiency=0.45, devices_per_node=8),
        achievable_overlap=0.6),   # NCCL copy engines vs SM contention
    "h100-sxm": DevicePreset(DeviceInfo(
        name="h100-sxm", peak_flops=989e12, hbm_bytes=80 * 2**30,
        hbm_bw=3350e9, ici_bw=450e9, dci_bw=50e9, alpha=5e-6,
        mxu_efficiency=0.45, devices_per_node=8),
        achievable_overlap=0.8),   # SHARP offload + faster NVLink
}

DEVICE_PRESETS = tuple(sorted(PRESET_CATALOG))

# legacy view kept for callers that index the overlap table directly;
# derived from the catalog so the constants live in exactly one place
PRESET_OVERLAP = {name: p.achievable_overlap
                  for name, p in PRESET_CATALOG.items()}


# OSDPConfig.checkpointing value that promotes remat from a global
# switch into a per-slice searched decision (DP/ZDP x remat/no-remat)
SELECTIVE = "selective"

# the Search Engine's interchangeable cover-problem solvers: three
# engineered heuristics/exacts plus the explicit ILP oracle (ISSUE 6)
SOLVERS = ("dfs", "knapsack", "greedy", "ilp")
ILP_BACKENDS = ("auto", "milp", "bnb")


@dataclass(frozen=True)
class OSDPConfig:
    """OSDP feature switches for a run."""

    enabled: bool = True
    memory_limit_bytes: float = 16 * 2**30   # per-device M_limit
    search: str = "dfs"                      # one of SOLVERS
    allow_pod_hierarchical: bool = True      # beyond-paper ZDP_POD mode
    operator_splitting: bool = True
    default_slice_granularity: int = 4
    # beyond-paper: per-operator slice granularity from the cost model
    # (the paper fixes g=4 and names auto-tuning as future work, §4.3)
    auto_granularity: bool = False
    # remat (affects ZDP cost, §4.3): True/False force the legacy global
    # setting; "selective" searches remat per slice, jointly with the
    # sharding mode (4-mode axis; beyond paper)
    checkpointing: Union[bool, str] = True
    force_mode: Optional[str] = None         # "DP" | "ZDP": bypass search
    # alias for `search` (the solver-facing name): OSDPConfig(
    # solver="ilp") == OSDPConfig(search="ilp").  When set it overrides
    # the `search` default; setting both to different values is an error.
    solver: Optional[str] = None
    # --- ilp solver knobs (search="ilp" only) ------------------------------
    # anytime mode: > 0 caps each cover solve at this many seconds and
    # accepts the incumbent + proven bound; 0 = solve to optimality
    ilp_time_budget_s: float = 0.0
    ilp_backend: str = "auto"                # one of ILP_BACKENDS

    def __post_init__(self):
        if self.solver is not None:
            if self.search != "dfs" and self.search != self.solver:
                raise ValueError(
                    f"search={self.search!r} and solver={self.solver!r} "
                    f"disagree: `solver` is an alias for `search`, set "
                    f"one of them")
            object.__setattr__(self, "search", self.solver)
        if self.search not in SOLVERS:
            raise ValueError(
                f"search={self.search!r}: unknown solver; "
                f"known: {SOLVERS}")
        if self.ilp_backend not in ILP_BACKENDS:
            raise ValueError(
                f"ilp_backend={self.ilp_backend!r}: "
                f"known: {ILP_BACKENDS}")
        if self.ilp_time_budget_s < 0:
            raise ValueError("ilp_time_budget_s must be >= 0")
        if isinstance(self.checkpointing, str) \
                and self.checkpointing != SELECTIVE:
            raise ValueError(
                f"checkpointing={self.checkpointing!r}: the only "
                f"string value is {SELECTIVE!r} (or use True/False "
                f"for the global setting)")
        if self.force_mode and self.selective_remat:
            raise ValueError(
                "force_mode bypasses the search, so there is no "
                "selective-remat axis to decide: combine force_mode "
                "with checkpointing=True/False")

    @property
    def selective_remat(self) -> bool:
        return self.checkpointing == SELECTIVE

    @property
    def env_checkpointing(self) -> bool:
        """The CostEnv default-remat bit this config implies: selective
        searches start from the no-remat base plan; any other truthy
        value keeps the legacy global-remat behaviour."""
        return bool(self.checkpointing) and not self.selective_remat


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig
    osdp: OSDPConfig = field(default_factory=OSDPConfig)
    # long-context strategy for full-attention archs ("swa" | "native")
    long_context: str = "swa"
    swa_window: int = 8_192
    microbatch: int = 0       # 0 = no microbatching
    seed: int = 0

    @property
    def per_device_batch(self) -> int:
        dp = self.mesh.data_parallel
        if self.shape.global_batch % dp == 0:
            return self.shape.global_batch // dp
        return max(1, self.shape.global_batch // dp)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test variant of the same family: <=2 layers, d_model<=512,
    <=4 experts, tiny vocab — runnable on one CPU device."""
    head_dim = 64
    n_heads = max(2, min(4, cfg.n_heads or 2))
    n_kv = max(1, min(cfg.n_kv_heads or 1, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    small = dict(
        n_layers=2,
        d_model=n_heads * head_dim,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=256,
        vocab_size=512,
        vocab_pad_multiple=16,
        mrope_sections=(16, 8, 8),
    )
    if cfg.is_moe:
        small.update(moe_experts=4, moe_top_k=min(2, cfg.moe_top_k),
                     moe_dense_d_ff=128)
    if cfg.has_ssm:
        small.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.sliding_window:
        small.update(sliding_window=64)
    small.update(overrides)
    out = dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
    out.validate()
    return out
