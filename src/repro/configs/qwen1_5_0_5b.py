"""qwen1.5-0.5b — Qwen1.5 0.5B. [hf:Qwen/Qwen1.5-0.5B]

Small dense decoder with QKV bias and tied embeddings; the paper's
N&D small-hidden regime where OSDP keeps most operators in DP mode.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family=DENSE,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    act="swiglu",
    rope="rope",
    source="[hf:Qwen/Qwen1.5-0.5B]",
)
