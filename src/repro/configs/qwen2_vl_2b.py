"""qwen2-vl-2b — Qwen2-VL 2B language backbone. [arXiv:2409.12191]

M-RoPE (multimodal rotary: temporal/height/width sections) + dynamic
resolution. The ViT vision encoder is STUBBED per the assignment
carve-out: input_specs() supplies pre-projected patch embeddings that
are merged into the token stream ahead of the text tokens.
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family=VLM,
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope="mrope",
    mrope_sections=(16, 24, 24),   # t/h/w split of head_dim/2 = 64
    rope_theta=1_000_000.0,
    act="swiglu",
    source="[arXiv:2409.12191]",
)

# VLM stub frontend: number of image patch embeddings prepended per
# sequence (dynamic resolution -> fixed budget for the dry-run shapes).
N_PATCHES = 256
