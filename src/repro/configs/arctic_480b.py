"""arctic-480b — Snowflake Arctic base. [hf:Snowflake/snowflake-arctic-base]

MoE 128 experts top-2 with a dense residual MLP in parallel
(Arctic's "dense-MoE hybrid" design).
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family=MOE,
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe_experts=128,
    moe_top_k=2,
    moe_dense_residual=True,
    moe_dense_d_ff=4864,
    act="swiglu",
    rope="rope",
    source="[hf:Snowflake/snowflake-arctic-base]",
)
