"""llama3-405b — Llama 3.1 405B. [arXiv:2407.21783]

Dense GQA decoder, 128k vocab. The paper's W&S regime at extreme
scale: the 16384x53248 FFN matmuls are exactly the "gigantic tensor"
case OSDP's operator splitting targets.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family=DENSE,
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    act="swiglu",
    rope="rope",
    rope_theta=500_000.0,
    source="[arXiv:2407.21783]",
)
