"""OSDP core: the paper's contribution as a composable JAX module."""
from repro.core.api import (  # noqa: F401
    dp_baseline, evaluate_plan, fsdp_baseline, osdp, search_hybrid)
from repro.core.cost_model import (  # noqa: F401
    DP, ZDP, ZDP_POD, CostEnv, Decision, OpCost, PlanCost, PlanEvaluator,
    op_cost, plan_cost, uniform_plan, zdp_extra_time, zdp_saving)
from repro.core.descriptions import (  # noqa: F401
    ModelDescription, OperatorDesc, describe, sanity_check)
from repro.core.hybrid import (  # noqa: F401
    Factorization, HybridPlan, factorizations, hybrid_step_time,
    pp_bubble_fraction, slice_description, stage_bounds,
    tp_activation_time)
from repro.core.ilp import (  # noqa: F401
    HAVE_SCIPY_MILP, ILPSolve, solve_ilp)
from repro.core.operator_split import chunked_ffn, chunked_matmul  # noqa: F401
from repro.core.plan import Plan, make_plan  # noqa: F401
from repro.core.search import (  # noqa: F401
    SearchResult, schedule, search_plan)
