"""OSDP Search Engine + Scheduler (paper Algorithm 1).

Four solvers over the same problem
    min_p  T(p, b)   s.t.  M(p, b) <= M_limit,  p_i in {DP, ZDP[, ZDP_POD]}

With `OSDPConfig(checkpointing="selective")` the per-slice decision
space widens to the 4-mode axis {DP, ZDP[, ZDP_POD]} x {remat,
no-remat}: the base plan is all-DP-no-remat and every item offers
remat'd variants of each sharding mode (plus remat-only), whose
activation savings and recompute costs are batch-linear — the solvers
stay unchanged, they just see more choices per item, materialized per
batch candidate.  `checkpointing=True/False` keep the legacy global
behaviour byte-for-byte.

  * ``dfs``      — the paper's depth-first search with its two pruning
                   rules (memory-exceeded, worse-than-incumbent), made
                   exact-and-fast with branch-and-bound lower bounds,
                   best-ratio branch ordering, and *group collapsing*:
                   per-layer descriptions expose hundreds of slices with
                   identical (saving, cost) signatures, and the search
                   branches on how many of each signature to shard
                   instead of which — same optimum, exponentially fewer
                   nodes. Paper-faithful semantics: returns the same
                   argmin as brute force.
  * ``knapsack`` — beyond-paper exact solver: choosing ZDP for op i
                   saves dM_i memory and costs dT_i time, so the problem
                   is a 0/1 knapsack-cover; solved by a vectorized
                   (numpy row-wise) DP over discretized memory savings
                   with a compact int8 parent encoding. O(n * M/Q) cell
                   relaxations with quantum Q.
  * ``greedy``   — dT/dM ratio heuristic, O(n log n); near-optimal when
                   savings are small relative to the gap (used to seed
                   the DFS incumbent).
  * ``ilp``      — the explicit integer-linear-program oracle
                   (``core.ilp``): scipy's HiGHS MILP when available, a
                   dependency-free Lagrangian-bound branch-and-bound
                   otherwise.  Exact by construction rather than by
                   search engineering — the reference the other three
                   are audited against (``benchmarks/solver_audit.py``)
                   — and *anytime* under ``OSDPConfig.ilp_time_budget_s``
                   (incumbent + proven ``SearchResult.lower_bound``).

Plan evaluation around the solvers goes through
``cost_model.PlanEvaluator``: per-op/per-mode cost tables are built once
per (description, env), full evaluations are vectorized, and the repair
loop's one-slice flips are O(1) delta updates instead of full
``plan_cost`` re-walks (the pre-optimization path was
O(slices^2 * ops) when repair triggered).

The Scheduler sweeps the batch size b upward until even the
all-ZDP+split plan exceeds the limit, keeping the throughput-argmax
(Algorithm 1 lines 3–18, 20); items and tables are shared across the
whole sweep because only the batch-linear activation/compute terms
change between candidates.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import (DeviceInfo, MeshConfig, ModelConfig,
                                OSDPConfig, ShapeConfig)
from repro.cluster.topology import ClusterSpec
from repro.core.cost_model import (DP, MODES, REMAT_INHERIT, REMAT_OFF,
                                   REMAT_ON, ZDP, ZDP_POD, CostEnv,
                                   Decision, MixServingCost, PlanCost,
                                   PlanEvaluator, RequestClass,
                                   RequestClassMix, ServingCost,
                                   ServingWorkload, WorkloadLike,
                                   plan_cost, remat_act_saving_slope,
                                   remat_compute_slope, remat_gather_time,
                                   inference_act_bytes, serving_mix_cost,
                                   serving_plan_cost, uniform_plan,
                                   zdp_extra_time, zdp_saving)
from repro.core.descriptions import ModelDescription, OperatorDesc, describe
from repro.core.ilp import solve_ilp
from repro.core.hybrid import (Factorization, HybridPlan, factorizations,
                               hybrid_step_time, pp_boundary_time,
                               slice_description, stage_bounds,
                               tp_activation_time)


# selective remat widens each item's choice set from sharding modes to
# (sharding x remat) pairs, keyed "ZDP" / "ZDP+R" / "DP+R" / ...; the
# "+R" choices rematerialize the slice (keep 1/remat_layers of its
# activations, pay the ~30% recompute and — sharded — the 4th gather).
REMAT_KEY = "+R"


def _key(mode: str, remat: bool) -> str:
    return mode + REMAT_KEY if remat else mode


def _parse_key(key: str) -> Tuple[str, bool]:
    if key.endswith(REMAT_KEY):
        return key[:-len(REMAT_KEY)], True
    return key, False


@dataclass
class SliceItem:
    """One decidable unit: an operator slice (whole op if unsplit).

    `savings` / `extra_time` are the batch-independent parts; the
    `_slope` dicts (selective remat only) hold the batch-linear parts
    per unit of per-device batch — activation bytes saved and recompute
    seconds added scale with b.  `_SearchContext.solve` materializes
    concrete per-batch items before handing them to the solvers, so the
    solvers themselves stay batch-agnostic.
    """

    op_name: str
    slice_idx: int
    n_slices: int
    savings: Dict[str, float]      # choice -> steady bytes saved vs base
    extra_time: Dict[str, float]   # choice -> seconds added vs base
    savings_slope: Dict[str, float] = field(default_factory=dict)
    extra_time_slope: Dict[str, float] = field(default_factory=dict)


@dataclass
class SearchResult:
    decisions: Dict[str, Decision]
    cost: PlanCost
    batch_size: int
    feasible: bool
    solver: str
    search_seconds: float
    # solver effort, one unified integer per backend (each is the
    # backend's natural unit of work, monotone in the solver's budget
    # for one fixed instance — pinned by tests/test_ilp.py):
    #   dfs      — branch-and-bound nodes expanded (0 when the root
    #              capacity prune proves the need uncoverable)
    #   knapsack — DP cells relaxed (0 when round-down quantization
    #              proves the quantized need uncoverable and the solve
    #              short-circuits to the max-saving fallback)
    #   greedy   — items ranked (= number of items, always)
    #   ilp      — integer variables + branch-and-bound nodes (HiGHS
    #              mip_node_count for the milp backend, best-first pops
    #              for the pure-Python bnb; >= 1 always — trivial and
    #              uncoverable instances still report model size)
    nodes_visited: int = 0
    candidates: List[Tuple[int, float]] = field(default_factory=list)
    # (batch, throughput) per Scheduler iteration — Algorithm 1's P set
    # --- ilp-only optimality certificate (None for other solvers) ----------
    # proven lower bound on the cover objective of the winning solve,
    # and whether the incumbent closed the gap (False = anytime mode
    # returned early); solver_backend records which ilp engine ran
    lower_bound: Optional[float] = None
    proven_optimal: Optional[bool] = None
    solver_backend: str = ""


def auto_granularity(op, env: CostEnv, osdp: OSDPConfig,
                     candidates=(1, 2, 4, 8, 16)) -> int:
    """Per-operator slice granularity (beyond paper — §4.3 names this
    as open future work).

    Larger g shrinks the transiently-gathered slice (M_extra/g) but
    adds (g-1) extra collective-latency terms. Pick the g minimizing
        alpha_cost(g) + shadow_price * gathered(g)
    where the shadow price converts bytes to seconds at the ring rate
    of this op's own gather (the marginal cost of covering the same
    bytes by sharding some other operator instead)."""
    if not (osdp.operator_splitting and op.splittable):
        return 1
    topo = env.topo
    rounds = (3 + (1 if env.checkpointing else 0)) if env.train else 1
    gathered_full = op.param_bytes / env.n_tp / max(1, op.layers)
    # seconds per byte of memory covered by sharding elsewhere, at the
    # full-span hierarchical ring rate (= the flat bottleneck ring on a
    # depth-2 single-pod adapter)
    ga, gb = topo.gather_terms(topo.depth)
    shadow = rounds * gb

    def total(g: int) -> float:
        alpha_cost = rounds * ga * (g - 1)
        return alpha_cost + shadow * gathered_full / g

    return min(candidates, key=total)


def _build_items(desc: ModelDescription, env: CostEnv,
                 osdp: OSDPConfig) -> List[SliceItem]:
    modes = [ZDP]
    if osdp.allow_pod_hierarchical:
        # level-k ZDP: one extra choice per intermediate hierarchy
        # level whose span is a real subdivision (depth-2 adapters
        # expose the legacy ZDP_POD exactly when the mesh is multi-pod)
        topo = env.topo
        modes += [topo.span_mode(k) for k in topo.shard_levels]
    selective = osdp.selective_remat
    seq = desc.shape.seq_len
    items: List[SliceItem] = []
    for op in desc.decidable():
        if osdp.auto_granularity:
            g = auto_granularity(op, env, osdp)
        else:
            g = (osdp.default_slice_granularity
                 if (osdp.operator_splitting and op.splittable) else 1)
        sav = {m: zdp_saving(op, env, m, g) / g for m in modes}
        ext = {m: zdp_extra_time(op, env, m) / g for m in modes}
        if not selective:
            for j in range(g):
                items.append(SliceItem(op.name, j, g, sav, ext))
            continue
        # 4-mode axis: every sharding choice with and without remat,
        # plus remat-only (stay DP) when it can actually save memory.
        # The base (no choice) is (DP, no-remat).
        act_slope = remat_act_saving_slope(op, env, seq, g)
        comp_slope = remat_compute_slope(op, env, seq, g)
        sav_slope: Dict[str, float] = {}
        ext_slope: Dict[str, float] = {}
        if act_slope > 0:
            for m in modes:
                rk = _key(m, True)
                sav[rk] = sav[m]
                ext[rk] = ext[m] + remat_gather_time(op, env, m, g)
                sav_slope[rk] = act_slope
                ext_slope[rk] = comp_slope
            rdp = _key(DP, True)
            sav[rdp] = 0.0
            ext[rdp] = 0.0
            sav_slope[rdp] = act_slope
            ext_slope[rdp] = comp_slope
        for j in range(g):
            items.append(SliceItem(op.name, j, g, sav, ext,
                                   sav_slope, ext_slope))
    if selective:
        # remat is orthogonal to sharding: operators pinned to DP
        # (decidable=False) still choose remat/no-remat — without this
        # a selective plan could not reach the global-remat memory
        # floor (e.g. mamba2's conv/gate group holds real activations)
        for op in desc.operators:
            if op.decidable:
                continue
            act_slope = remat_act_saving_slope(op, env, seq, 1)
            if act_slope <= 0:
                continue
            rdp = _key(DP, True)
            items.append(SliceItem(
                op.name, 0, 1, {rdp: 0.0}, {rdp: 0.0},
                {rdp: act_slope},
                {rdp: remat_compute_slope(op, env, seq, 1)}))
    return items


def _materialize_items(items: List[SliceItem], bpd: int) -> List[SliceItem]:
    """Fold the batch-linear slopes into concrete per-batch items."""
    out: List[SliceItem] = []
    for it in items:
        if not it.savings_slope and not it.extra_time_slope:
            out.append(it)
            continue
        sav = {m: v + bpd * it.savings_slope.get(m, 0.0)
               for m, v in it.savings.items()}
        ext = {m: v + bpd * it.extra_time_slope.get(m, 0.0)
               for m, v in it.extra_time.items()}
        out.append(SliceItem(it.op_name, it.slice_idx, it.n_slices,
                             sav, ext))
    return out


def _items_to_decisions(desc: ModelDescription, items: List[SliceItem],
                        choice: List[Optional[str]]
                        ) -> Dict[str, Decision]:
    """Legacy (2-mode) choices -> decisions; the production path emits
    decisions through PlanEvaluator.decisions() instead (which also
    carries the remat axis) — this helper remains the reference shape
    used by the golden tests."""
    per_op: Dict[str, List[str]] = {}
    for it, c in zip(items, choice):
        per_op.setdefault(it.op_name, [DP] * it.n_slices)
        if c is not None:
            per_op[it.op_name][it.slice_idx] = _parse_key(c)[0]
    out: Dict[str, Decision] = {}
    for op in desc.operators:
        if op.name in per_op:
            out[op.name] = Decision(op.name, tuple(per_op[op.name]))
        else:
            out[op.name] = Decision(op.name, (DP,))
    return out


def _best_mode(it: SliceItem) -> str:
    """Cheapest dT/dM mode for one item (the repair/branch order key)."""
    return min(it.savings, key=lambda m: it.extra_time[m]
               / max(it.savings[m], 1e-9))


def _best_ratio(it: SliceItem) -> float:
    return min(it.extra_time[m] / max(it.savings[m], 1e-9)
               for m in it.savings)


# ---------------------------------------------------------------------------
# Solver 1: the paper's DFS (branch and bound over signature groups, exact)
# ---------------------------------------------------------------------------

def _solve_dfs(items: List[SliceItem], need: float,
               node_budget: int = 2_000_000) -> Tuple[List[Optional[str]], int]:
    """Minimize sum extra_time s.t. sum savings >= need.

    Paper Algorithm 1 lines 5–11: traverse the plan space depth-first,
    pruning on (a) memory infeasibility and (b) incumbent time bound.
    Items with identical (savings, extra_time) signatures — all slices
    of one stacked operator, and every per-layer copy of the same
    operator — are interchangeable, so the search branches on *how
    many* of each signature group to shard per mode (a prefix of the
    group, WLOG) rather than on each slice: the optimum is unchanged
    and the tree shrinks from 2^n to a product over distinct
    signatures. Within the remaining tree the classic bounds apply:
    best-ratio level ordering, an admissible remaining-time bound
    (remaining need x best remaining ratio), and a capacity bound
    (even sharding everything left cannot cover the need).
    """
    n = len(items)
    if need <= 0:
        return [None] * n, 1

    # greedy incumbent (also the fallback when the need is uncoverable,
    # matching the pre-grouping implementation)
    inc_choice, inc_time = _solve_greedy(items, need)

    # group by exact cost signature
    sig_groups: Dict[tuple, List[int]] = {}
    for i, it in enumerate(items):
        sig = (tuple(sorted(it.savings.items())),
               tuple(sorted(it.extra_time.items())))
        sig_groups.setdefault(sig, []).append(i)
    glist = sorted(
        ([idxs, items[idxs[0]]] for idxs in sig_groups.values()),
        key=lambda g: _best_ratio(g[1]))

    # levels: one per (group, mode), contiguous per group, cheapest
    # ratio first within the group
    levels: List[Tuple[int, str, float, float, int, bool]] = []
    for gi, (idxs, it) in enumerate(glist):
        ms = sorted(it.savings, key=lambda m: it.extra_time[m]
                    / max(it.savings[m], 1e-9))
        for mj, m in enumerate(ms):
            levels.append((gi, m, it.savings[m], it.extra_time[m],
                           len(idxs), mj == 0))
    L = len(levels)

    # bounds: max savings still reachable from a level (per-item, within
    # the level's group) and over all later groups; best ratio suffix
    inner_max = [0.0] * L
    group_best = {}
    for li in range(L - 1, -1, -1):
        gi, m, sav, ext, k, is_first = levels[li]
        group_best[gi] = max(group_best.get(gi, 0.0), sav)
        inner_max[li] = group_best[gi]
    suffix_group_sav = [0.0] * (len(glist) + 1)
    for gi in range(len(glist) - 1, -1, -1):
        idxs, it = glist[gi]
        suffix_group_sav[gi] = (suffix_group_sav[gi + 1]
                                + len(idxs) * max(it.savings.values()))
    suffix_ratio = [float("inf")] * (L + 1)
    for li in range(L - 1, -1, -1):
        gi, m, sav, ext, k, is_first = levels[li]
        suffix_ratio[li] = min(suffix_ratio[li + 1],
                               ext / max(sav, 1e-9))

    # fractional (LP) suffix bound: cheapest fractional cover of the
    # remaining need by levels >= li, each level capped at its full
    # group capacity (relaxes mode exclusivity and group sharing —
    # admissible).  Far stronger than need x best-remaining-ratio when
    # the cheap levels have small capacity, which is exactly what blows
    # up the 4-mode (selective remat) tree: 5 incomparable modes per
    # signature would otherwise branch near-unpruned.
    frac_tables: List[Tuple[List[float], List[float], List[float]]] = []
    for li in range(L + 1):
        lvls = sorted((ext / max(sav, 1e-9), k * sav)
                      for _, _, sav, ext, k, _ in levels[li:] if sav > 0)
        cum_s, cum_c = [0.0], [0.0]
        for r, cap in lvls:
            cum_s.append(cum_s[-1] + cap)
            cum_c.append(cum_c[-1] + r * cap)
        frac_tables.append((cum_s, cum_c, [r for r, _ in lvls]))

    def frac_bound(li: int, need_rem: float) -> float:
        if need_rem <= 0:
            return 0.0
        cum_s, cum_c, ratios = frac_tables[li]
        if need_rem > cum_s[-1]:
            return float("inf")
        j = bisect.bisect_left(cum_s, need_rem)
        return cum_c[j - 1] + (need_rem - cum_s[j - 1]) * ratios[j - 1]

    best_time = inc_time
    best_counts: Optional[List[int]] = None
    counts = [0] * L
    nodes = 0

    def c_max_at(li: int, rem: int, saved: float) -> int:
        gi, m, sav, ext, k, is_first = levels[li]
        if sav > 0:
            c_cover = math.ceil((need - saved) / sav)
            # sharding beyond coverage is dominated when it costs time
            return min(rem, c_cover) if ext > 0 else rem
        return rem if ext <= 0 else 0

    # iterative DFS: frames of (level, remaining group capacity on
    # entry, saved, t, next-branch index); branch bi maps to taking
    # c = c_max - bi slices at this level (greedy-like: most first)
    stack: List[Tuple[int, int, float, float, int]] = [(0, 0, 0.0, 0.0, 0)]
    while stack:
        li, rem, saved, t, bi = stack.pop()
        if bi == 0:
            if saved >= need:
                if t < best_time:
                    best_time = t
                    best_counts = counts[:li] + [0] * (L - li)
                continue
            if li == L:
                continue
            if levels[li][5]:                 # first level of its group
                rem = levels[li][4]
            gi = levels[li][0]
            # prune: even sharding everything left cannot cover the need
            if (saved + rem * inner_max[li]
                    + suffix_group_sav[gi + 1] < need):
                continue
            # prune: admissible lower bound on remaining time (cheap
            # best-ratio test first, then the fractional-cover bound)
            if (t + (need - saved) * suffix_ratio[li] >= best_time
                    or t + frac_bound(li, need - saved) >= best_time):
                continue
            nodes += 1
            if nodes > node_budget:
                break
        # re-check the bound when revisiting (incumbent may have improved)
        elif (t + (need - saved) * suffix_ratio[li] >= best_time
              or t + frac_bound(li, need - saved) >= best_time):
            counts[li] = 0
            continue
        c = c_max_at(li, rem, saved) - bi
        if c < 0:                             # branches exhausted
            counts[li] = 0
            continue
        _, m, sav, ext, k, _ = levels[li]
        counts[li] = c
        stack.append((li, rem, saved, t, bi + 1))   # resume point
        stack.append((li + 1, rem - c, saved + c * sav, t + c * ext, 0))

    if best_counts is None:
        return list(inc_choice), nodes
    choice: List[Optional[str]] = [None] * n
    ptr = {gi: 0 for gi in range(len(glist))}
    for li, c in enumerate(best_counts):
        gi, m, sav, ext, k, is_first = levels[li]
        idxs = glist[gi][0]
        for _ in range(c):
            choice[idxs[ptr[gi]]] = m
            ptr[gi] += 1
    return choice, nodes


# ---------------------------------------------------------------------------
# Solver 2: exact knapsack-cover DP (beyond paper), vectorized
# ---------------------------------------------------------------------------

def _solve_knapsack(items: List[SliceItem], need: float,
                    quantum: float = 16 * 2**20
                    ) -> Tuple[List[Optional[str]], int]:
    """DP over discretized memory saving. Savings are rounded DOWN (so a
    'covered' answer is truly feasible); `need` is rounded up.

    The relaxation is row-vectorized with numpy: one strided
    minimum-update per (item, mode) instead of a Python loop over every
    cell, and the n x cap parent table is an int8 mode index (plus one
    int per item for the saturated top cell) instead of a list of
    (state, mode) tuples. Returns (choice, cells_relaxed).
    """
    n = len(items)
    if need <= 0:
        return [None] * n, 0
    cap = int(-(-need // quantum))          # ceil
    mode_lists = [list(it.savings) for it in items]
    q_best = [max((int(sav // quantum) for sav in it.savings.values()),
                  default=0) for it in items]
    if sum(q_best) < cap:
        # uncoverable even at full sharding (the saturating DP could
        # never reach the cap cell): same fallback, without the table
        return [max(it.savings, key=it.savings.get) for it in items], 0

    INF = float("inf")
    dp = np.full(cap + 1, INF)
    dp[0] = 0.0
    pmode = np.full((n, cap + 1), -1, dtype=np.int8)
    pcap = np.full(n, -1, dtype=np.int64)   # source state for cap updates
    cells = 0
    for i, it in enumerate(items):
        ndp = dp.copy()
        row = pmode[i]
        for mi, m in enumerate(mode_lists[i]):
            q = int(it.savings[m] // quantum)
            if q == 0:
                continue
            t = it.extra_time[m]
            cells += int(np.isfinite(dp).sum())
            if q <= cap and cap - q >= 1:
                # exact targets: state s -> s + q for s in [0, cap-q)
                cand = dp[:cap - q] + t
                tgt = ndp[q:cap]
                imp = cand < tgt
                if imp.any():
                    tgt[imp] = cand[imp]
                    row[q:cap][imp] = mi
            # states [max(0, cap-q), cap] all saturate into the cap
            # cell; the winner is the first minimum (strict-improvement
            # sweep order of the scalar implementation)
            lo = max(0, cap - q)
            window = dp[lo:]
            j = int(np.argmin(window))
            v = window[j] + t
            if v < ndp[cap]:
                ndp[cap] = v
                row[cap] = mi
                pcap[i] = lo + j
        dp = ndp
    if not np.isfinite(dp[cap]):
        return [max(it.savings, key=it.savings.get) for it in items], cells
    # backtrack
    choice: List[Optional[str]] = [None] * n
    s = cap
    for i in range(n - 1, -1, -1):
        mi = int(pmode[i, s])
        if mi < 0:
            continue
        m = mode_lists[i][mi]
        choice[i] = m
        if s == cap:
            s = int(pcap[i])
        else:
            s -= int(items[i].savings[m] // quantum)
    return choice, cells


# ---------------------------------------------------------------------------
# Solver 3: greedy ratio heuristic
# ---------------------------------------------------------------------------

def _solve_greedy(items: List[SliceItem],
                  need: float) -> Tuple[List[Optional[str]], float]:
    n = len(items)
    choice: List[Optional[str]] = [None] * n
    if need <= 0:
        return choice, 0.0
    ranked = []
    for i, it in enumerate(items):
        m = _best_mode(it)
        ranked.append((it.extra_time[m] / max(it.savings[m], 1e-9), i, m))
    ranked.sort()
    saved = t = 0.0
    for _, i, m in ranked:
        if saved >= need:
            break
        choice[i] = m
        saved += items[i].savings[m]
        t += items[i].extra_time[m]
    return choice, (t if saved >= need else float("inf"))


# ---------------------------------------------------------------------------
# Search Engine: reusable context + fixed-b solve
# ---------------------------------------------------------------------------

class _SearchContext:
    """Everything batch-independent about one search problem.

    Items (per-slice savings / extra-time) and the PlanEvaluator tables
    depend only on (description, env, osdp); the Scheduler's batch sweep
    and search_hybrid's factorization sweep re-use one context instead
    of rebuilding them per candidate — only the batch-linear activation
    and compute terms change between solves.
    """

    def __init__(self, desc: ModelDescription, env: CostEnv,
                 osdp: OSDPConfig):
        self.desc = desc
        self.env = env
        self.osdp = osdp
        self.selective = osdp.selective_remat
        if self.selective and env.checkpointing:
            raise ValueError(
                "selective remat expects CostEnv(checkpointing=False): "
                "the search's base plan keeps activations and turns "
                "remat on per slice")
        self.items = _build_items(desc, env, osdp)
        self._has_slopes = any(it.savings_slope or it.extra_time_slope
                               for it in self.items)
        gran = {it.op_name: it.n_slices for it in self.items}
        self.ev = PlanEvaluator(desc, env, gran)
        op_index = {name: k for k, name in enumerate(self.ev.op_names)}
        self.item_slice = np.array(
            [int(self.ev.op_start[op_index[it.op_name]]) + it.slice_idx
             for it in self.items], dtype=np.int64)
        self.mode_idx = self.ev.mode_index
        # per-group memory limits: uniform clusters use the config's
        # limit; heterogeneous clusters bind at the worst group (its
        # hbm_bytes is its budget — see ClusterSpec.memory_limit)
        self.limit = env.topo.memory_limit(osdp.memory_limit_bytes)
        # hierarchical topologies get the stronger upgrade repair (the
        # solver's level-k mixes overshoot the item model's savings
        # more often); flat envs — including the flat single-level
        # residues search_hybrid builds on the legacy no-cluster path —
        # keep the legacy repair semantics bit-for-bit
        # (BENCH_search.json decisions are pinned on them).  A topology
        # is "hierarchical" exactly when it offers level-k items.
        self._upgrade_repair = (env.cluster is not None
                                and bool(env.topo.shard_levels))

    def _mirror_items(self, remat_on: bool) -> Tuple[List[SliceItem],
                                                     np.ndarray]:
        """Legacy 2-mode items for a uniform-remat mirror problem
        (lazily built and cached), plus their evaluator slice map."""
        attr = "_mirror_on" if remat_on else "_mirror_off"
        cached = getattr(self, attr, None)
        if cached is not None:
            return cached
        env = dataclasses.replace(self.env, checkpointing=remat_on)
        osdp = dataclasses.replace(self.osdp, checkpointing=remat_on)
        items = _build_items(self.desc, env, osdp)
        op_index = {name: k for k, name in enumerate(self.ev.op_names)}
        item_slice = np.array(
            [int(self.ev.op_start[op_index[it.op_name]]) + it.slice_idx
             for it in items], dtype=np.int64)
        if any(int(it.n_slices) != int(
                self.ev.granularity[op_index[it.op_name]])
                for it in items):
            raise ValueError("mirror granularity mismatch")
        setattr(self, attr, (items, item_slice))
        return items, item_slice

    def _ext_index(self, choice_key: str, state_map) -> int:
        """Extended evaluator column for one item choice key."""
        m, r = _parse_key(choice_key)
        return self.mode_idx[m] + self.ev.n_modes * state_map(r)

    def _solve_once(self, global_batch: int, items: List[SliceItem],
                    item_slice: np.ndarray, base_modes: np.ndarray,
                    need: float, state_map, solver: str,
                    node_budget: int,
                    quantum: Optional[float] = None) -> SearchResult:
        """One covering solve + repair on a prepared problem.

        `base_modes` is the extended-mode array the choices overlay;
        `state_map` maps each choice key's remat flag to the evaluator
        remat state (inherit for legacy runs, explicit off/on for
        selective and the uniform mirrors).
        """
        limit = self.limit
        ilp = None
        if solver == "dfs":
            choice, nodes = _solve_dfs(items, need, node_budget)
        elif solver == "knapsack":
            choice, nodes = (_solve_knapsack(items, need, quantum)
                             if quantum else _solve_knapsack(items, need))
        elif solver == "greedy":
            choice, _ = _solve_greedy(items, need)
            nodes = len(items)
        elif solver == "ilp":
            ilp = solve_ilp(items, need,
                            time_budget=self.osdp.ilp_time_budget_s,
                            backend=self.osdp.ilp_backend,
                            node_budget=node_budget)
            choice, nodes = list(ilp.choice), ilp.nodes
        else:
            raise ValueError(f"unknown solver {solver!r}")

        def modes_of(ch):
            modes = base_modes.copy()
            for i, c in enumerate(ch):
                if c is not None:
                    modes[item_slice[i]] = self._ext_index(c, state_map)
            return modes

        ev = self.ev
        ev.begin(modes_of(choice), global_batch)

        # Repair: per-slice savings are exact for uniform runs but
        # slightly optimistic for mixed ones (each ZDP run re-gathers a
        # slice), so the Profiler's evaluation can come out a hair over
        # the limit. Flip the cheapest remaining base slices until the
        # evaluation fits — each flip is an O(1) evaluator delta, and
        # under selective remat it may flip remat independently of
        # sharding (whatever the item's cheapest remaining choice is).
        if ev.memory > limit:
            remaining = sorted(
                (i for i, c in enumerate(choice) if c is None),
                key=lambda i: _best_ratio(items[i]))
            for i in remaining:
                m = _best_mode(items[i])
                choice[i] = m
                ev.flip(int(item_slice[i]), self._ext_index(m, state_map))
                if ev.memory <= limit:
                    break
            if ev.memory > limit and self._upgrade_repair:
                # upgrade already-chosen slices toward their max-saving
                # mode, cheapest marginal dT/dM first (each flip exact
                # through the evaluator) — on hierarchical topologies
                # the solver's cover often mixes level-k modes whose
                # per-run re-gathers the item model cannot see, and
                # escalating straight to the all-max plan would throw
                # the whole mix away
                upgrades = []
                for i, c in enumerate(choice):
                    it = items[i]
                    best = max(it.savings, key=it.savings.get)
                    if c == best:
                        continue
                    dsav = it.savings[best] - (it.savings[c] if c else 0.0)
                    if dsav <= 0:
                        continue
                    dt = (it.extra_time[best]
                          - (it.extra_time[c] if c else 0.0))
                    upgrades.append((dt / dsav, i, best))
                upgrades.sort()
                for _, i, best in upgrades:
                    choice[i] = best
                    ev.flip(int(item_slice[i]),
                            self._ext_index(best, state_map))
                    if ev.memory <= limit:
                        break
            if ev.memory > limit:
                # escalate every slice to its max-saving mode (ZDP,
                # remat'd under selective) — the most-sharded plan is
                # the feasibility frontier
                choice = [max(it.savings, key=it.savings.get)
                          for it in items]
                ev.begin(modes_of(choice), global_batch)

        cost = ev.result()
        decisions = ev.decisions(ev.current_modes)
        res = SearchResult(decisions, cost, global_batch,
                           bool(cost.memory <= limit), self.osdp.search,
                           0.0, nodes)
        if ilp is not None:
            res.lower_bound = ilp.lower_bound
            res.proven_optimal = ilp.optimal
            res.solver_backend = ilp.backend
        return res

    def solve(self, global_batch: int) -> SearchResult:
        t0 = _time.perf_counter()
        osdp = self.osdp
        limit = self.limit
        bpd = self.ev._bpd(global_batch)
        n_m = self.ev.n_modes

        if not self.selective:
            base = np.zeros(self.ev.n_slices, dtype=np.int8)
            need = self.ev.all_dp_memory(global_batch) - limit
            res = self._solve_once(
                global_batch, self.items, self.item_slice, base, need,
                lambda r: REMAT_INHERIT, osdp.search, 2_000_000)
            res.search_seconds = _time.perf_counter() - t0
            return res

        # Selective remat solves three covering problems and keeps the
        # best: the full 4-mode search (bounded B&B effort — near the
        # feasibility frontier the 5-choice tree is genuinely hard),
        # plus the two uniform-remat mirrors (cheap legacy 2-mode
        # problems evaluated on the explicit columns).  The mirrors
        # guarantee the selective plan never loses to either global
        # checkpointing setting, whatever the solver budget did.
        base_off = np.zeros(self.ev.n_slices, dtype=np.int8)
        base_off[self.item_slice] = n_m * REMAT_OFF
        need_off = self.ev.all_dp_memory(global_batch, False) - limit
        items = (_materialize_items(self.items, bpd)
                 if self._has_slopes else self.items)
        # per-slice remat savings can be far below the legacy 16 MiB
        # knapsack quantum (one slice of one layer's activations), and
        # each item loses up to one quantum to round-down — so the
        # 4-mode knapsack sizes its grid from the coverage headroom:
        # n/2 expected quanta of loss must fit inside it, else a
        # coverable need quantizes to "uncoverable"
        quantum = None
        if need_off > 0 and items:
            headroom = (sum(max(it.savings.values()) for it in items)
                        - need_off)
            quantum = min(16 * 2.0**20,
                          max(2.0**16, headroom / len(items),
                              need_off / 65536))
        best = self._solve_once(
            global_batch, items, self.item_slice, base_off, need_off,
            lambda r: REMAT_ON if r else REMAT_OFF, osdp.search, 10_000,
            quantum)
        nodes = best.nodes_visited

        mirrors = [(False, base_off, need_off)]
        base_on = base_off.copy()
        base_on[self.item_slice] = n_m * REMAT_ON
        mirrors.append((True, base_on,
                        self.ev.all_dp_memory(global_batch, True) - limit))
        for remat_on, base, need in mirrors:
            m_items, m_slice = self._mirror_items(remat_on)
            st = REMAT_ON if remat_on else REMAT_OFF
            res = self._solve_once(
                global_batch, m_items, m_slice, base, need,
                lambda r, st=st: st, osdp.search, 2_000_000)
            nodes += res.nodes_visited
            if (res.feasible and
                    (not best.feasible
                     or res.cost.throughput > best.cost.throughput)):
                best = res
        best.nodes_visited = nodes
        best.search_seconds = _time.perf_counter() - t0
        return best


def search_plan(desc: ModelDescription, global_batch: int, env: CostEnv,
                osdp: OSDPConfig) -> SearchResult:
    if osdp.force_mode:
        t0 = _time.perf_counter()
        dec = uniform_plan(
            desc, osdp.force_mode,
            osdp.default_slice_granularity if osdp.operator_splitting else 1)
        cost = plan_cost(desc, dec, global_batch, env)
        # feasibility is judged on steady memory, same as the searched
        # path below (transient peaks stay visible in cost.peak_memory)
        limit = env.topo.memory_limit(osdp.memory_limit_bytes)
        return SearchResult(dec, cost, global_batch,
                            cost.memory <= limit,
                            f"forced:{osdp.force_mode}",
                            _time.perf_counter() - t0)
    return _SearchContext(desc, env, osdp).solve(global_batch)


# ---------------------------------------------------------------------------
# Scheduler: batch-size sweep (Algorithm 1 outer loop)
# ---------------------------------------------------------------------------

def schedule(desc: ModelDescription, env: CostEnv, osdp: OSDPConfig,
             batch_candidates: Optional[Sequence[int]] = None,
             max_batch: int = 4096) -> SearchResult:
    t0 = _time.perf_counter()
    best: Optional[SearchResult] = None
    first: Optional[SearchResult] = None
    cands: List[Tuple[int, float]] = []
    batches = (list(batch_candidates) if batch_candidates is not None
               else _default_batches(max_batch, env))
    if not batches:
        raise ValueError("empty batch_candidates")
    ctx = None if osdp.force_mode else _SearchContext(desc, env, osdp)
    for b in batches:
        res = ctx.solve(b) if ctx is not None \
            else search_plan(desc, b, env, osdp)
        if first is None:
            first = res
        if not res.feasible:
            # Algorithm 1 line 12–14: all plans exceed the limit -> stop
            if best is not None:
                break
            continue
        cands.append((b, res.cost.throughput))
        if best is None or res.cost.throughput > best.cost.throughput:
            best = res
    if best is None:
        # nothing fits even fully sharded: the first candidate's result
        # is already the most-sharded plan — reuse it instead of paying
        # a duplicate solve
        best = first
    best.candidates = cands
    best.search_seconds = _time.perf_counter() - t0
    return best


def _default_batches(max_batch: int, env: CostEnv) -> List[int]:
    # per-device microbatch 1,2,3,... like Algorithm 1's b in {1,2,3,...}
    n = env.n_data
    out = []
    b = n
    while b <= max_batch:
        out.append(b)
        b += n
    return out or [n]


# ---------------------------------------------------------------------------
# Serving Scheduler: sharding + concurrency under the KV-cache budget
# ---------------------------------------------------------------------------

@dataclass
class ServePlan:
    """A searched serving configuration: per-slice sharding decisions
    plus the KV-budget admission limit.

    `slots_per_device` is the throughput-argmax concurrency;
    `max_slots_per_device` is the largest concurrency that still fits
    the memory limit under the (same-search) plan — the continuous
    engine's admission limit.  `candidates` records every probed
    (slots, output tokens/s) pair, the serving analogue of Algorithm
    1's P set."""

    model_name: str
    workload: ServingWorkload
    decisions: Dict[str, Decision]
    cost: ServingCost
    slots_per_device: int
    max_slots_per_device: int
    max_concurrency: int
    feasible: bool
    solver: str
    search_seconds: float
    nodes_visited: int = 0
    candidates: List[Tuple[int, float]] = field(default_factory=list)
    inner: Optional[SearchResult] = None
    # fleet generalization: the mix the plan was searched for and its
    # exact per-class economics (None on the legacy single-workload
    # path — a single-class mix routes through that path byte-for-byte)
    mix: Optional[RequestClassMix] = None
    class_costs: Optional[Dict[str, ServingCost]] = None

    def summary(self) -> str:
        c = self.cost
        n_zdp = sum(1 for d in self.decisions.values()
                    if d.uniform() not in (DP, None))
        n_mixed = sum(1 for d in self.decisions.values()
                      if d.uniform() is None)
        return "\n".join([
            f"serve-plan[{self.model_name} p{self.workload.prompt_len}"
            f"+d{self.workload.decode_len}] ops={len(self.decisions)} "
            f"zdp={n_zdp} mixed={n_mixed} "
            f"({'feasible' if self.feasible else 'INFEASIBLE'})",
            f"  concurrency = {c.concurrency} in flight "
            f"({c.slots_per_device} slots/device, admission limit "
            f"{self.max_concurrency})",
            f"  est memory/device = {c.memory / 2**30:.2f} GiB "
            f"(weights {c.weight_memory / 2**30:.2f}, cache/seq "
            f"{c.cache_bytes_per_seq / 2**20:.1f} MiB)",
            f"  est ttft = {c.ttft * 1e3:.2f} ms, tpot = "
            f"{c.tpot * 1e3:.3f} ms, request latency = "
            f"{c.request_latency * 1e3:.1f} ms",
            f"  est throughput = {c.throughput:.0f} output tok/s",
        ])


def search_serve(model: ModelConfig, workload: WorkloadLike,
                 env: CostEnv, osdp: OSDPConfig, max_slots: int = 512,
                 slot_candidates: Optional[Sequence[int]] = None
                 ) -> ServePlan:
    """Search the serving plan space: per-slice sharding x concurrency.

    The inner problem at a fixed per-device concurrency `s` is exactly
    the training search with the KV budget folded into the limit — the
    caches of `s` admitted sequences are mode-independent, so the
    sharding cover problem runs against `M_limit - s * cache_seq` on
    the decode-shaped description (the phase whose step time the
    sharding actually taxes) and reuses the existing solvers and
    `PlanEvaluator` tables across the whole sweep.  Every probed plan
    is then re-scored with `serving_plan_cost` (both phases + HBM
    floors + the cache term), and the sweep keeps the throughput
    argmax plus the largest feasible concurrency (the admission
    limit).  Without explicit `slot_candidates` the sweep doubles
    until infeasible, then bisects the frontier.

    `workload` may also be a `RequestClassMix`: a single-class mix is
    an exact alias of its `ServingWorkload` (same path, byte-identical
    plan); a multi-class mix prices every probe per class through
    `serving_mix_cost` and keeps the aggregate-throughput argmax.
    """
    t0 = _time.perf_counter()
    if env.train:
        raise ValueError("search_serve needs a train=False CostEnv")
    if env.checkpointing:
        raise ValueError("serving env must not checkpoint "
                         "(CostEnv(checkpointing=False)): inference "
                         "keeps no activations to rematerialize")
    if osdp.selective_remat:
        raise ValueError("serving has no backward pass to rematerialize: "
                         "use checkpointing=False")
    mix: Optional[RequestClassMix] = None
    if isinstance(workload, RequestClassMix):
        mix = workload
        if len(mix) > 1:
            return _search_serve_mix(model, mix, env, osdp, max_slots,
                                     slot_candidates)
        workload = mix.classes[0].workload()
    pre_shape = ShapeConfig("serve_prefill", workload.prompt_len,
                            env.n_data, "prefill")
    dec_shape = ShapeConfig("serve_decode", 1, env.n_data, "decode")
    desc_pre = describe(model, pre_shape)
    desc_dec = describe(model, dec_shape)
    limit = env.topo.memory_limit(osdp.memory_limit_bytes)
    cache_seq = desc_dec.cache_bytes_per_seq(workload.cache_len, env.n_tp)

    ctx = None if osdp.force_mode else _SearchContext(desc_dec, env, osdp)
    base_limit = ctx.limit if ctx is not None else limit
    # the evaluator charges the training act term (every layer's
    # activations x batch); inference holds one layer + the residual
    # stream (`inference_act_bytes`), so the folded limit swaps one for
    # the other — per-slot slopes, both linear in the concurrency
    act_ev_slope = (desc_dec.resident_act_bytes_per_token
                    + sum(op.act_bytes_per_token
                          for op in desc_dec.operators)) / env.n_tp
    nodes = 0
    evals: Dict[int, Tuple[Dict[str, Decision], Optional[SearchResult],
                           ServingCost, bool]] = {}

    def probe(slots: int):
        nonlocal nodes
        if slots in evals:
            return evals[slots]
        if ctx is None:
            g = (osdp.default_slice_granularity
                 if osdp.operator_splitting else 1)
            decisions = uniform_plan(desc_dec, osdp.force_mode, g)
            res = None
        else:
            # fold the KV budget into the limit (caches are
            # mode-independent, so this is exact) and correct the
            # training-vs-inference activation gap
            act_inf = inference_act_bytes(desc_dec, env, slots, 1)
            ctx.limit = max(0.0, base_limit - slots * cache_seq
                            - act_inf + act_ev_slope * slots)
            res = ctx.solve(slots * env.n_data)
            decisions = res.decisions
            nodes += res.nodes_visited
        sc = serving_plan_cost(desc_pre, desc_dec, decisions, workload,
                               env, slots)
        ok = sc.memory <= limit
        evals[slots] = (decisions, res, sc, ok)
        return evals[slots]

    probed: List[int] = []
    if slot_candidates is not None:
        probed = sorted({max(1, int(s)) for s in slot_candidates})
        for s in probed:
            probe(s)
    else:
        s, last_ok, first_bad = 1, 0, None
        while s <= max_slots:
            probed.append(s)
            if probe(s)[3]:
                last_ok = s
            else:
                first_bad = s
                break
            s *= 2
        if first_bad is None and probed and probed[-1] != max_slots:
            probed.append(max_slots)
            if probe(max_slots)[3]:
                last_ok = max_slots
            else:
                first_bad = max_slots
        if first_bad is not None and last_ok:
            lo, hi = last_ok, first_bad
            while hi - lo > 1:          # bisect the admission frontier
                mid = (lo + hi) // 2
                probed.append(mid)
                if probe(mid)[3]:
                    lo = mid
                else:
                    hi = mid

    if ctx is not None:
        ctx.limit = base_limit
    feas = [s for s in evals if evals[s][3]]
    max_feas = max(feas) if feas else 0
    if feas:
        best_slots = max(feas, key=lambda s: evals[s][2].throughput)
        feasible = True
    else:
        best_slots = min(evals)     # most-sharded repair plan at slots=1
        feasible = False
    decisions, res, sc, _ = evals[best_slots]
    return ServePlan(
        model_name=model.name, workload=workload, decisions=decisions,
        cost=sc, slots_per_device=best_slots if feasible else 0,
        max_slots_per_device=max_feas,
        max_concurrency=max_feas * env.n_data,
        feasible=feasible,
        solver=(f"forced:{osdp.force_mode}" if osdp.force_mode
                else osdp.search),
        search_seconds=_time.perf_counter() - t0,
        nodes_visited=nodes,
        candidates=sorted((s, evals[s][2].throughput if evals[s][3]
                           else 0.0) for s in evals),
        inner=res,
        mix=mix,
        class_costs=({mix.classes[0].name: sc} if mix is not None
                     else None))


def _blend_mix_cost(mix: RequestClassMix,
                    mc: MixServingCost) -> ServingCost:
    """Aggregate display `ServingCost` for a mix plan: latency figures
    are arrival-rate weighted means, throughput/memory the aggregate /
    binding figures (exact per-class numbers live in
    `ServePlan.class_costs`)."""
    total = mix.total_rate
    w = {c.name: c.arrival_rate / total for c in mix.classes}

    def mean(attr):
        return sum(w[n] * getattr(sc, attr)
                   for n, sc in mc.per_class.items())

    return ServingCost(
        weight_memory=mc.weight_memory,
        cache_bytes_per_seq=mc.cache_bytes_per_slot,
        slots_per_device=mc.slots_per_device,
        concurrency=mc.concurrency,
        memory=mc.memory,
        prefill_time=mean("prefill_time"),
        decode_step_time=mc.decode_step_time,
        ttft=mean("ttft"),
        tpot=mc.decode_step_time,
        request_latency=mean("request_latency"),
        throughput=mc.throughput)


def _search_serve_mix(model: ModelConfig, mix: RequestClassMix,
                      env: CostEnv, osdp: OSDPConfig, max_slots: int,
                      slot_candidates: Optional[Sequence[int]]
                      ) -> ServePlan:
    """The multi-class body of `search_serve`: same sweep, but every
    probe folds the *expected* (slot-share weighted) cache bytes into
    the solver limit and is priced per class with `serving_mix_cost`;
    the argmax is the aggregate output-token throughput."""
    t0 = _time.perf_counter()
    dec_shape = ShapeConfig("serve_decode", 1, env.n_data, "decode")
    desc_dec = describe(model, dec_shape)
    desc_pres: Dict[int, ModelDescription] = {}
    for c in mix.classes:
        if c.prompt_len not in desc_pres:
            desc_pres[c.prompt_len] = describe(
                model, ShapeConfig("serve_prefill", c.prompt_len,
                                   env.n_data, "prefill"))
    limit = env.topo.memory_limit(osdp.memory_limit_bytes)
    cache_exp = sum(
        mix.slot_share(c)
        * desc_dec.cache_bytes_per_seq(c.cache_len, env.n_tp)
        for c in mix.classes)

    ctx = None if osdp.force_mode else _SearchContext(desc_dec, env, osdp)
    base_limit = ctx.limit if ctx is not None else limit
    act_ev_slope = (desc_dec.resident_act_bytes_per_token
                    + sum(op.act_bytes_per_token
                          for op in desc_dec.operators)) / env.n_tp
    nodes = 0
    evals: Dict[int, Tuple[Dict[str, Decision], Optional[SearchResult],
                           MixServingCost, bool]] = {}

    def probe(slots: int):
        nonlocal nodes
        if slots in evals:
            return evals[slots]
        if ctx is None:
            g = (osdp.default_slice_granularity
                 if osdp.operator_splitting else 1)
            decisions = uniform_plan(desc_dec, osdp.force_mode, g)
            res = None
        else:
            act_inf = inference_act_bytes(desc_dec, env, slots, 1)
            ctx.limit = max(0.0, base_limit - slots * cache_exp
                            - act_inf + act_ev_slope * slots)
            res = ctx.solve(slots * env.n_data)
            decisions = res.decisions
            nodes += res.nodes_visited
        mc = serving_mix_cost(desc_pres, desc_dec, decisions, mix, env,
                              slots)
        ok = mc.memory <= limit
        evals[slots] = (decisions, res, mc, ok)
        return evals[slots]

    probed: List[int] = []
    if slot_candidates is not None:
        probed = sorted({max(1, int(s)) for s in slot_candidates})
        for s in probed:
            probe(s)
    else:
        s, last_ok, first_bad = 1, 0, None
        while s <= max_slots:
            probed.append(s)
            if probe(s)[3]:
                last_ok = s
            else:
                first_bad = s
                break
            s *= 2
        if first_bad is None and probed and probed[-1] != max_slots:
            probed.append(max_slots)
            if probe(max_slots)[3]:
                last_ok = max_slots
            else:
                first_bad = max_slots
        if first_bad is not None and last_ok:
            lo, hi = last_ok, first_bad
            while hi - lo > 1:
                mid = (lo + hi) // 2
                probed.append(mid)
                if probe(mid)[3]:
                    lo = mid
                else:
                    hi = mid

    if ctx is not None:
        ctx.limit = base_limit
    feas = [s for s in evals if evals[s][3]]
    max_feas = max(feas) if feas else 0
    if feas:
        best_slots = max(feas, key=lambda s: evals[s][2].throughput)
        feasible = True
    else:
        best_slots = min(evals)
        feasible = False
    decisions, res, mc, _ = evals[best_slots]
    return ServePlan(
        model_name=model.name, workload=mix.workload(),
        decisions=decisions, cost=_blend_mix_cost(mix, mc),
        slots_per_device=best_slots if feasible else 0,
        max_slots_per_device=max_feas,
        max_concurrency=max_feas * env.n_data,
        feasible=feasible,
        solver=(f"forced:{osdp.force_mode}" if osdp.force_mode
                else osdp.search),
        search_seconds=_time.perf_counter() - t0,
        nodes_visited=nodes,
        candidates=sorted((s, evals[s][2].throughput if evals[s][3]
                           else 0.0) for s in evals),
        inner=res,
        mix=mix,
        class_costs=dict(mc.per_class))


def rescore_serve_plan(model: ModelConfig, workload: WorkloadLike,
                       decisions: Dict[str, Decision], env: CostEnv,
                       osdp: OSDPConfig, slots: int
                       ) -> Tuple[ServingCost, bool]:
    """Re-score an existing serving plan's decisions against a (possibly
    different) environment: (cost, fits-memory).

    This is the resilience supervisor's feasibility check after a
    device loss — a plan searched on the healthy cluster is re-costed
    verbatim on the degraded `CostEnv` (whose `topo.memory_limit` has
    typically tightened) to decide whether the survivors can keep
    running it, or whether a fresh `search_serve` is required.  No
    solver runs: only the analytical cost model.

    A multi-class `RequestClassMix` re-scores through
    `serving_mix_cost` (returning the blended aggregate cost); a
    single-class mix is the exact `ServingWorkload` alias."""
    if isinstance(workload, RequestClassMix):
        if len(workload) > 1:
            dec_shape = ShapeConfig("serve_decode", 1, env.n_data,
                                    "decode")
            desc_dec = describe(model, dec_shape)
            desc_pres = {
                c.prompt_len: describe(model, ShapeConfig(
                    "serve_prefill", c.prompt_len, env.n_data,
                    "prefill"))
                for c in workload.classes}
            limit = env.topo.memory_limit(osdp.memory_limit_bytes)
            mc = serving_mix_cost(desc_pres, desc_dec, decisions,
                                  workload, env, max(1, int(slots)))
            return _blend_mix_cost(workload, mc), mc.memory <= limit
        workload = workload.classes[0].workload()
    pre_shape = ShapeConfig("serve_prefill", workload.prompt_len,
                            env.n_data, "prefill")
    dec_shape = ShapeConfig("serve_decode", 1, env.n_data, "decode")
    desc_pre = describe(model, pre_shape)
    desc_dec = describe(model, dec_shape)
    limit = env.topo.memory_limit(osdp.memory_limit_bytes)
    sc = serving_plan_cost(desc_pre, desc_dec, decisions, workload,
                           env, max(1, int(slots)))
    return sc, sc.memory <= limit


# ---------------------------------------------------------------------------
# Fleet Scheduler: replica count x per-group plan x per-class routing
# ---------------------------------------------------------------------------

@dataclass
class ReplicaGroup:
    """`n_replicas` identical serving replicas carved out of one pool
    (a heterogeneous `DeviceGroup`, or the whole uniform fleet), each
    running `plan` on the `cluster` sub-spec and serving the named
    request classes."""

    name: str
    n_replicas: int
    devices_per_replica: int
    cluster: ClusterSpec
    plan: ServePlan
    classes: Tuple[str, ...]

    @property
    def capacity_tokens_per_s(self) -> float:
        """Aggregate planned output tokens/s across the replicas."""
        return self.n_replicas * self.plan.cost.throughput

    def class_capacity(self, name: str) -> float:
        """Planned output tokens/s the group allots to one class."""
        if self.plan.class_costs and name in self.plan.class_costs:
            return (self.n_replicas
                    * self.plan.class_costs[name].throughput)
        return self.capacity_tokens_per_s if name in self.classes else 0.0


@dataclass
class FleetPlan:
    """A searched fleet configuration: replica groups (each with its
    own `ServePlan`), a class -> group routing table, and per-class
    admission limits (max in-flight + queued requests fleet-wide —
    2x the planned steady-state slot allocation).

    `goodput` is the planned satisfied load Σ_c min(offered_c,
    capacity_c) in output tokens/s; `slo_attained` is the analytic
    per-class check (phase latencies within target AND capacity covers
    the offered load).  The traffic simulator
    (`repro.serving.simulator`) is the measured-under-load validator
    of both claims."""

    model_name: str
    mix: RequestClassMix
    cluster: ClusterSpec
    strategy: str                       # "slo" | "uniform"
    groups: List[ReplicaGroup]
    routing: Dict[str, Dict[str, float]]
    admission: Dict[str, int]
    slo_attained: Dict[str, bool]
    feasible: bool
    throughput: float
    goodput: float
    search_seconds: float

    @property
    def n_replicas(self) -> int:
        return sum(g.n_replicas for g in self.groups)

    @property
    def n_slo_attained(self) -> int:
        return sum(1 for ok in self.slo_attained.values() if ok)

    def summary(self) -> str:
        lines = [
            f"fleet-plan[{self.model_name} {self.strategy}] "
            f"{self.n_replicas} replicas in {len(self.groups)} groups "
            f"({'feasible' if self.feasible else 'INFEASIBLE'}), "
            f"SLO {self.n_slo_attained}/{len(self.mix)} classes",
            f"  planned capacity = {self.throughput:.0f} tok/s, "
            f"satisfied load = {self.goodput:.0f} of "
            f"{self.mix.offered_tokens_per_s:.0f} tok/s offered",
        ]
        for g in self.groups:
            lines.append(
                f"  group {g.name}: {g.n_replicas} x "
                f"{g.devices_per_replica} devices, "
                f"{g.plan.max_slots_per_device} slots/device, "
                f"classes [{', '.join(g.classes)}], "
                f"{g.capacity_tokens_per_s:.0f} tok/s")
        adm = ", ".join(f"{k}<={v}" for k, v in self.admission.items())
        lines.append(f"  admission (in-flight + queued): {adm}")
        return "\n".join(lines)


def _fleet_pools(cluster: ClusterSpec
                 ) -> List[Tuple[str, ClusterSpec]]:
    """Partition a fleet into uniform pools: one per heterogeneous
    `DeviceGroup` (groups split at the outermost level, so each pool
    keeps the inner levels and scales the outer fan-out), or the whole
    cluster when it is already uniform."""
    if not cluster.groups:
        return [("fleet", cluster)]
    inner = math.prod(l.ways for l in cluster.levels[:-1])
    pools = []
    for g in cluster.groups:
        dev = dataclasses.replace(cluster.device, hbm_bytes=g.hbm_bytes)
        if g.peak_flops > 0:
            dev = dataclasses.replace(dev, peak_flops=g.peak_flops)
        if inner > 0 and g.n_devices % inner == 0 \
                and g.n_devices >= inner:
            levels = cluster.levels[:-1] + (dataclasses.replace(
                cluster.levels[-1], ways=g.n_devices // inner),)
        else:
            # the group does not tile the inner levels: flatten it
            levels = (dataclasses.replace(cluster.levels[0],
                                          ways=g.n_devices),)
        pools.append((g.name, ClusterSpec(levels=tuple(levels),
                                          device=dev)))
    return pools


def _replica_counts(pool: ClusterSpec,
                    candidates: Optional[Sequence[int]]) -> List[int]:
    """Admissible replica counts for a pool: the requested candidates
    (or powers of two up to the pool size) that `consume_outer`
    accepts — replicas are independent engines, so they split at the
    outermost level like pipeline stages."""
    if candidates is None:
        cands, r = [], 1
        while r <= pool.n_devices:
            cands.append(r)
            r *= 2
    else:
        cands = sorted({int(r) for r in candidates if r >= 1})
    out = []
    for r in cands:
        if r > pool.n_devices or pool.n_devices % r:
            continue
        try:
            pool.consume_outer(r)
        except ValueError:
            continue
        out.append(r)
    return out or [1]


def search_fleet(model: ModelConfig, mix: WorkloadLike,
                 cluster: ClusterSpec, osdp: OSDPConfig, *,
                 max_slots: int = 512,
                 replica_candidates: Optional[Sequence[int]] = None,
                 strategy: str = "slo") -> FleetPlan:
    """Search the fleet plan space: replica count x per-group plan x
    per-class routing/admission.

    The fleet is first partitioned into uniform pools (one per
    heterogeneous `DeviceGroup`, else the whole cluster); each pool
    may be split into `r` independent replicas (`consume_outer`, like
    pipeline stages — no collectives cross replicas).  The search then
    enumerates class -> pool assignments; every (pool, replica count,
    class subset) combination reuses `search_serve` on the
    per-replica sub-spec with the sub-mix routed there, and the winner
    maximizes (feasibility, #SLO-attained classes, satisfied load,
    capacity).

    `strategy="uniform"` is the baseline the fleet benchmark compares
    against: the whole cluster is split into identical replicas, every
    class routed everywhere — heterogeneity is ignored, so planning is
    bound by the worst group's memory and long-prompt classes share
    slots with latency-critical ones.  `strategy="slo"` plans each
    pool at its real budget and routes classes to the groups that can
    hold their SLOs."""
    t0 = _time.perf_counter()
    mix = RequestClassMix.of(mix)
    if strategy not in ("slo", "uniform"):
        raise ValueError(f"unknown fleet strategy {strategy!r}")
    if strategy == "uniform":
        pools = [("uniform", cluster)]
        assignments = [tuple(0 for _ in mix.classes)]
    else:
        pools = _fleet_pools(cluster)
        n_pools = len(pools)
        assignments = [(0,) * len(mix)] if n_pools == 1 else [
            tuple(a) for a in _np_cartesian(n_pools, len(mix))]

    offered = {c.name: c.arrival_rate * c.decode_len
               for c in mix.classes}
    pool_osdp = []
    for name, spec in pools:
        limit = (spec.device.hbm_bytes if cluster.groups
                 and strategy == "slo"
                 else osdp.memory_limit_bytes)
        pool_osdp.append(dataclasses.replace(
            osdp, memory_limit_bytes=limit))

    plan_cache: Dict[Tuple, ServePlan] = {}

    def pool_plan(pi: int, r: int, names: Tuple[str, ...]) -> ServePlan:
        key = (pi, r, names)
        if key not in plan_cache:
            rep = pools[pi][1].consume_outer(r)
            env = CostEnv(rep.device, None, checkpointing=False,
                          train=False, cluster=rep)
            plan_cache[key] = search_serve(
                model, mix.subset(names), env, pool_osdp[pi],
                max_slots=max_slots)
        return plan_cache[key]

    best = None        # (score, groups, routing, slo, thr, good, feas)
    for assign in assignments:
        by_pool: Dict[int, List[str]] = {}
        for ci, pi in enumerate(assign):
            by_pool.setdefault(pi, []).append(mix.classes[ci].name)
        groups: List[ReplicaGroup] = []
        slo: Dict[str, bool] = {}
        cap: Dict[str, float] = {}
        feas = True
        for pi, names in sorted(by_pool.items()):
            pname, pspec = pools[pi]
            names_t = tuple(names)
            sub = mix.subset(names_t)
            best_r = None
            for r in _replica_counts(pspec, replica_candidates):
                plan = pool_plan(pi, r, names_t)
                if not plan.feasible:
                    continue
                costs = plan.class_costs or {}
                r_slo, r_cap = {}, {}
                for c in sub.classes:
                    sc = costs.get(c.name, plan.cost)
                    r_cap[c.name] = r * sc.throughput
                    r_slo[c.name] = (
                        sc.ttft <= c.ttft_slo
                        and sc.tpot <= c.tpot_slo
                        and r_cap[c.name] + 1e-12 >= offered[c.name])
                score = (sum(r_slo.values()),
                         sum(min(offered[n], r_cap[n]) for n in names),
                         sum(r_cap.values()))
                if best_r is None or score > best_r[0]:
                    best_r = (score, r, plan, r_slo, r_cap)
            if best_r is None:
                # nothing fits this pool: keep the r=1 repair plan
                plan = pool_plan(pi, 1, names_t)
                groups.append(ReplicaGroup(
                    pname, 1, pspec.n_devices, pspec.consume_outer(1),
                    plan, names_t))
                for n in names:
                    slo[n], cap[n] = False, 0.0
                feas = False
                continue
            _, r, plan, r_slo, r_cap = best_r
            groups.append(ReplicaGroup(
                pname, r, pspec.n_devices // r, pspec.consume_outer(r),
                plan, names_t))
            slo.update(r_slo)
            cap.update(r_cap)
        thr = sum(g.capacity_tokens_per_s for g in groups
                  if g.plan.feasible)
        good = sum(min(offered[n], cap[n]) for n in offered)
        score = (feas, sum(slo.values()), good, thr)
        if best is None or score > best[0]:
            routing = {c.name: {g.name: 1.0 for g in groups
                                if c.name in g.classes}
                       for c in mix.classes}
            best = (score, groups, routing, slo, thr, good, feas)

    _, groups, routing, slo, thr, good, feas = best
    admission: Dict[str, int] = {}
    for c in mix.classes:
        alloc = 0.0
        for g in groups:
            if c.name not in g.classes:
                continue
            sub = RequestClassMix(tuple(
                k for k in mix.classes if k.name in g.classes))
            alloc += (g.n_replicas * g.plan.max_concurrency
                      * sub.slot_share(c))
        admission[c.name] = max(1, int(math.ceil(2.0 * alloc)))
    return FleetPlan(
        model_name=model.name, mix=mix, cluster=cluster,
        strategy=strategy, groups=groups, routing=routing,
        admission=admission, slo_attained=slo, feasible=feas,
        throughput=thr, goodput=good,
        search_seconds=_time.perf_counter() - t0)


def _np_cartesian(n_pools: int, n_classes: int):
    """All class -> pool assignments (n_pools ** n_classes tuples)."""
    grids = np.meshgrid(*([np.arange(n_pools)] * n_classes),
                        indexing="ij")
    return np.stack([g.ravel() for g in grids], axis=1)


# ---------------------------------------------------------------------------
# Hybrid Scheduler: (dp, tp, pp) factorization sweep ("3D+OSDP")
# ---------------------------------------------------------------------------

def search_hybrid(desc: ModelDescription, device: DeviceInfo,
                  n_devices: int, osdp: OSDPConfig,
                  batch_candidates: Optional[Sequence[int]] = None,
                  micro: int = 8,
                  candidates: Optional[Sequence[Factorization]] = None,
                  max_tp: int = 0, max_pp: int = 0,
                  cluster: Optional[ClusterSpec] = None,
                  profile=None) -> HybridPlan:
    """The paper's strongest configuration, "3D+OSDP", as a search.

    Sweeps every (dp, tp, pp) factorization of `n_devices` (or the
    given `candidates`); inside each, the DP dimension of the
    1/(tp*pp) model residue is decided by the existing Scheduler —
    i.e. the dfs/knapsack/greedy solvers, or a forced uniform mode
    when `osdp.force_mode` is set (force_mode="ZDP" reproduces plain
    DeepSpeed-style 3D; no force is 3D+OSDP).  Returns the global
    throughput argmax as a `HybridPlan`.

    Topology placement: with a `cluster` (hierarchical `ClusterSpec`),
    TP occupies the innermost levels (its per-layer activation
    all-reduces need the fastest links), PP the outermost (its
    point-to-point sends tolerate the slowest), and the DP dimension
    searches over the *residual* hierarchy — so the inner Scheduler
    sees level-k ZDP items and per-group memory limits of the actual
    data extent.  Factorizations that do not divide the level
    structure are skipped as inadmissible.  Without a `cluster` one is
    inferred from the device (`ClusterSpec.from_device`): flat devices
    keep the legacy all-ICI pricing; devices declaring
    `devices_per_node` get a node/cluster hierarchy, fixing the old
    path that charged `ici_bw` for TP groups spanning nodes.

    When the OSDP search is on with operator splitting, the unsplit
    search runs as well and the better of the two is kept (splitting
    trades smaller transient gathers for extra collective latency, so
    neither dominates — same policy as the fig5 benchmark).

    Sweep-level optimizations (results unchanged):
      * the inner problem only depends on (dp, residual topology) —
        factorizations sharing a residue and data extent reuse one
        sliced description and one Scheduler solve,
      * factorizations are visited best-bound-first and skipped when
        even their compute-only step time (comm >= 0 is dropped — an
        admissible bound) cannot beat the incumbent's throughput.
    """
    t0 = _time.perf_counter()
    if cluster is not None and cluster.n_devices != n_devices:
        raise ValueError(
            f"cluster has {cluster.n_devices} devices, search asked "
            f"for {n_devices}")
    topo = cluster if cluster is not None \
        else ClusterSpec.from_device(device, n_devices)
    if candidates is None:
        candidates = factorizations(n_devices, max_tp, max_pp)
    seq = desc.shape.seq_len
    batches = (list(batch_candidates) if batch_candidates is not None
               else [desc.shape.global_batch])
    n_layers = max(1, desc.model.n_layers)

    # TP innermost, PP outermost: the data residue of each admissible
    # factorization (skip those that don't divide the level structure)
    residues: Dict[Factorization, ClusterSpec] = {}
    for f in candidates:
        if f.pp > n_layers:
            continue
        try:
            residues[f] = topo.consume_inner(f.tp).consume_outer(f.pp)
        except ValueError:
            continue

    # admissible throughput upper bound: the inner step time is at
    # least the residue's compute time (the only mode-independent term;
    # under selective remat the bound drops the 1.30 recompute factor —
    # a fully-no-remat plan is reachable, so 1.0x stays admissible).
    # Heterogeneous fleets run lockstep at the slowest group's pace.
    flops_tok = sum(op.flops_per_token for op in desc.operators)
    if profile is None:
        comp_unit = seq * 3.0 * (1.30 if osdp.env_checkpointing else 1.0) \
            / (topo.effective_peak_flops * device.mxu_efficiency)
    else:
        # calibrated bound stays admissible: no op runs above the
        # curve's best fraction, and every op pays >= the fitted
        # recompute factor when checkpointing is forced on
        eff_hi = max(profile.efficiency.fraction)
        rf = profile.remat_factor if osdp.env_checkpointing else 1.0
        comp_unit = seq * 3.0 * rf \
            / (topo.effective_peak_flops * eff_hi)

    def thr_bound(f: Factorization) -> float:
        best_b = 0.0
        for b in batches:
            bpd = max(1, b // f.dp)
            t_comp = flops_tok / (f.tp * f.pp) * comp_unit * bpd
            t = hybrid_step_time(t_comp, desc, device, b, f, micro, topo)
            if t > 0:
                best_b = max(best_b, b * seq / t)
        return best_b

    admissible = list(residues)
    bounds = {f: thr_bound(f) for f in admissible}
    admissible.sort(key=bounds.__getitem__, reverse=True)

    variants = [osdp]
    if osdp.force_mode is None and osdp.operator_splitting:
        variants.append(dataclasses.replace(osdp,
                                            operator_splitting=False))

    slice_cache: Dict[int, ModelDescription] = {}
    sched_cache: Dict[Tuple, SearchResult] = {}

    best: Optional[HybridPlan] = None
    fallback: Optional[HybridPlan] = None   # min-memory infeasible plan
    swept: List[Tuple[Factorization, float]] = []

    for f in admissible:
        # dominance pruning: an incumbent nothing here can beat
        if best is not None and (bounds[f] * (1 + 1e-9)
                                 <= best.cost.throughput):
            continue
        mp = f.tp * f.pp
        sub = slice_cache.get(mp)
        if sub is None:
            sub = slice_cache[mp] = slice_description(desc, f.tp, f.pp)
        data_spec = residues[f]
        env = CostEnv(device, MeshConfig((f.dp, 1), ("data", "model")),
                      checkpointing=osdp.env_checkpointing,
                      include_tp=False, cluster=data_spec,
                      profile=profile)
        local: Optional[HybridPlan] = None
        for vi, cfg in enumerate(variants):
            key = (mp, data_spec, vi)
            res = sched_cache.get(key)
            if res is None:
                res = sched_cache[key] = schedule(
                    sub, env, cfg, batch_candidates=batches)
            t = hybrid_step_time(res.cost.time, desc, device,
                                 res.batch_size, f, micro, topo)
            plan = _as_hybrid_plan(desc, device, f, res, t, micro, cfg,
                                   topo)
            if not res.feasible:
                if fallback is None or (plan.cost.memory
                                        < fallback.cost.memory):
                    fallback = plan
                continue
            if local is None or plan.cost.throughput > local.cost.throughput:
                local = plan
        if local is None:
            continue
        swept.append((f, local.cost.throughput))
        if best is None or local.cost.throughput > best.cost.throughput:
            best = local

    if best is None:
        if fallback is None:
            # every candidate inadmissible (e.g. pp > n_layers for a
            # forced factorization): report an infeasible placeholder
            # rather than raise — same contract as the flat Scheduler.
            cands = list(candidates)
            if not cands:
                raise ValueError(
                    f"no factorization candidates for {n_devices} devices")
            f = cands[0]
            inf = float("inf")
            best = HybridPlan(
                desc=desc, device=device, factorization=f,
                stage_bounds=stage_bounds(desc.model.n_layers, f.pp),
                decisions={}, cost=PlanCost(inf, inf, inf, 0.0, 0.0, 0.0),
                batch_size=batches[0], micro=micro, feasible=False,
                dp_strategy="inadmissible", inner=None, cluster=topo)
        else:
            best = fallback
    best.swept = swept
    if best.inner is not None:
        best.inner.search_seconds = _time.perf_counter() - t0
    return best


def _as_hybrid_plan(desc: ModelDescription, device: DeviceInfo,
                    f: Factorization, res: SearchResult, t: float,
                    micro: int, cfg: OSDPConfig,
                    cluster: Optional[ClusterSpec] = None) -> HybridPlan:
    b_local = max(1, res.batch_size // f.dp)
    tp_t = tp_activation_time(desc, device, b_local, f.tp, cluster)
    pp_t = pp_boundary_time(desc, device, b_local, f.pp, micro, cluster)
    tokens = res.batch_size * desc.shape.seq_len
    cost = PlanCost(
        memory=res.cost.memory, peak_memory=res.cost.peak_memory,
        time=t, comm_time=res.cost.comm_time + tp_t + pp_t,
        compute_time=res.cost.compute_time,
        throughput=tokens / t if t > 0 else 0.0)
    strategy = (f"forced:{cfg.force_mode}" if cfg.force_mode
                else cfg.search + ("" if cfg.operator_splitting
                                   else "/nosplit"))
    return HybridPlan(
        desc=desc, device=device, factorization=f,
        stage_bounds=stage_bounds(desc.model.n_layers, f.pp),
        decisions=res.decisions, cost=cost, batch_size=res.batch_size,
        micro=micro, feasible=res.feasible, dp_strategy=strategy,
        inner=res, cluster=cluster)
