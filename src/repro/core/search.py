"""OSDP Search Engine + Scheduler (paper Algorithm 1).

Three solvers over the same problem
    min_p  T(p, b)   s.t.  M(p, b) <= M_limit,  p_i in {DP, ZDP[, ZDP_POD]}

  * ``dfs``      — the paper's depth-first search with its two pruning
                   rules (memory-exceeded, worse-than-incumbent), made
                   exact-and-fast with branch-and-bound lower bounds and
                   best-ratio branch ordering. Paper-faithful semantics:
                   returns the same argmin as brute force.
  * ``knapsack`` — beyond-paper exact solver: choosing ZDP for op i
                   saves dM_i memory and costs dT_i time, so the problem
                   is a 0/1 knapsack-cover; solved by DP over discretized
                   memory savings. O(n * M/Q) with quantum Q.
  * ``greedy``   — dT/dM ratio heuristic, O(n log n); near-optimal when
                   savings are small relative to the gap (used to seed
                   the DFS incumbent).

The Scheduler sweeps the batch size b upward until even the
all-ZDP+split plan exceeds the limit, keeping the throughput-argmax
(Algorithm 1 lines 3–18, 20).
"""
from __future__ import annotations

import dataclasses
import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import DeviceInfo, MeshConfig, OSDPConfig
from repro.core.cost_model import (DP, ZDP, ZDP_POD, CostEnv, Decision,
                                   PlanCost, plan_cost, uniform_plan,
                                   zdp_extra_time, zdp_saving)
from repro.core.descriptions import ModelDescription, OperatorDesc
from repro.core.hybrid import (Factorization, HybridPlan, factorizations,
                               hybrid_step_time, pp_boundary_time,
                               slice_description, stage_bounds,
                               tp_activation_time)


@dataclass
class SliceItem:
    """One decidable unit: an operator slice (whole op if unsplit)."""

    op_name: str
    slice_idx: int
    n_slices: int
    savings: Dict[str, float]      # mode -> steady bytes saved vs DP
    extra_time: Dict[str, float]   # mode -> seconds added vs DP


@dataclass
class SearchResult:
    decisions: Dict[str, Decision]
    cost: PlanCost
    batch_size: int
    feasible: bool
    solver: str
    search_seconds: float
    nodes_visited: int = 0
    candidates: List[Tuple[int, float]] = field(default_factory=list)
    # (batch, throughput) per Scheduler iteration — Algorithm 1's P set


def auto_granularity(op, env: CostEnv, osdp: OSDPConfig,
                     candidates=(1, 2, 4, 8, 16)) -> int:
    """Per-operator slice granularity (beyond paper — §4.3 names this
    as open future work).

    Larger g shrinks the transiently-gathered slice (M_extra/g) but
    adds (g-1) extra collective-latency terms. Pick the g minimizing
        alpha_cost(g) + shadow_price * gathered(g)
    where the shadow price converts bytes to seconds at the ring rate
    of this op's own gather (the marginal cost of covering the same
    bytes by sharding some other operator instead)."""
    if not (osdp.operator_splitting and op.splittable):
        return 1
    dev = env.device
    n = env.n_data
    rounds = (3 + (1 if env.checkpointing else 0)) if env.train else 1
    gathered_full = op.param_bytes / env.n_tp / max(1, op.layers)
    # seconds per byte of memory covered by sharding elsewhere
    shadow = rounds * (n - 1) / n / min(
        dev.link_bw(a) for a in env.mesh.axes if a in ("pod", "data"))

    def total(g: int) -> float:
        alpha_cost = rounds * (n - 1) * dev.alpha * (g - 1)
        return alpha_cost + shadow * gathered_full / g

    return min(candidates, key=total)


def _build_items(desc: ModelDescription, env: CostEnv,
                 osdp: OSDPConfig) -> List[SliceItem]:
    modes = [ZDP]
    if osdp.allow_pod_hierarchical and env.mesh.multi_pod:
        modes.append(ZDP_POD)
    items: List[SliceItem] = []
    for op in desc.decidable():
        if osdp.auto_granularity:
            g = auto_granularity(op, env, osdp)
        else:
            g = (osdp.default_slice_granularity
                 if (osdp.operator_splitting and op.splittable) else 1)
        sav = {m: zdp_saving(op, env, m, g) / g for m in modes}
        ext = {m: zdp_extra_time(op, env, m) / g for m in modes}
        for j in range(g):
            items.append(SliceItem(op.name, j, g, sav, ext))
    return items


def _items_to_decisions(desc: ModelDescription, items: List[SliceItem],
                        choice: List[Optional[str]]) -> Dict[str, Decision]:
    per_op: Dict[str, List[str]] = {}
    for it, c in zip(items, choice):
        per_op.setdefault(it.op_name, [DP] * it.n_slices)
        per_op[it.op_name][it.slice_idx] = c or DP
    out: Dict[str, Decision] = {}
    for op in desc.operators:
        if op.name in per_op:
            out[op.name] = Decision(op.name, tuple(per_op[op.name]))
        else:
            out[op.name] = Decision(op.name, (DP,))
    return out


def _base_cost(desc: ModelDescription, batch: int,
               env: CostEnv) -> PlanCost:
    """Cost of the all-DP plan — the reference the items perturb."""
    return plan_cost(desc, uniform_plan(desc, DP), batch, env)


# ---------------------------------------------------------------------------
# Solver 1: the paper's DFS (branch and bound, exact)
# ---------------------------------------------------------------------------

def _solve_dfs(items: List[SliceItem], need: float,
               node_budget: int = 2_000_000) -> Tuple[List[Optional[str]], int]:
    """Minimize sum extra_time s.t. sum savings >= need.

    Paper Algorithm 1 lines 5–11: traverse {DP, ZDP}^n depth-first,
    pruning on (a) memory infeasibility and (b) incumbent time bound.
    We order operators by best dT/dM ratio and add an admissible bound
    (remaining need * best remaining ratio), which keeps the traversal
    exact while visiting few nodes.
    """
    n = len(items)
    if need <= 0:
        return [None] * n, 1

    def best_ratio(it: SliceItem) -> float:
        return min(it.extra_time[m] / max(it.savings[m], 1e-9)
                   for m in it.savings)

    order = sorted(range(n), key=lambda i: best_ratio(items[i]))
    # suffix quantities for bounds
    suffix_sav = [0.0] * (n + 1)
    suffix_best_ratio = [float("inf")] * (n + 1)
    for i in range(n - 1, -1, -1):
        it = items[order[i]]
        suffix_sav[i] = suffix_sav[i + 1] + max(it.savings.values())
        suffix_best_ratio[i] = min(suffix_best_ratio[i + 1], best_ratio(it))

    # greedy incumbent
    incumbent_choice, incumbent_time = _solve_greedy(items, need)
    best_time = incumbent_time
    best_choice = list(incumbent_choice)
    nodes = 0
    choice: List[Optional[str]] = [None] * n

    # pre-sorted branch options per item: cheapest-ratio mode first, DP last
    branches: List[List[Optional[str]]] = []
    for i in range(n):
        it = items[order[i]]
        ms = sorted(it.savings, key=lambda m: it.extra_time[m]
                    / max(it.savings[m], 1e-9))
        branches.append(ms + [None])

    # iterative DFS: frames of (depth, saved, t, next-branch index)
    stack = [(0, 0.0, 0.0, 0)]
    while stack:
        i, saved, t, bi = stack.pop()
        if bi == 0:
            nodes += 1
            if nodes > node_budget:
                break
            if saved >= need:
                if t < best_time:
                    best_time = t
                    best_choice = list(choice)
                continue
            if i == n:
                continue  # infeasible leaf
            # prune: even sharding everything left cannot cover the need
            if saved + suffix_sav[i] < need:
                continue
            # prune: admissible lower bound on remaining time
            if t + (need - saved) * suffix_best_ratio[i] >= best_time:
                continue
        opts = branches[i]
        if bi >= len(opts):
            choice[order[i]] = None
            continue
        # re-check the bound when revisiting (incumbent may have improved)
        if bi > 0 and t + (need - saved) * suffix_best_ratio[i] >= best_time:
            choice[order[i]] = None
            continue
        m = opts[bi]
        stack.append((i, saved, t, bi + 1))   # resume point
        choice[order[i]] = m
        if m is None:
            stack.append((i + 1, saved, t, 0))
        else:
            it = items[order[i]]
            stack.append((i + 1, saved + it.savings[m],
                          t + it.extra_time[m], 0))

    return best_choice, nodes


# ---------------------------------------------------------------------------
# Solver 2: exact knapsack-cover DP (beyond paper)
# ---------------------------------------------------------------------------

def _solve_knapsack(items: List[SliceItem], need: float,
                    quantum: float = 16 * 2**20) -> List[Optional[str]]:
    """DP over discretized memory saving. Savings are rounded DOWN (so a
    'covered' answer is truly feasible); `need` is rounded up."""
    n = len(items)
    if need <= 0:
        return [None] * n
    cap = int(-(-need // quantum))          # ceil
    INF = float("inf")
    # dp[s] = min time to save >= s quanta (clamped at cap)
    dp = [INF] * (cap + 1)
    dp[0] = 0.0
    parent: List[List[Optional[Tuple[int, str]]]] = [
        [None] * (cap + 1) for _ in range(n + 1)]
    for i, it in enumerate(items):
        ndp = dp[:]
        npar = [None] * (cap + 1)
        for m, sav in it.savings.items():
            q = int(sav // quantum)
            if q == 0:
                continue
            t = it.extra_time[m]
            for s in range(cap + 1):
                if dp[s] == INF:
                    continue
                s2 = min(cap, s + q)
                if dp[s] + t < ndp[s2]:
                    ndp[s2] = dp[s] + t
                    npar[s2] = (s, m)
        dp = ndp
        parent[i + 1] = npar  # type: ignore[assignment]
    if dp[cap] == INF:
        # infeasible even at full sharding
        return [max(it.savings, key=it.savings.get) for it in items]
    # backtrack
    choice: List[Optional[str]] = [None] * n
    s = cap
    for i in range(n, 0, -1):
        p = parent[i][s]
        if p is not None:
            s, m = p
            choice[i - 1] = m
    return choice


# ---------------------------------------------------------------------------
# Solver 3: greedy ratio heuristic
# ---------------------------------------------------------------------------

def _solve_greedy(items: List[SliceItem],
                  need: float) -> Tuple[List[Optional[str]], float]:
    n = len(items)
    choice: List[Optional[str]] = [None] * n
    if need <= 0:
        return choice, 0.0
    ranked = []
    for i, it in enumerate(items):
        m = min(it.savings, key=lambda m: it.extra_time[m]
                / max(it.savings[m], 1e-9))
        ranked.append((it.extra_time[m] / max(it.savings[m], 1e-9), i, m))
    ranked.sort()
    saved = t = 0.0
    for _, i, m in ranked:
        if saved >= need:
            break
        choice[i] = m
        saved += items[i].savings[m]
        t += items[i].extra_time[m]
    return choice, (t if saved >= need else float("inf"))


# ---------------------------------------------------------------------------
# Search Engine: fixed-b solve
# ---------------------------------------------------------------------------

def search_plan(desc: ModelDescription, global_batch: int, env: CostEnv,
                osdp: OSDPConfig) -> SearchResult:
    t0 = _time.perf_counter()
    if osdp.force_mode:
        dec = uniform_plan(
            desc, osdp.force_mode,
            osdp.default_slice_granularity if osdp.operator_splitting else 1)
        cost = plan_cost(desc, dec, global_batch, env)
        # feasibility is judged on steady memory, same as the searched
        # path below (transient peaks stay visible in cost.peak_memory)
        return SearchResult(dec, cost, global_batch,
                            cost.memory <= osdp.memory_limit_bytes,
                            f"forced:{osdp.force_mode}",
                            _time.perf_counter() - t0)

    items = _build_items(desc, env, osdp)
    base = _base_cost(desc, global_batch, env)
    need = base.memory - osdp.memory_limit_bytes
    nodes = 0
    if osdp.search == "dfs":
        choice, nodes = _solve_dfs(items, need)
    elif osdp.search == "knapsack":
        choice = _solve_knapsack(items, need)
    elif osdp.search == "greedy":
        choice, _ = _solve_greedy(items, need)
    else:
        raise ValueError(f"unknown solver {osdp.search!r}")
    decisions = _items_to_decisions(desc, items, choice)
    cost = plan_cost(desc, decisions, global_batch, env)

    # Repair: per-slice savings are exact for uniform runs but slightly
    # optimistic for mixed ones (each ZDP run re-gathers a slice), so
    # the Profiler's evaluation can come out a hair over the limit.
    # Flip the cheapest remaining DP slices until the evaluation fits.
    if cost.memory > osdp.memory_limit_bytes:
        remaining = sorted(
            (i for i, c in enumerate(choice) if c is None),
            key=lambda i: min(items[i].extra_time[m]
                              / max(items[i].savings[m], 1e-9)
                              for m in items[i].savings))
        for i in remaining:
            it = items[i]
            choice[i] = min(it.savings,
                            key=lambda m: it.extra_time[m]
                            / max(it.savings[m], 1e-9))
            decisions = _items_to_decisions(desc, items, choice)
            cost = plan_cost(desc, decisions, global_batch, env)
            if cost.memory <= osdp.memory_limit_bytes:
                break
        if cost.memory > osdp.memory_limit_bytes:
            # escalate every slice to its max-saving mode (ZDP) — the
            # most-sharded plan is the feasibility frontier
            choice = [max(it.savings, key=it.savings.get) for it in items]
            decisions = _items_to_decisions(desc, items, choice)
            cost = plan_cost(desc, decisions, global_batch, env)

    return SearchResult(decisions, cost, global_batch,
                        cost.memory <= osdp.memory_limit_bytes,
                        osdp.search, _time.perf_counter() - t0, nodes)


# ---------------------------------------------------------------------------
# Scheduler: batch-size sweep (Algorithm 1 outer loop)
# ---------------------------------------------------------------------------

def schedule(desc: ModelDescription, env: CostEnv, osdp: OSDPConfig,
             batch_candidates: Optional[Sequence[int]] = None,
             max_batch: int = 4096) -> SearchResult:
    t0 = _time.perf_counter()
    best: Optional[SearchResult] = None
    cands: List[Tuple[int, float]] = []
    batches = (list(batch_candidates) if batch_candidates is not None
               else _default_batches(max_batch, env))
    for b in batches:
        res = search_plan(desc, b, env, osdp)
        if not res.feasible:
            # Algorithm 1 line 12–14: all plans exceed the limit -> stop
            if best is not None:
                break
            continue
        cands.append((b, res.cost.throughput))
        if best is None or res.cost.throughput > best.cost.throughput:
            best = res
    if best is None:
        # nothing fits even fully sharded: return the most-sharded plan
        best = search_plan(desc, batches[0], env, osdp)
    best.candidates = cands
    best.search_seconds = _time.perf_counter() - t0
    return best


def _default_batches(max_batch: int, env: CostEnv) -> List[int]:
    # per-device microbatch 1,2,3,... like Algorithm 1's b in {1,2,3,...}
    n = env.n_data
    out = []
    b = n
    while b <= max_batch:
        out.append(b)
        b += n
    return out or [n]


# ---------------------------------------------------------------------------
# Hybrid Scheduler: (dp, tp, pp) factorization sweep ("3D+OSDP")
# ---------------------------------------------------------------------------

def search_hybrid(desc: ModelDescription, device: DeviceInfo,
                  n_devices: int, osdp: OSDPConfig,
                  batch_candidates: Optional[Sequence[int]] = None,
                  micro: int = 8,
                  candidates: Optional[Sequence[Factorization]] = None,
                  max_tp: int = 0, max_pp: int = 0) -> HybridPlan:
    """The paper's strongest configuration, "3D+OSDP", as a search.

    Sweeps every (dp, tp, pp) factorization of `n_devices` (or the
    given `candidates`); inside each, the DP dimension of the
    1/(tp*pp) model residue is decided by the existing Scheduler —
    i.e. the dfs/knapsack/greedy solvers, or a forced uniform mode
    when `osdp.force_mode` is set (force_mode="ZDP" reproduces plain
    DeepSpeed-style 3D; no force is 3D+OSDP).  Returns the global
    throughput argmax as a `HybridPlan`.

    When the OSDP search is on with operator splitting, the unsplit
    search runs as well and the better of the two is kept (splitting
    trades smaller transient gathers for extra collective latency, so
    neither dominates — same policy as the fig5 benchmark).
    """
    t0 = _time.perf_counter()
    if candidates is None:
        candidates = factorizations(n_devices, max_tp, max_pp)
    seq = desc.shape.seq_len
    batches = (list(batch_candidates) if batch_candidates is not None
               else [desc.shape.global_batch])
    n_layers = max(1, desc.model.n_layers)

    best: Optional[HybridPlan] = None
    fallback: Optional[HybridPlan] = None   # min-memory infeasible plan
    swept: List[Tuple[Factorization, float]] = []

    for f in candidates:
        # explicit candidates may undersubscribe the environment (e.g.
        # GPipe over 8 of 16 devices); only pp > layers is inadmissible
        if f.pp > n_layers:
            continue
        sub = slice_description(desc, f.tp, f.pp)
        env = CostEnv(device, MeshConfig((f.dp, 1), ("data", "model")),
                      checkpointing=osdp.checkpointing, include_tp=False)
        variants = [osdp]
        if osdp.force_mode is None and osdp.operator_splitting:
            variants.append(dataclasses.replace(
                osdp, operator_splitting=False))
        local: Optional[HybridPlan] = None
        for cfg in variants:
            res = schedule(sub, env, cfg, batch_candidates=batches)
            t = hybrid_step_time(res.cost.time, desc, device,
                                 res.batch_size, f, micro)
            plan = _as_hybrid_plan(desc, device, f, res, t, micro, cfg)
            if not res.feasible:
                if fallback is None or (plan.cost.memory
                                        < fallback.cost.memory):
                    fallback = plan
                continue
            if local is None or plan.cost.throughput > local.cost.throughput:
                local = plan
        if local is None:
            continue
        swept.append((f, local.cost.throughput))
        if best is None or local.cost.throughput > best.cost.throughput:
            best = local

    if best is None:
        if fallback is None:
            # every candidate inadmissible (e.g. pp > n_layers for a
            # forced factorization): report an infeasible placeholder
            # rather than raise — same contract as the flat Scheduler.
            cands = list(candidates)
            if not cands:
                raise ValueError(
                    f"no factorization candidates for {n_devices} devices")
            f = cands[0]
            inf = float("inf")
            best = HybridPlan(
                desc=desc, device=device, factorization=f,
                stage_bounds=stage_bounds(desc.model.n_layers, f.pp),
                decisions={}, cost=PlanCost(inf, inf, inf, 0.0, 0.0, 0.0),
                batch_size=batches[0], micro=micro, feasible=False,
                dp_strategy="inadmissible", inner=None)
        else:
            best = fallback
    best.swept = swept
    if best.inner is not None:
        best.inner.search_seconds = _time.perf_counter() - t0
    return best


def _as_hybrid_plan(desc: ModelDescription, device: DeviceInfo,
                    f: Factorization, res: SearchResult, t: float,
                    micro: int, cfg: OSDPConfig) -> HybridPlan:
    b_local = max(1, res.batch_size // f.dp)
    tp_t = tp_activation_time(desc, device, b_local, f.tp)
    pp_t = pp_boundary_time(desc, device, b_local, f.pp, micro)
    tokens = res.batch_size * desc.shape.seq_len
    cost = PlanCost(
        memory=res.cost.memory, peak_memory=res.cost.peak_memory,
        time=t, comm_time=res.cost.comm_time + tp_t + pp_t,
        compute_time=res.cost.compute_time,
        throughput=tokens / t if t > 0 else 0.0)
    strategy = (f"forced:{cfg.force_mode}" if cfg.force_mode
                else cfg.search + ("" if cfg.operator_splitting
                                   else "/nosplit"))
    return HybridPlan(
        desc=desc, device=device, factorization=f,
        stage_bounds=stage_bounds(desc.model.n_layers, f.pp),
        decisions=res.decisions, cost=cost, batch_size=res.batch_size,
        micro=micro, feasible=res.feasible, dp_strategy=strategy,
        inner=res)


