"""Plan compilation: RunConfig -> OSDP decisions -> JAX shardings.

This is the glue between the abstract search (core.search) and the
concrete distributed program (sharding.specs + launch.*): it runs the
Profiler+SearchEngine+Scheduler pipeline of the paper and exposes the
result as the `decisions` dict the model builder consumes, plus the
activation/batch PartitionSpecs for jit in_shardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DeviceInfo, OSDPConfig, RunConfig
from repro.core.cost_model import (DP, ZDP, CostEnv, Decision, PlanCost,
                                   plan_cost, uniform_plan)
from repro.core.descriptions import ModelDescription, describe
from repro.core.search import SearchResult, search_plan


@dataclass
class Plan:
    run: RunConfig
    desc: ModelDescription
    decisions: Dict[str, Decision]
    cost: PlanCost
    search: Optional[SearchResult]

    def summary(self) -> str:
        n_zdp = sum(1 for d in self.decisions.values()
                    if d.uniform() not in (DP, None))
        n_mixed = sum(1 for d in self.decisions.values()
                      if d.uniform() is None)
        lines = [
            f"plan[{self.run.model.name} x {self.run.shape.name}] "
            f"ops={len(self.decisions)} zdp={n_zdp} mixed={n_mixed}",
            f"  remat: {remat_summary(self.decisions, self.run.osdp)}",
            f"  est memory/device = {self.cost.memory / 2**30:.2f} GiB "
            f"(peak {self.cost.peak_memory / 2**30:.2f})",
            f"  est step time = {self.cost.time * 1e3:.2f} ms "
            f"(comm {self.cost.comm_time * 1e3:.2f}, "
            f"compute {self.cost.compute_time * 1e3:.2f})",
            f"  est throughput = {self.cost.throughput / 1e6:.2f} Mtok/s",
        ]
        return "\n".join(lines)


def remat_summary(decisions: Dict[str, Decision], osdp) -> str:
    """One-line remat description of a plan: the legacy global flag, or
    the per-op on/off/mixed counts of a selective plan."""
    explicit = [d for d in decisions.values()
                if d.remat is not None
                and any(r is not None for r in d.remat)]
    if not explicit:
        if osdp.selective_remat:
            return "selective (none set)"
        return "global on" if osdp.env_checkpointing else "global off"
    n_on = sum(1 for d in explicit if d.uniform_remat() is True)
    n_off = sum(1 for d in explicit if d.uniform_remat() is False)
    n_mix = len(explicit) - n_on - n_off
    n_inherit = len(decisions) - len(explicit)
    return (f"selective — {n_on} ops on, {n_off} off, {n_mix} mixed"
            + (f", {n_inherit} inherit" if n_inherit else ""))


def make_plan(run: RunConfig,
              device: Optional[DeviceInfo] = None,
              cluster=None, profile=None) -> Plan:
    """Run the OSDP pipeline for a RunConfig with a fixed global batch.

    `cluster` (a `repro.cluster.ClusterSpec`) prices collectives
    against the real bandwidth hierarchy; without one the flat
    (device, mesh) depth-2 adapter applies.  `profile` (a
    `repro.calibrate.CalibrationProfile`) prices with measured
    constants; None keeps the scalar path byte-identical."""
    device = device or (cluster.device if cluster is not None
                        else DeviceInfo())
    desc = describe(run.model, run.shape)
    # selective remat searches from the no-remat base env; bool flags
    # keep the legacy global-checkpointing environment
    env = CostEnv(device, run.mesh,
                  checkpointing=run.osdp.env_checkpointing,
                  train=(run.shape.kind == "train"),
                  cluster=cluster, profile=profile)
    if not run.osdp.enabled:
        decisions = uniform_plan(desc, DP)
        cost = plan_cost(desc, decisions, run.shape.global_batch, env)
        return Plan(run, desc, decisions, cost, None)
    res = search_plan(desc, run.shape.global_batch, env, run.osdp)
    return Plan(run, desc, res.decisions, res.cost, res)


# --- activation / batch shardings -------------------------------------------

def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes carrying the global batch: the whole data extent, so
    cluster-derived meshes (axes named after hierarchy levels) work
    like the legacy ('pod',) 'data' layouts."""
    from repro.sharding.specs import data_axis_names
    return data_axis_names(mesh)


def data_sharding(mesh: Mesh, ndim: int = 2,
                  batch_axis: int = 0) -> NamedSharding:
    """Global-batch arrays: batch dim over (pod, data)."""
    parts = [None] * ndim
    parts[batch_axis] = batch_axes(mesh)
    return NamedSharding(mesh, P(*parts))


def seq_sharding(mesh: Mesh, ndim: int, seq_axis: int) -> NamedSharding:
    """Sequence-sharded arrays (long_500k KV cache: batch=1) — over the
    innermost data axis ('data' on legacy meshes)."""
    parts = [None] * ndim
    parts[seq_axis] = batch_axes(mesh)[-1]
    return NamedSharding(mesh, P(*parts))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
