"""Exact ILP backend for the OSDP cover problem (the fourth solver).

The Search Engine's covering problem (``core/search.py``)

    min  sum_i  extra_time_i[m_i]
    s.t. sum_i  savings_i[m_i]  >=  need,      m_i in modes(i) + {None}

is a 0/1 multiple-choice knapsack-cover: every slice item picks at most
one of its (mode, remat) choices.  The shipped dfs/knapsack/greedy
solvers are heuristically engineered (branch ordering, quantization,
ratio ranking) — this module solves the *same* problem as an explicit
integer linear program, so their answers can be audited against a
formulation whose optimality is a property of the model, not of the
search implementation (ROADMAP item 4; cf. AutoDDL's offline
near-optimal layout solves and scamp-ml's interchangeable z3 / MiniZinc
/ CPLEX templates behind one interface).

Group collapsing (exact). Items with identical (savings, extra_time)
signatures — every per-layer copy of one operator, all slices of a
stacked op — are interchangeable, so the ILP's variables are *counts*:

    y[g, m] = number of group-g slices assigned choice m
    min   sum_{g,m} ext[g,m]  y[g,m]
    s.t.  sum_{g,m} sav[g,m]  y[g,m] >= need         (cover)
          sum_m     y[g,m]          <= K_g   (all g) (exclusivity)
          y integer, 0 <= y[g,m] <= K_g

Identical optimum, exponentially fewer variables (885 per-layer ops
collapse to a few dozen signatures).  Solutions decode to per-item
choices in the DFS's canonical order (cheapest-ratio mode takes the
earliest slices of each group), so a unique optimum yields decisions
*byte-identical* to ``_solve_dfs`` — asserted by
``benchmarks/solver_audit.py`` on the committed BENCH cases.

Two interchangeable backends behind ``solve_ilp``:

  * ``milp`` — ``scipy.optimize.milp`` (HiGHS) when scipy is present;
    ``mip_rel_gap=0`` so the answer is exact, `time_limit` for the
    anytime mode.
  * ``bnb``  — dependency-free best-first branch-and-bound whose lower
    bound is the LP relaxation, evaluated through its Lagrangian dual:
    for any multiplier lam >= 0 on the cover row,

        LP >= lam * need + sum_g  min over feasible y_g of
                              sum_m (ext[g,m] - lam sav[g,m]) y[g,m]
            = lam * need + sum_g  K_g * min(0, min_m rc[g,m](lam))

    (each group's inner minimum puts all capacity on its most negative
    reduced cost).  The dual is concave piecewise-linear in lam with
    breakpoints only at reduced-cost sign changes and crossings, so
    maximizing over that finite candidate set gives the exact LP bound;
    any subset stays admissible.  Tier-1 therefore never gains a hard
    dependency: scipy missing only removes the milp path.

Both backends are *anytime*: given a time (or node) budget they return
the best incumbent found plus a proven lower bound on the optimum —
``ILPSolve.objective`` vs ``ILPSolve.lower_bound`` — with
``optimal=False`` when the gap is open.
"""
from __future__ import annotations

import heapq
import math
import time as _time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:                                     # optional exact backend
    from scipy.optimize import Bounds, LinearConstraint, milp as _milp
    HAVE_SCIPY_MILP = True
except Exception:                        # pragma: no cover - env without scipy
    HAVE_SCIPY_MILP = False

ILP_BACKENDS = ("auto", "milp", "bnb")


@dataclass
class ILPSolve:
    """Result of one exact-cover solve.

    ``objective`` is the incumbent's cover cost (seconds of step time
    added over the all-base plan); ``lower_bound`` the proven minimum.
    ``optimal`` means the gap is closed (or infeasibility proven —
    then ``objective`` is inf and ``choice`` is the max-saving
    fallback every other solver returns on uncoverable instances).
    ``nodes`` is the backend's effort: branch-and-bound nodes expanded
    plus one per integer variable (so trivially-presolved instances
    still report their model size).
    """

    choice: List[Optional[str]]
    nodes: int
    objective: float
    lower_bound: float
    optimal: bool
    backend: str

    @property
    def gap(self) -> float:
        """Relative optimality gap of the incumbent (0 when closed)."""
        if not math.isfinite(self.objective):
            return math.inf
        if self.objective <= self.lower_bound:
            return 0.0
        return (self.objective - self.lower_bound) \
            / max(abs(self.lower_bound), 1e-30)


class _Group:
    """One signature group: interchangeable items, shared choice menu."""

    __slots__ = ("idxs", "modes", "sav", "ext", "cap")

    def __init__(self, idxs: List[int], savings: Dict[str, float],
                 extra_time: Dict[str, float]):
        self.idxs = idxs
        # the DFS's canonical within-group mode order (cheapest dT/dM
        # first; same key, same stable sort) — the decode contract
        self.modes = sorted(savings, key=lambda m: extra_time[m]
                            / max(savings[m], 1e-9))
        self.sav = [savings[m] for m in self.modes]
        self.ext = [extra_time[m] for m in self.modes]
        self.cap = len(idxs)


def _group_items(items: Sequence) -> List[_Group]:
    """Collapse items into signature groups (the DFS's exact grouping:
    items are interchangeable iff their full choice menus match)."""
    table: Dict[tuple, List[int]] = {}
    for i, it in enumerate(items):
        sig = (tuple(sorted(it.savings.items())),
               tuple(sorted(it.extra_time.items())))
        table.setdefault(sig, []).append(i)
    groups = [_Group(idxs, items[idxs[0]].savings,
                     items[idxs[0]].extra_time)
              for idxs in table.values()]
    # best-ratio group order (the DFS's glist order): irrelevant for
    # correctness, it just makes the bnb find good incumbents first
    groups.sort(key=lambda g: min(e / max(s, 1e-9)
                                  for s, e in zip(g.sav, g.ext)))
    return groups


def _decode(items: Sequence, groups: List[_Group],
            counts: List[List[int]]) -> List[Optional[str]]:
    """Counts -> per-item choices, in the DFS's canonical order: mode
    j of a group takes the next counts[g][j] of the group's item
    indices (ascending), cheapest-ratio mode first."""
    choice: List[Optional[str]] = [None] * len(items)
    for g, cnt in zip(groups, counts):
        ptr = 0
        for m, c in zip(g.modes, cnt):
            for _ in range(int(c)):
                choice[g.idxs[ptr]] = m
                ptr += 1
    return choice


def _max_saving_fallback(items: Sequence) -> List[Optional[str]]:
    """The uncoverable-instance fallback every solver agrees on:
    shard everything at its max-saving choice (the feasibility
    frontier; ``_solve_once``'s repair escalates to the same plan)."""
    return [max(it.savings, key=it.savings.get) for it in items]


def _objective(groups: List[_Group], counts: List[List[int]]) -> float:
    return sum(c * e for g, cnt in zip(groups, counts)
               for c, e in zip(cnt, g.ext))


def _coverage(groups: List[_Group], counts: List[List[int]]) -> float:
    return sum(c * s for g, cnt in zip(groups, counts)
               for c, s in zip(cnt, g.sav))


def _greedy_counts(groups: List[_Group], need: float
                   ) -> Optional[List[List[int]]]:
    """Ratio-greedy incumbent on the grouped problem (None if it
    cannot cover)."""
    lvls = sorted((g.ext[j] / max(g.sav[j], 1e-9), gi, j)
                  for gi, g in enumerate(groups)
                  for j in range(len(g.modes)) if g.sav[j] > 0)
    counts = [[0] * len(g.modes) for g in groups]
    rem = [g.cap for g in groups]
    saved = 0.0
    for _, gi, j in lvls:
        if saved >= need:
            break
        take = min(rem[gi],
                   int(math.ceil((need - saved) / groups[gi].sav[j])))
        counts[gi][j] += take
        rem[gi] -= take
        saved += take * groups[gi].sav[j]
    return counts if saved >= need else None


def _topup(groups: List[_Group], counts: List[List[int]],
           need: float) -> None:
    """Greedily add spare capacity until `counts` covers `need` (used
    to absorb sub-quantum float slack in backend solutions)."""
    saved = _coverage(groups, counts)
    if saved >= need:
        return
    lvls = sorted((g.ext[j] / max(g.sav[j], 1e-9), gi, j)
                  for gi, g in enumerate(groups)
                  for j in range(len(g.modes)) if g.sav[j] > 0)
    for _, gi, j in lvls:
        if saved >= need:
            return
        g = groups[gi]
        rem = g.cap - sum(counts[gi])
        take = min(rem, int(math.ceil((need - saved) / g.sav[j])))
        counts[gi][j] += take
        saved += take * g.sav[j]


# ---------------------------------------------------------------------------
# Backend 1: scipy.optimize.milp (HiGHS)
# ---------------------------------------------------------------------------

def _solve_milp(groups: List[_Group], need: float, time_budget: float
                ) -> Tuple[Optional[List[List[int]]], int, float, bool]:
    """Returns (counts | None, nodes, lower_bound, optimal)."""
    n_var = sum(len(g.modes) for g in groups)
    c = np.empty(n_var)
    s = np.empty(n_var)
    ub = np.empty(n_var)
    rows = np.zeros((1 + len(groups), n_var))
    off = 0
    for gi, g in enumerate(groups):
        w = len(g.modes)
        c[off:off + w] = g.ext
        s[off:off + w] = g.sav
        ub[off:off + w] = g.cap
        rows[1 + gi, off:off + w] = 1.0
        off += w
    rows[0] = s
    lb_row = np.full(1 + len(groups), -np.inf)
    ub_row = np.array([np.inf] + [float(g.cap) for g in groups])
    lb_row[0], ub_row[0] = need, np.inf
    options = {"mip_rel_gap": 0.0}
    if time_budget > 0:
        options["time_limit"] = float(time_budget)
    res = _milp(c=c, constraints=LinearConstraint(rows, lb_row, ub_row),
                integrality=np.ones(n_var), bounds=Bounds(0, ub),
                options=options)
    nodes = n_var + max(0, int(getattr(res, "mip_node_count", 0) or 0))
    if res.x is None:
        # proven infeasible (status 2) or budget exhausted with no
        # incumbent — the caller already screened uncoverable needs,
        # so a missing x with status 2 can only be float slack at the
        # cover row; either way fall back to the caller's incumbent
        bound = float(getattr(res, "mip_dual_bound", 0.0) or 0.0)
        return None, nodes, bound, False
    counts: List[List[int]] = []
    off = 0
    for g in groups:
        w = len(g.modes)
        cnt = [int(v) for v in np.clip(np.round(res.x[off:off + w]),
                                       0, g.cap)]
        over = sum(cnt) - g.cap          # exclusivity after rounding
        for j in range(w - 1, -1, -1):
            if over <= 0:
                break
            take = min(cnt[j], over)
            cnt[j] -= take
            over -= take
        counts.append(cnt)
        off += w
    _topup(groups, counts, need)         # absorb solver float slack
    optimal = res.status == 0
    bound = (float(res.mip_dual_bound)
             if getattr(res, "mip_dual_bound", None) is not None
             else 0.0)
    if optimal:
        bound = _objective(groups, counts)
    return counts, nodes, bound, optimal


# ---------------------------------------------------------------------------
# Backend 2: dependency-free branch-and-bound over the LP relaxation
# ---------------------------------------------------------------------------

class _DualTables:
    """Precomputed Lagrangian-dual machinery for the bnb bound.

    For every candidate multiplier lam (the dual's breakpoints) and
    every level position, hold the within-group suffix minimum reduced
    cost and the over-later-groups capacity-weighted dual sum, so one
    bound evaluation is a vectorized max over candidates."""

    MAX_CANDIDATES = 1024

    def __init__(self, groups: List[_Group]):
        self.levels: List[Tuple[int, float, float, bool]] = []
        for gi, g in enumerate(groups):
            for j in range(len(g.modes)):
                self.levels.append((gi, g.sav[j], g.ext[j], j == 0))
        L = len(self.levels)
        cands = {0.0}
        for g in groups:
            for j in range(len(g.modes)):
                if g.sav[j] > 0:
                    cands.add(max(0.0, g.ext[j] / g.sav[j]))
                for k in range(j + 1, len(g.modes)):
                    ds = g.sav[j] - g.sav[k]
                    if ds:
                        lam = (g.ext[j] - g.ext[k]) / ds
                        if lam > 0:
                            cands.add(lam)
        lam = np.array(sorted(cands))
        if lam.size > self.MAX_CANDIDATES:   # any subset stays admissible
            keep = np.linspace(0, lam.size - 1,
                               self.MAX_CANDIDATES).astype(int)
            lam = lam[np.unique(keep)]
        self.lam = lam
        A = lam.size
        # rc[li, a] = ext - lam * sav
        sav = np.array([s for _, s, _, _ in self.levels])
        ext = np.array([e for _, _, e, _ in self.levels])
        rc = ext[:, None] - lam[None, :] * sav[:, None]
        # within-group suffix min reduced cost, clamped at 0
        self.inmin = np.zeros((L + 1, A))
        gid = [gi for gi, _, _, _ in self.levels]
        for li in range(L - 1, -1, -1):
            below = (self.inmin[li + 1]
                     if li + 1 < L and gid[li + 1] == gid[li] else 0.0)
            self.inmin[li] = np.minimum(np.minimum(rc[li], below), 0.0)
        # capacity-weighted dual over the groups strictly after gi
        G = len(groups)
        gmin = np.zeros((G, A))
        first_level = {}
        for li, (gi, _, _, first) in enumerate(self.levels):
            if first:
                first_level[gi] = li
        for gi, g in enumerate(groups):
            gmin[gi] = g.cap * self.inmin[first_level[gi]]
        self.suffix_dual = np.zeros((G + 1, A))
        for gi in range(G - 1, -1, -1):
            self.suffix_dual[gi] = self.suffix_dual[gi + 1] + gmin[gi]
        # capacity pruning tables (dfs-style): best saving reachable
        # per remaining-group slice, and total over later groups
        self.inner_max = np.zeros(L)
        for li in range(L - 1, -1, -1):
            below = (self.inner_max[li + 1]
                     if li + 1 < L and gid[li + 1] == gid[li] else 0.0)
            self.inner_max[li] = max(self.levels[li][1], below)
        self.suffix_cap = np.zeros(G + 1)
        for gi in range(G - 1, -1, -1):
            self.suffix_cap[gi] = (self.suffix_cap[gi + 1]
                                   + groups[gi].cap * max(groups[gi].sav))
        self.gid = gid
        self.first = [f for _, _, _, f in self.levels]
        self.cap_at = [groups[gi].cap for gi in gid]

    def bound(self, li: int, need_rem: float, rem: int) -> float:
        """Admissible lower bound on finishing from level li with
        `need_rem` still to cover (`rem` slices left in li's group;
        ignored — reset to the group capacity — when li opens a fresh
        group).  Covered (need_rem <= 0) is NOT zero when negative-cost
        levels remain: the lam=0 dual term counts every still-available
        cost *reduction*, keeping the bound admissible for modes that
        are both memory-saving and faster."""
        L = len(self.levels)
        if li >= L:
            return 0.0 if need_rem <= 0 else math.inf
        if self.first[li]:
            rem = self.cap_at[li]
        gi = self.gid[li]
        if need_rem <= 0:
            # lam = 0 (index 0: candidates are sorted, all >= 0)
            return float(rem * self.inmin[li, 0]
                         + self.suffix_dual[gi + 1, 0])
        if rem * self.inner_max[li] + self.suffix_cap[gi + 1] < need_rem:
            return math.inf              # capacity: uncoverable from here
        vals = (self.lam * need_rem + rem * self.inmin[li]
                + self.suffix_dual[gi + 1])
        return float(vals.max())


def _solve_bnb(groups: List[_Group], need: float, node_budget: int,
               time_budget: float
               ) -> Tuple[Optional[List[List[int]]], int, float, bool]:
    """Best-first branch-and-bound on the grouped cover problem.

    Nodes branch one level (group, mode) at a time on the count taken;
    priority = cost so far + the Lagrangian LP bound on the rest.  Every
    covered node popped updates the incumbent (None for all remaining
    slices completes it); with an admissible bound, the search is exact
    the moment the smallest outstanding priority reaches the incumbent.
    Budget exhaustion returns the best incumbent plus the smallest
    outstanding node priority — a proven lower bound (anytime mode)."""
    t0 = _time.perf_counter()
    tables = _DualTables(groups)
    levels = tables.levels
    L = len(levels)

    inc_counts = _greedy_counts(groups, need)
    inc_cost = (_objective(groups, inc_counts)
                if inc_counts is not None else math.inf)

    root_bound = tables.bound(0, need, groups[0].cap if groups else 0)
    if not math.isfinite(root_bound):
        return inc_counts, 1, inc_cost, inc_counts is not None
    heap: List[Tuple[float, int, int, int, float, float, tuple]] = []
    tie = 0
    heapq.heappush(heap, (root_bound, tie, 0,
                          groups[0].cap if groups else 0, 0.0, 0.0, ()))
    nodes = 0
    best_outstanding = root_bound
    while heap:
        bound, _, li, rem, saved, cost, path = heapq.heappop(heap)
        if bound >= inc_cost:
            # everything left is no better than the incumbent: the
            # incumbent is optimal (priority queue is bound-sorted)
            best_outstanding = inc_cost
            break
        nodes += 1
        if saved >= need and cost < inc_cost:
            # choosing None for every remaining slice completes this
            # node; keep expanding — remaining negative-cost levels
            # (modes both memory-saving and faster) may improve it
            inc_cost = cost
            inc_counts = _path_counts(groups, levels, path)
        if nodes > node_budget or (time_budget > 0 and
                                   _time.perf_counter() - t0 > time_budget):
            best_outstanding = bound     # smallest outstanding priority
            return inc_counts, nodes, best_outstanding, False
        if li == L:
            continue
        gi, sav, ext, first = levels[li]
        if first:
            rem = groups[gi].cap
        if ext <= 0:
            c_max = rem                  # free (or profitable) capacity
        elif sav > 0:
            c_max = min(rem, max(0, int(math.ceil((need - saved) / sav))))
        else:
            c_max = 0
        for c in range(c_max, -1, -1):
            s2 = saved + c * sav
            t2 = cost + c * ext
            b2 = t2 + tables.bound(li + 1, need - s2, rem - c)
            if b2 >= inc_cost or not math.isfinite(b2):
                continue
            tie += 1
            heapq.heappush(heap, (b2, tie, li + 1, rem - c, s2, t2,
                                  path + (c,)))
    else:
        best_outstanding = inc_cost
    if inc_counts is None:
        return None, max(1, nodes), best_outstanding, False
    return inc_counts, max(1, nodes), min(best_outstanding, inc_cost), True


def _path_counts(groups: List[_Group], levels, path: tuple
                 ) -> List[List[int]]:
    counts = [[0] * len(g.modes) for g in groups]
    j_in_group = 0
    for li, c in enumerate(path):
        gi, _, _, first = levels[li]
        if first:
            j_in_group = 0
        counts[gi][j_in_group] = c
        j_in_group += 1
    return counts


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------

def solve_ilp(items: Sequence, need: float, *, time_budget: float = 0.0,
              backend: str = "auto",
              node_budget: int = 2_000_000) -> ILPSolve:
    """Solve the cover problem exactly (or anytime, under a budget).

    `items` duck-types ``search.SliceItem`` (``savings`` /
    ``extra_time`` choice dicts).  ``backend="auto"`` picks scipy's
    milp when importable, else the pure-Python branch-and-bound;
    explicit ``"milp"`` / ``"bnb"`` force one (milp without scipy
    raises ImportError).  ``time_budget > 0`` (seconds) turns on the
    anytime mode: the result carries the incumbent and a proven
    ``lower_bound`` with ``optimal=False`` when the gap stayed open.
    """
    if backend not in ILP_BACKENDS:
        raise ValueError(f"unknown ilp backend {backend!r}; "
                         f"known: {ILP_BACKENDS}")
    if backend == "milp" and not HAVE_SCIPY_MILP:
        raise ImportError(
            "ilp_backend='milp' needs scipy.optimize.milp; install "
            "scipy or use backend='bnb' (the dependency-free fallback)")
    use = backend if backend != "auto" else \
        ("milp" if HAVE_SCIPY_MILP else "bnb")
    n = len(items)
    if need <= 0:
        return ILPSolve([None] * n, 1, 0.0, 0.0, True, use)
    groups = _group_items(items)
    capacity = sum(g.cap * max(g.sav) for g in groups)
    if capacity < need:
        # proven uncoverable: agree with every other backend's
        # max-saving fallback (repair escalates to the same plan)
        return ILPSolve(_max_saving_fallback(items), 1, math.inf,
                        math.inf, True, use)
    if use == "milp":
        counts, nodes, bound, optimal = _solve_milp(groups, need,
                                                    time_budget)
    else:
        counts, nodes, bound, optimal = _solve_bnb(groups, need,
                                                   node_budget,
                                                   time_budget)
    if counts is None:
        # budget ran out before any incumbent: fall back to the greedy
        # cover (feasible — capacity was proven sufficient above)
        g = _greedy_counts(groups, need)
        if g is None:                    # pragma: no cover - capacity>=need
            return ILPSolve(_max_saving_fallback(items), nodes,
                            math.inf, bound, False, use)
        counts = g
    obj = _objective(groups, counts)
    if optimal:
        bound = obj
    return ILPSolve(_decode(items, groups, counts), nodes, obj,
                    min(bound, obj), optimal, use)
