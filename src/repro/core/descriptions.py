"""Operator-level model description (the paper's "MD" input).

OSDP's cost model and search operate on a list of operators, each with
the three memory factors of §3.1 (model-state, activation, extra) and
the parameters needed for the (alpha, beta, gamma) time model.

Granularity: parameters are stored *stacked over layers* (scan-over-
layers), so one `OperatorDesc` describes a whole stacked param group
(e.g. all 126 `ffn_w13` matrices). The paper's finer per-slice plan
granularity (§3.3) is recovered through operator splitting: a
splittable operator with granularity g exposes g independently
decidable slices. For the paper-reproduction benchmarks we also build
per-layer (unstacked) descriptions, matching the paper's n=98..194
operator counts.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

from repro.configs.base import ModelConfig, ShapeConfig

BYTES_PER_PARAM = 2          # bf16 working copy
# mixed-precision AdamW model states per parameter:
#   bf16 param (2) + bf16 grad (2) + fp32 master (4) + fp32 m (4) + fp32 v (4)
STATE_BYTES_PER_PARAM = 16
ACT_BYTES = 2                # bf16 activations

# serving-cache layout constants (must match the runtime caches:
# models.attention.init_kv_cache / models.ssm.init_ssm_cache — the
# agreement is asserted against jax.eval_shape by tests/test_serving.py)
KV_POS_BYTES = 4             # int32 absolute-position tag per cache slot
SSM_STATE_BYTES = 4          # fp32 SSD recurrent state
SSM_CONV_K = 4               # depthwise-conv width (models.ssm.CONV_K)


@dataclass(frozen=True)
class OperatorDesc:
    """One decidable operator (stacked param group)."""

    name: str
    param_count: int               # total elements (all layers in the group)
    flops_per_token: float         # fwd FLOPs attributable to this op, per token
    act_bytes_per_token: float     # live activation bytes per token (no remat)
    splittable: bool = False       # supports §3.3 operator splitting
    decidable: bool = True         # False -> tiny op, pinned to DP
    layers: int = 1                # how many per-layer instances are stacked
    # How many peer layer instances share this op's recompute working
    # set under *explicit per-slice* remat: a remat'd slice keeps
    # 1/remat_layers of its activations live (one layer's worth).  For
    # stacked groups this equals `layers`; per-layer descriptions set it
    # to the model depth so remat'ing layer i doesn't pretend layer i's
    # activations stay live.  None -> `layers` (the legacy global-flag
    # scaling, which divides by the op's own stack depth).
    remat_layers: Optional[int] = None
    # --- serving-cache terms (inference workloads only) ---------------------
    # Decode-time cache bytes this op pins per *admitted sequence*: the
    # token-scaled part (KV cache: grows with the attended context, so
    # it is capped by a sliding window) and the fixed part (SSM/conv
    # recurrent state: O(1) in sequence length).  Both are per layer
    # instance x `layers`, matching the other per-group terms.
    kv_cache_bytes_per_token: float = 0.0
    cache_bytes_per_seq: float = 0.0
    # whether the runtime shards this op's cache over the TP axis
    # (SSD heads are model-sharded; GQA KV heads are replicated)
    cache_tp_sharded: bool = False
    # memory of the transiently *gathered* weight in ZDP mode (the §3.3
    # "gigantic tensor" peak); defaults to the full param bytes.

    @property
    def param_bytes(self) -> int:
        return self.param_count * BYTES_PER_PARAM

    @property
    def state_bytes(self) -> int:
        return self.param_count * STATE_BYTES_PER_PARAM

    @property
    def eff_remat_layers(self) -> int:
        """Live-fraction divisor for an explicitly remat'd slice."""
        return max(1, self.remat_layers
                   if self.remat_layers is not None else self.layers)


@dataclass(frozen=True)
class ModelDescription:
    model: ModelConfig
    shape: ShapeConfig
    operators: List[OperatorDesc]
    # activation bytes per token that must be stored regardless of remat
    # (layer-boundary checkpoints + embeddings)
    resident_act_bytes_per_token: float

    @property
    def n_operators(self) -> int:
        return len(self.operators)

    @property
    def total_params(self) -> int:
        return sum(op.param_count for op in self.operators)

    def decidable(self) -> List[OperatorDesc]:
        return [op for op in self.operators if op.decidable]

    def cache_bytes_per_seq(self, cache_len: int, tp: int = 1,
                            kv_dtype_bytes: int = ACT_BYTES) -> float:
        """Per-device cache bytes ONE admitted sequence pins when its
        attended context is `cache_len` tokens: the KV term scales with
        the context (capped by the arch's sliding window, matching the
        runtime's rolling cache), the SSM state term is O(1).  `tp`
        divides the model-sharded caches (SSD heads); GQA KV heads are
        replicated over the model axis, so the KV term never shrinks.
        `kv_dtype_bytes` rescales the k/v entries for non-bf16 caches
        (the int32 position tag is dtype-independent)."""
        win = self.model.sliding_window
        eff = min(cache_len, win) if win else cache_len
        total = 0.0
        for op in self.operators:
            t = tp if op.cache_tp_sharded else 1
            kv = op.kv_cache_bytes_per_token
            if kv and kv_dtype_bytes != ACT_BYTES:
                # the int32 position tag (one per layer instance in the
                # group) keeps its size; only the k/v entries rescale
                pos = KV_POS_BYTES * max(1, op.layers)
                kv = (kv - pos) / ACT_BYTES * kv_dtype_bytes + pos
            total += (kv * eff + op.cache_bytes_per_seq) / t
        return total


def _matmul_flops(d_in: int, d_out: int) -> float:
    return 2.0 * d_in * d_out


def describe(model: ModelConfig, shape: ShapeConfig,
             per_layer: bool = False) -> ModelDescription:
    """Build the operator list for (model, shape).

    per_layer=True unrolls the stacked groups into per-layer operators
    (the paper's granularity; used by the paper-repro benchmarks).
    """
    d = model.d_model
    L = model.n_layers
    V = model.padded_vocab
    seq = shape.seq_len
    ops: List[OperatorDesc] = []

    def add(name: str, params: int, flops_tok: float, act_tok: float,
            splittable: bool = False, decidable: bool = True,
            layers: int = 1, remat_layers: Optional[int] = None,
            kv_cache_tok: float = 0.0, cache_seq: float = 0.0,
            cache_tp_sharded: bool = False) -> None:
        ops.append(OperatorDesc(name, params, flops_tok, act_tok,
                                splittable, decidable, layers,
                                remat_layers, kv_cache_tok, cache_seq,
                                cache_tp_sharded))

    def add_layer_group(name: str, params_per_layer: int, flops_tok: float,
                        act_tok: float, splittable: bool = False,
                        decidable: bool = True, kv_cache_tok: float = 0.0,
                        cache_seq: float = 0.0,
                        cache_tp_sharded: bool = False) -> None:
        """A group stacked over L layers (or unrolled if per_layer)."""
        if per_layer:
            # each per-layer op gathers its own slice (layers=1) but
            # shares the one-layer-live recompute set with its L peers
            for i in range(L):
                add(f"layer{i}.{name}", params_per_layer, flops_tok,
                    act_tok, splittable, decidable, remat_layers=L,
                    kv_cache_tok=kv_cache_tok, cache_seq=cache_seq,
                    cache_tp_sharded=cache_tp_sharded)
        else:
            add(f"layers.{name}", params_per_layer * L, flops_tok * L,
                act_tok * L, splittable, decidable, layers=L,
                kv_cache_tok=kv_cache_tok * L, cache_seq=cache_seq * L,
                cache_tp_sharded=cache_tp_sharded)

    nm = 2 if model.norm == "layernorm" else 1   # norm scale (+bias)
    # --- embeddings / head --------------------------------------------------
    if model.encoder_only:
        add("embed.tok", d, 0.0, d * ACT_BYTES)   # mask embedding (stub)
    else:
        add("embed.tok", V * d, 0.0, d * ACT_BYTES, splittable=False)
    if (not model.tie_embeddings and model.is_decoder) or model.encoder_only:
        add("head.out", d * V, _matmul_flops(d, V), V * ACT_BYTES,
            splittable=True)
    add("final_norm", nm * d, 0.0, 0.0, decidable=False)

    # --- attention ----------------------------------------------------------
    if model.has_attention:
        qd, kvd = model.q_dim, model.kv_dim
        bias = (qd + 2 * kvd) if model.qkv_bias else 0
        # the op producing K/V owns the KV cache: k + v (bf16) plus the
        # int32 position tag, per attended token per sequence
        add_layer_group("attn_qkv", d * (qd + 2 * kvd) + bias,
                        _matmul_flops(d, qd + 2 * kvd),
                        (qd + 2 * kvd) * ACT_BYTES, splittable=True,
                        kv_cache_tok=2 * kvd * ACT_BYTES + KV_POS_BYTES)
        add_layer_group("attn_out", qd * d, _matmul_flops(qd, d),
                        d * ACT_BYTES, splittable=True)
        # score computation: param-less, pure gamma cost.
        window = model.sliding_window or seq
        eff_ctx = min(seq, window)
        add_layer_group("attn_scores", 0,
                        2.0 * 2.0 * eff_ctx * model.resolved_head_dim
                        * model.n_heads,
                        2 * model.n_heads * 0 * ACT_BYTES,  # flash: O(1) scores
                        decidable=False)
        add_layer_group("attn_norm", nm * d, 0.0, 0.0, decidable=False)

    # --- SSM (Mamba2 SSD) ---------------------------------------------------
    if model.has_ssm:
        di, ns, nh = model.ssm_d_inner, model.ssm_state, model.ssm_n_heads
        in_dim = 2 * di + 2 * ns + nh
        add_layer_group("ssm_in", d * in_dim, _matmul_flops(d, in_dim),
                        in_dim * ACT_BYTES, splittable=True)
        add_layer_group("ssm_out", di * d, _matmul_flops(di, d),
                        d * ACT_BYTES, splittable=True)
        # A, D, dt_bias, gate norm, depthwise conv (K=4) — tiny; the SSD
        # scan op owns the O(1)-per-sequence recurrent cache: the fp32
        # (nh, hd, ns) state plus the (K-1)-step conv tail, both
        # model-sharded with the SSD heads at decode time
        add_layer_group("ssm_small", 3 * nh + di + 4 * (di + 2 * ns),
                        2.0 * 2.0 * model.ssm_chunk * di  # ssd chunk scan
                        + 2.0 * di * ns * 2,
                        di * ACT_BYTES, decidable=False,
                        cache_seq=(di * ns * SSM_STATE_BYTES
                                   + (SSM_CONV_K - 1) * (di + 2 * ns)
                                   * SSM_STATE_BYTES),
                        cache_tp_sharded=True)
        add_layer_group("ssm_norm", d, 0.0, 0.0, decidable=False)

    # --- FFN / MoE ----------------------------------------------------------
    ff_mult = 3 if model.act == "swiglu" else 2
    if model.is_moe:
        E, k, ff = model.moe_experts, model.moe_top_k, model.d_ff
        add_layer_group("moe_router", d * E, _matmul_flops(d, E),
                        E * ACT_BYTES, decidable=False)
        # experts: flops per token counts only the top-k active experts
        add_layer_group("moe_w13", E * (ff_mult - 1) * d * ff,
                        k * _matmul_flops(d, (ff_mult - 1) * ff),
                        k * (ff_mult - 1) * ff * ACT_BYTES, splittable=True)
        add_layer_group("moe_w2", E * d * ff,
                        k * _matmul_flops(ff, d),
                        k * d * ACT_BYTES, splittable=True)
        if model.moe_dense_residual:
            dff = model.moe_dense_d_ff or ff
            add_layer_group("dense_w13", (ff_mult - 1) * d * dff,
                            _matmul_flops(d, (ff_mult - 1) * dff),
                            (ff_mult - 1) * dff * ACT_BYTES, splittable=True)
            add_layer_group("dense_w2", dff * d, _matmul_flops(dff, d),
                            d * ACT_BYTES, splittable=True)
    elif model.d_ff:
        ff = model.d_ff
        add_layer_group("ffn_w13", (ff_mult - 1) * d * ff,
                        _matmul_flops(d, (ff_mult - 1) * ff),
                        (ff_mult - 1) * ff * ACT_BYTES, splittable=True)
        add_layer_group("ffn_w2", ff * d, _matmul_flops(ff, d),
                        d * ACT_BYTES, splittable=True)
    if model.d_ff or model.is_moe:
        add_layer_group("ffn_norm", nm * d, 0.0, 0.0, decidable=False)

    # remat stores one d_model activation per layer boundary + embedding out
    resident = (L + 1) * d * ACT_BYTES
    return ModelDescription(model, shape, ops, resident)


def sanity_check(desc: ModelDescription) -> None:
    got = desc.total_params
    want = desc.model.param_count()
    # the closed-form and the operator sum must agree (within norm epsilon)
    assert abs(got - want) <= max(64, 0.001 * want), (
        f"{desc.model.name}: operator params {got} != closed-form {want}")
