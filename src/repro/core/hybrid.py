"""Hybrid 3D parallelism as a first-class plan space (paper Fig. 5/6).

The paper's strongest configuration replaces the DP dimension of 3D
parallelism (DP x TP x PP) with the OSDP search — "3D+OSDP".  This
module provides the pieces that make that configuration searchable by
`core.search.search_hybrid` instead of living in a one-off figure
script:

  * `Factorization`   — one (dp, tp, pp) point of the device grid,
  * `factorizations`  — the exhaustive sweep dp * tp * pp == n,
  * TP / PP cost terms expressed through the same ring-collective
    machinery as `cost_model` (CostEnv alpha/beta/gamma constants):
      TP — Megatron column+row pairs: 2 activation all-reduces per
           layer of the (b_local, s, d) tensor, each all-reduce a
           reduce-scatter + all-gather ring pass,
      PP — GPipe microbatching: bubble (pp-1)/(m+pp-1) and
           stage-boundary activation sends,
  * `slice_description` — the 1/(tp*pp) model residue the DP-dimension
    solvers (dfs/knapsack/greedy) decide over,
  * `HybridPlan`      — `core.plan.Plan`'s hybrid sibling: the chosen
    factorization, GPipe stage boundaries, and the per-operator
    DP/ZDP decisions of the inner search.

The DP residue inherits the full 4-mode decision axis: with
`OSDPConfig(checkpointing="selective")` the inner Scheduler searches
remat per slice jointly with DP/ZDP over the residue (its `Decision`s
carry explicit remat bits), and the factorization sweep's compute-only
throughput bound drops the 1.30 recompute factor so it stays
admissible for mixed-remat plans.

The activation collectives are charged in the bandwidth regime
(alpha dropped): the messages are MB-scale, so (n-1)*alpha is noise
next to the beta term, and dropping it keeps the hybrid rows directly
comparable with the analytical baselines.  The DP-dimension costs
coming out of `cost_model.plan_cost` keep their full alpha+beta
treatment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import DeviceInfo, MeshConfig
from repro.cluster.topology import ClusterSpec
from repro.core.cost_model import (DP, Decision, PlanCost, _ring_time,
                                   count_remat_slices)
from repro.core.descriptions import ACT_BYTES, ModelDescription

HYBRID_AXES = ("data", "model", "pipe")


@dataclass(frozen=True)
class Factorization:
    """One point of the (dp, tp, pp) device-grid sweep."""

    dp: int
    tp: int
    pp: int

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def is_pure_dp(self) -> bool:
        return self.tp == 1 and self.pp == 1

    def mesh_config(self) -> MeshConfig:
        """3-axis logical mesh: data (DP/ZDP) x model (TP) x pipe (PP)."""
        return MeshConfig((self.dp, self.tp, self.pp), HYBRID_AXES)

    def __str__(self) -> str:
        return f"(dp={self.dp}, tp={self.tp}, pp={self.pp})"


def factorizations(n_devices: int, max_tp: int = 0,
                   max_pp: int = 0) -> List[Factorization]:
    """All (dp, tp, pp) with dp * tp * pp == n_devices, exhaustively.

    `max_tp` / `max_pp` cap the respective axes (0 = uncapped); TP is
    usually capped at the per-node device count so its all-reduces stay
    on the fast intra-node links.
    """
    out: List[Factorization] = []
    for tp in range(1, n_devices + 1):
        if n_devices % tp or (max_tp and tp > max_tp):
            continue
        rest = n_devices // tp
        for pp in range(1, rest + 1):
            if rest % pp or (max_pp and pp > max_pp):
                continue
            out.append(Factorization(rest // pp, tp, pp))
    return out


def slice_description(desc: ModelDescription, tp: int,
                      pp: int) -> ModelDescription:
    """The 1/(tp*pp) per-device model residue the DP dimension sees.

    TP divides every weight across the model axis; PP gives each
    pipeline stage 1/pp of the layers.  The DP-dimension search then
    decides DP/ZDP per operator over this residue exactly as in the
    flat case.
    """
    scale = 1.0 / (tp * pp)
    if scale == 1.0:
        return desc
    ops = [dataclasses.replace(
        op, param_count=int(op.param_count * scale),
        flops_per_token=op.flops_per_token * scale,
        act_bytes_per_token=op.act_bytes_per_token * scale)
        for op in desc.operators]
    return dataclasses.replace(
        desc, operators=ops,
        resident_act_bytes_per_token=(
            desc.resident_act_bytes_per_token * scale))


def stage_bounds(n_layers: int, pp: int) -> Tuple[int, ...]:
    """GPipe stage boundaries: pp near-equal contiguous layer ranges.

    Returns pp+1 monotone layer indices; stage s owns layers
    [bounds[s], bounds[s+1]).
    """
    pp = max(1, min(pp, n_layers))
    return tuple(round(n_layers * s / pp) for s in range(pp + 1))


# ---------------------------------------------------------------------------
# TP / PP cost terms (same alpha/beta machinery as cost_model)
# ---------------------------------------------------------------------------

def activation_bytes(desc: ModelDescription, batch_local: int) -> float:
    """Bytes of one (b_local, s, d) boundary activation tensor."""
    return batch_local * desc.shape.seq_len * desc.model.d_model * ACT_BYTES


def tp_activation_time(desc: ModelDescription, device: DeviceInfo,
                       batch_local: int, tp: int,
                       cluster: Optional[ClusterSpec] = None) -> float:
    """Megatron TP activation collectives per step.

    Each layer runs a column-parallel then a row-parallel pair, i.e.
    2 all-reduces of the (b_local, s, d) activation; an all-reduce is
    a reduce-scatter + all-gather, two ring passes over the `model`
    axis (bandwidth regime — see module docstring).

    With a `cluster`, TP occupies the *innermost* `tp` devices of the
    hierarchy and the ring is priced hierarchically over the levels it
    spans — a TP group reaching past the node/pod boundary pays that
    level's (slower) links instead of the flat `ici_bw` the legacy
    path charged unconditionally.
    """
    if tp <= 1:
        return 0.0
    act = activation_bytes(desc, batch_local)
    if cluster is not None:
        _, beta = cluster.inner_span_terms(tp)
        per_allreduce = 2 * act * beta
    else:
        per_allreduce = 2 * _ring_time(act, tp, 0.0, device.ici_bw)
    return 2 * max(1, desc.model.n_layers) * per_allreduce


def pp_bubble_fraction(pp: int, micro: int) -> float:
    """GPipe pipeline bubble: (pp-1)/(m+pp-1) of the step is idle."""
    if pp <= 1:
        return 0.0
    return (pp - 1) / (micro + pp - 1)


def pp_boundary_time(desc: ModelDescription, device: DeviceInfo,
                     batch_local: int, pp: int, micro: int,
                     cluster: Optional[ClusterSpec] = None) -> float:
    """Stage-boundary activation sends: each of the `micro` microbatches
    crosses pp-1 boundaries carrying its share of the activation.

    With a `cluster`, PP is placed across the *outermost* (slowest)
    levels — pipeline traffic is point-to-point and tolerates slow
    links best — and boundary sends are priced at the bandwidth of the
    innermost level the pp-way split reaches."""
    if pp <= 1:
        return 0.0
    act = activation_bytes(desc, batch_local)
    bw = (cluster.pp_boundary_bandwidth(pp) if cluster is not None
          else device.ici_bw)
    return (pp - 1) * micro * (act / micro) / bw


def hybrid_step_time(base_time: float, desc: ModelDescription,
                     device: DeviceInfo, batch: int, f: Factorization,
                     micro: int = 8,
                     cluster: Optional[ClusterSpec] = None) -> float:
    """Step time of the full 3D configuration.

    `base_time` is the DP-dimension step time of the 1/(tp*pp) residue
    (out of `plan_cost` / the inner search); TP collectives add to it,
    then the GPipe bubble stretches the whole step and the boundary
    sends land on the critical path.

    When the boundary level declares a comm/compute overlap factor,
    each microbatch's boundary send hides under the next microbatch's
    in-flight work: per microbatch the exposed send is
    max(0, send - ov * t/micro), which totals max(0, pp_t - ov * t)
    over the step.  At overlap 0 this is exactly the serial `+= pp_t`.
    TP activation all-reduces sit on the layer critical path (each
    layer's output feeds the next) and stay serial."""
    b_local = max(1, batch // f.dp)
    t = base_time + tp_activation_time(desc, device, b_local, f.tp,
                                       cluster)
    if f.pp > 1:
        t /= (1.0 - pp_bubble_fraction(f.pp, micro))
        pp_t = pp_boundary_time(desc, device, b_local, f.pp, micro,
                                cluster)
        ov = (cluster.pp_boundary_overlap(f.pp) if cluster is not None
              else 0.0)
        t += pp_t if ov <= 0.0 else max(0.0, pp_t - ov * t)
    return t


# ---------------------------------------------------------------------------
# HybridPlan
# ---------------------------------------------------------------------------

@dataclass
class HybridPlan:
    """A 3D(+OSDP) execution plan: core.plan.Plan's hybrid sibling.

    The (dp, tp, pp) factorization and GPipe stage boundaries come out
    of `core.search.search_hybrid`; `decisions` is the per-operator
    DP/ZDP plan of the inner search over the DP dimension (the paper's
    "3D+OSDP" when that search is OSDP, plain 3D when it is forced
    ZDP).  `cost` is the hybrid-adjusted PlanCost (TP collectives +
    pipeline bubble folded into time; memory is the per-device residue
    estimate of the inner search).
    """

    desc: ModelDescription
    device: DeviceInfo
    factorization: Factorization
    stage_bounds: Tuple[int, ...]
    decisions: Dict[str, Decision]
    cost: PlanCost
    batch_size: int
    micro: int
    feasible: bool
    dp_strategy: str                    # inner solver / forced mode label
    inner: Optional[object] = None      # core.search.SearchResult
    swept: List[Tuple[Factorization, float]] = field(default_factory=list)
    # (factorization, throughput) per feasible sweep point
    cluster: Optional[ClusterSpec] = None   # topology the plan was priced on

    @property
    def dp(self) -> int:
        return self.factorization.dp

    @property
    def tp(self) -> int:
        return self.factorization.tp

    @property
    def pp(self) -> int:
        return self.factorization.pp

    def mesh_config(self) -> MeshConfig:
        return self.factorization.mesh_config()

    def stage_layers(self) -> List[Tuple[int, int]]:
        """[(first_layer, one_past_last)] per pipeline stage."""
        return [(self.stage_bounds[s], self.stage_bounds[s + 1])
                for s in range(len(self.stage_bounds) - 1)]

    def summary(self) -> str:
        n_zdp = sum(1 for d in self.decisions.values()
                    if d.uniform() not in (DP, None))
        n_mixed = sum(1 for d in self.decisions.values()
                      if d.uniform() is None)
        n_remat = count_remat_slices(self.decisions)
        remat = (f" remat_slices={n_remat}"
                 if any(d.remat is not None
                        for d in self.decisions.values()) else "")
        lines = [
            f"hybrid[{self.desc.model.name}] {self.factorization} "
            f"dp_strategy={self.dp_strategy} "
            f"batch={self.batch_size} micro={self.micro} "
            f"{'feasible' if self.feasible else 'INFEASIBLE'}",
            f"  stages: {self.stage_layers()}",
            f"  ops={len(self.decisions)} zdp={n_zdp} "
            f"mixed={n_mixed}{remat}",
            f"  est memory/device = {self.cost.memory / 2**30:.2f} GiB "
            f"(peak {self.cost.peak_memory / 2**30:.2f})",
            f"  est step time = {self.cost.time * 1e3:.2f} ms "
            f"(dp-dim comm {self.cost.comm_time * 1e3:.2f})",
            f"  est throughput = {self.cost.throughput:.0f} tok/s",
        ]
        return "\n".join(lines)
