"""User-facing OSDP API — the paper's Figure 3 one-call wrap.

FairScale:    model = FSDP(model)
OSDP (paper): model = OSDP(model, device_information)
Here:         plan  = osdp(model_cfg, shape, mesh, memory_limit=...)

returning a `Plan` whose decisions drive parameter shardings; models
built through `repro.models.registry.build_model(run, plan)` execute
it. `force_mode="ZDP"` reproduces plain FSDP, `force_mode="DP"` plain
data parallelism — the baselines the paper compares against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

from repro.calibrate.profile import CalibrationProfile
from repro.cluster.topology import ClusterSpec
from repro.configs.base import (DeviceInfo, MeshConfig, ModelConfig,
                                OSDPConfig, RunConfig, ShapeConfig,
                                SINGLE_POD_MESH)
from repro.core.cost_model import (CostEnv, Decision, PlanCost,
                                   PlanEvaluator, RequestClass,
                                   RequestClassMix, ServingWorkload)
from repro.core.descriptions import ModelDescription, describe
from repro.core.hybrid import Factorization, HybridPlan
from repro.core.plan import Plan, make_plan
from repro.core.search import FleetPlan, ServePlan
from repro.core import search as _search


def osdp(model: ModelConfig,
         shape: ShapeConfig,
         mesh: Optional[MeshConfig] = None,
         *,
         memory_limit_gib: float = 16.0,
         device: Optional[DeviceInfo] = None,
         search: str = "dfs",
         operator_splitting: bool = True,
         slice_granularity: int = 4,
         checkpointing: Union[bool, str] = True,
         force_mode: Optional[str] = None,
         ilp_time_budget_s: float = 0.0,
         ilp_backend: str = "auto",
         cluster: Optional["ClusterSpec"] = None,
         profile: Optional["CalibrationProfile"] = None) -> Plan:
    """Search the optimal sharded-data-parallel plan (paper Alg. 1).

    `search` picks the cover solver: "dfs" (paper Algorithm 1),
    "knapsack", "greedy", or "ilp" — the exact integer-program oracle
    (`core.ilp`); with `ilp_time_budget_s > 0` the ilp runs anytime,
    returning the incumbent plus a proven bound
    (`plan.search.lower_bound` / `.proven_optimal`), and `ilp_backend`
    forces scipy's "milp" or the dependency-free "bnb".

    `checkpointing` accepts the legacy global flags True / False, or
    "selective" to co-optimize remat per slice with the sharding mode
    (the 4-mode axis: DP/ZDP x remat/no-remat) — the returned plan's
    `Decision.remat` then carries the per-slice bits and compiles to a
    matching `jax.checkpoint` policy via `models.registry.build_model`.

    `cluster` (a `repro.cluster.ClusterSpec`) makes the search
    topology-aware: collectives are priced with hierarchical rings,
    the sharding axis widens to level-k ZDP, and heterogeneous device
    groups bound feasibility at the worst group.  Without one, the
    flat (device, mesh) model applies (mesh defaults to
    SINGLE_POD_MESH).

    `profile` (a `repro.calibrate.CalibrationProfile`, from
    `repro calibrate`) prices with measured constants — efficiency
    curve, fitted link alpha/bandwidth, fitted recompute factor;
    None keeps the scalar datasheet path byte-identical.
    """
    if mesh is None:
        mesh = (cluster.mesh_config() if cluster is not None
                else SINGLE_POD_MESH)
    cfg = OSDPConfig(
        enabled=True,
        memory_limit_bytes=memory_limit_gib * 2**30,
        search=search,
        operator_splitting=operator_splitting,
        default_slice_granularity=slice_granularity,
        checkpointing=checkpointing,
        force_mode=force_mode,
        ilp_time_budget_s=ilp_time_budget_s,
        ilp_backend=ilp_backend,
    )
    run = RunConfig(model=model, shape=shape, mesh=mesh, osdp=cfg)
    return make_plan(run, device, cluster=cluster, profile=profile)


def search_hybrid(model: Union[ModelConfig, ModelDescription],
                  shape: Optional[ShapeConfig] = None,
                  *,
                  n_devices: Optional[int] = None,
                  memory_limit_gib: float = 16.0,
                  device: Optional[DeviceInfo] = None,
                  search: str = "dfs",
                  operator_splitting: bool = True,
                  slice_granularity: int = 4,
                  checkpointing: Union[bool, str] = True,
                  force_mode: Optional[str] = None,
                  ilp_time_budget_s: float = 0.0,
                  ilp_backend: str = "auto",
                  micro: int = 8,
                  max_tp: int = 0,
                  max_pp: int = 0,
                  batch_candidates: Optional[Sequence[int]] = None,
                  candidates: Optional[Sequence[Factorization]] = None,
                  cluster: Optional[ClusterSpec] = None,
                  profile: Optional["CalibrationProfile"] = None,
                  ) -> HybridPlan:
    """Search the hybrid 3D(+OSDP) plan space (paper Fig. 5/6 rows).

    Sweeps the (dp, tp, pp) factorizations of `n_devices`; inside
    each, the DP dimension runs the OSDP Scheduler (Algorithm 1) over
    the per-device model residue — or a forced uniform mode:
    `force_mode="ZDP"` is plain DeepSpeed-style 3D, `force_mode="DP"`
    TP/PP with replicated data parallelism.  The default (no force) is
    the paper's strongest configuration, 3D+OSDP.

    `model` may be a ModelConfig (paired with `shape`) or a prebuilt
    ModelDescription (e.g. the per-layer inconsistent models of the
    paper's I&C family).

    With a `cluster`, placement is topology-aware: TP on the
    innermost levels, PP across the outermost, the DP residue searched
    over the remaining hierarchy (level-k ZDP enabled); `n_devices`
    defaults to the cluster size.
    """
    if isinstance(model, ModelDescription):
        desc = model
    else:
        if shape is None:
            raise TypeError("shape is required when model is a ModelConfig")
        desc = describe(model, shape)
    if n_devices is None:
        if cluster is None:
            raise TypeError("n_devices is required without a cluster")
        n_devices = cluster.n_devices
    cfg = OSDPConfig(
        enabled=True,
        memory_limit_bytes=memory_limit_gib * 2**30,
        search=search,
        operator_splitting=operator_splitting,
        default_slice_granularity=slice_granularity,
        allow_pod_hierarchical=cluster is not None,
        checkpointing=checkpointing,
        force_mode=force_mode,
        ilp_time_budget_s=ilp_time_budget_s,
        ilp_backend=ilp_backend,
    )
    dev = device or (cluster.device if cluster is not None
                     else DeviceInfo())
    return _search.search_hybrid(
        desc, dev, n_devices, cfg,
        batch_candidates=batch_candidates, micro=micro,
        candidates=candidates, max_tp=max_tp, max_pp=max_pp,
        cluster=cluster, profile=profile)


def search_serve(model: ModelConfig,
                 *,
                 prompt_len: int = 512,
                 decode_len: int = 128,
                 mesh: Optional[MeshConfig] = None,
                 n_devices: int = 1,
                 memory_limit_gib: float = 16.0,
                 device: Optional[DeviceInfo] = None,
                 search: str = "dfs",
                 operator_splitting: bool = True,
                 slice_granularity: int = 4,
                 force_mode: Optional[str] = None,
                 ilp_time_budget_s: float = 0.0,
                 ilp_backend: str = "auto",
                 max_slots: int = 512,
                 slot_candidates: Optional[Sequence[int]] = None,
                 cluster: Optional[ClusterSpec] = None,
                 mix: Optional[RequestClassMix] = None,
                 profile: Optional["CalibrationProfile"] = None) -> ServePlan:
    """Search the optimal serving configuration (inference OSDP).

    Same §3.1 trade as training — memory vs utilization per operator
    under the device budget — on the inference workload: the per-op
    KV/SSM caches of every admitted sequence are the dominant memory
    term, so the search jointly picks the per-slice sharding AND the
    max-concurrency admission limit that the continuous-batching
    engine (`repro.serving.engine.ContinuousEngine`) enforces.  The
    plan is scored at both phase shapes: the compute-bound prefill
    (batch x prompt_len) and the bandwidth-bound decode (batch x 1,
    floored by streaming weights + live caches from HBM).

    `mesh` defaults to an (n_devices, 1) data mesh (or the cluster's);
    `force_mode="DP"` reproduces the unplanned replicated engine,
    `force_mode="ZDP"` weight-sharded serving without the search.

    A `mix` (`RequestClassMix`) replaces (`prompt_len`, `decode_len`)
    with weighted request classes priced per class; a single-class mix
    is an exact alias of the legacy workload.
    """
    if mesh is None:
        mesh = (cluster.mesh_config() if cluster is not None
                else MeshConfig((n_devices, 1), ("data", "model")))
    cfg = OSDPConfig(
        enabled=True,
        memory_limit_bytes=memory_limit_gib * 2**30,
        search=search,
        operator_splitting=operator_splitting,
        default_slice_granularity=slice_granularity,
        checkpointing=False,
        force_mode=force_mode,
        ilp_time_budget_s=ilp_time_budget_s,
        ilp_backend=ilp_backend,
    )
    env = CostEnv(device or (cluster.device if cluster is not None
                             else DeviceInfo()),
                  mesh, checkpointing=False, train=False, cluster=cluster,
                  profile=profile)
    workload = (mix if mix is not None
                else ServingWorkload(prompt_len, decode_len))
    return _search.search_serve(
        model, workload, env, cfg,
        max_slots=max_slots, slot_candidates=slot_candidates)


def search_fleet(model: ModelConfig,
                 *,
                 mix: Optional[RequestClassMix] = None,
                 classes: Optional[Sequence[RequestClass]] = None,
                 cluster: Optional[ClusterSpec] = None,
                 n_devices: int = 1,
                 memory_limit_gib: float = 16.0,
                 device: Optional[DeviceInfo] = None,
                 search: str = "dfs",
                 operator_splitting: bool = True,
                 slice_granularity: int = 4,
                 force_mode: Optional[str] = None,
                 max_slots: int = 512,
                 replica_candidates: Optional[Sequence[int]] = None,
                 strategy: str = "slo") -> FleetPlan:
    """Search a fleet-scale serving configuration (multi-replica OSDP).

    Partitions the `cluster` (one pool per heterogeneous
    `DeviceGroup`, else the whole fleet) into independent replica
    groups and searches replica count x per-group sharding plan x
    per-class routing jointly, returning a `FleetPlan`: per-group
    `ServePlan`s, a class -> group routing table, and per-class
    admission limits the class-aware router enforces.

    The workload is a `RequestClassMix` (pass `mix`, or `classes` as a
    sequence of `RequestClass`); `strategy="uniform"` is the
    heterogeneity-blind baseline (identical replicas, every class
    routed everywhere) the fleet benchmark compares against."""
    if mix is None:
        if not classes:
            raise TypeError("search_fleet needs mix= or classes=")
        mix = RequestClassMix(tuple(classes))
    elif classes:
        raise TypeError("pass mix= or classes=, not both")
    if cluster is None:
        cluster = ClusterSpec.from_device(device or DeviceInfo(),
                                          n_devices)
    cfg = OSDPConfig(
        enabled=True,
        memory_limit_bytes=memory_limit_gib * 2**30,
        search=search,
        operator_splitting=operator_splitting,
        default_slice_granularity=slice_granularity,
        checkpointing=False,
        force_mode=force_mode,
    )
    return _search.search_fleet(
        model, mix, cluster, cfg, max_slots=max_slots,
        replica_candidates=replica_candidates, strategy=strategy)


def rescore_serve(model: ModelConfig, plan: ServePlan,
                  *,
                  slots: Optional[int] = None,
                  mesh: Optional[MeshConfig] = None,
                  n_devices: int = 1,
                  memory_limit_gib: float = 16.0,
                  device: Optional[DeviceInfo] = None,
                  cluster: Optional[ClusterSpec] = None):
    """Re-score an existing `ServePlan` on a different cluster:
    (ServingCost, feasible).

    The resilience supervisor's first question after a device loss —
    "does the stale plan still fit the survivors?" — answered with the
    analytical cost model only (no search).  Pass the degraded
    `cluster` (from `ClusterSpec.degrade`); the memory limit tightens
    to the surviving worst group and the collective terms re-price on
    the shrunken topology."""
    if mesh is None:
        mesh = (cluster.mesh_config() if cluster is not None
                else MeshConfig((n_devices, 1), ("data", "model")))
    cfg = OSDPConfig(
        enabled=True,
        memory_limit_bytes=memory_limit_gib * 2**30,
        checkpointing=False,
    )
    env = CostEnv(device or (cluster.device if cluster is not None
                             else DeviceInfo()),
                  mesh, checkpointing=False, train=False, cluster=cluster)
    return _search.rescore_serve_plan(
        model, plan.workload, plan.decisions, env, cfg,
        plan.slots_per_device if slots is None else slots)


def evaluate_plan(model: Union[ModelConfig, ModelDescription],
                  decisions: Dict[str, Decision],
                  shape: Optional[ShapeConfig] = None,
                  mesh: Optional[MeshConfig] = SINGLE_POD_MESH,
                  *,
                  global_batch: Optional[int] = None,
                  device: Optional[DeviceInfo] = None,
                  checkpointing: bool = True,
                  train: bool = True,
                  cluster: Optional[ClusterSpec] = None,
                  profile: Optional["CalibrationProfile"] = None) -> PlanCost:
    """Score an explicit plan through the vectorized PlanEvaluator.

    Same result as `cost_model.plan_cost` (to float-summation order),
    but table-driven: callers scoring many plans against one
    (model, mesh) — schedulers, what-if tooling, external autotuners —
    should hold a `PlanEvaluator` directly; this one-call wrap is for
    one-off scoring.
    """
    if not isinstance(checkpointing, bool):
        raise ValueError(
            "evaluate_plan scores a FIXED plan, so checkpointing must "
            "be the global bool default for inherit slices — encode "
            "selective remat in the decisions' Decision.remat bits")
    if isinstance(model, ModelDescription):
        desc = model
    else:
        if shape is None:
            raise TypeError("shape is required when model is a ModelConfig")
        desc = describe(model, shape)
    if cluster is not None and mesh is SINGLE_POD_MESH:
        mesh = None          # derive the mesh from the cluster spec
    env = CostEnv(device or (cluster.device if cluster is not None
                             else DeviceInfo()), mesh,
                  checkpointing=checkpointing, train=train,
                  cluster=cluster, profile=profile)
    ev = PlanEvaluator.for_decisions(desc, env, decisions)
    modes = ev.modes_from_decisions(decisions)
    return ev.plan_cost(modes, global_batch or desc.shape.global_batch)


def fsdp_baseline(model: ModelConfig, shape: ShapeConfig,
                  mesh: MeshConfig = SINGLE_POD_MESH, **kw) -> Plan:
    """All-ZDP: the FairScale/DeepSpeed ZeRO-3 baseline."""
    return osdp(model, shape, mesh, force_mode="ZDP", **kw)


def dp_baseline(model: ModelConfig, shape: ShapeConfig,
                mesh: MeshConfig = SINGLE_POD_MESH, **kw) -> Plan:
    """All-DP: the PyTorch-DDP baseline."""
    return osdp(model, shape, mesh, force_mode="DP", **kw)
