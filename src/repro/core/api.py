"""User-facing OSDP API — the paper's Figure 3 one-call wrap.

FairScale:    model = FSDP(model)
OSDP (paper): model = OSDP(model, device_information)
Here:         plan  = osdp(model_cfg, shape, mesh, memory_limit=...)

returning a `Plan` whose decisions drive parameter shardings; models
built through `repro.models.registry.build_model(run, plan)` execute
it. `force_mode="ZDP"` reproduces plain FSDP, `force_mode="DP"` plain
data parallelism — the baselines the paper compares against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import (DeviceInfo, MeshConfig, ModelConfig,
                                OSDPConfig, RunConfig, ShapeConfig,
                                SINGLE_POD_MESH)
from repro.core.plan import Plan, make_plan


def osdp(model: ModelConfig,
         shape: ShapeConfig,
         mesh: MeshConfig = SINGLE_POD_MESH,
         *,
         memory_limit_gib: float = 16.0,
         device: Optional[DeviceInfo] = None,
         search: str = "dfs",
         operator_splitting: bool = True,
         slice_granularity: int = 4,
         checkpointing: bool = True,
         force_mode: Optional[str] = None) -> Plan:
    """Search the optimal sharded-data-parallel plan (paper Alg. 1)."""
    cfg = OSDPConfig(
        enabled=True,
        memory_limit_bytes=memory_limit_gib * 2**30,
        search=search,
        operator_splitting=operator_splitting,
        default_slice_granularity=slice_granularity,
        checkpointing=checkpointing,
        force_mode=force_mode,
    )
    run = RunConfig(model=model, shape=shape, mesh=mesh, osdp=cfg)
    return make_plan(run, device)


def fsdp_baseline(model: ModelConfig, shape: ShapeConfig,
                  mesh: MeshConfig = SINGLE_POD_MESH, **kw) -> Plan:
    """All-ZDP: the FairScale/DeepSpeed ZeRO-3 baseline."""
    return osdp(model, shape, mesh, force_mode="ZDP", **kw)


def dp_baseline(model: ModelConfig, shape: ShapeConfig,
                mesh: MeshConfig = SINGLE_POD_MESH, **kw) -> Plan:
    """All-DP: the PyTorch-DDP baseline."""
    return osdp(model, shape, mesh, force_mode="DP", **kw)
