"""Operator splitting (§3.3) as sequential chunked computation.

Two realizations of the paper's slice-and-sum (see DESIGN.md §3):

  * `chunked_matmul` — `lax.scan` over contraction-dim slices. XLA's
    buffer liveness keeps only one (gathered) weight slice plus the
    accumulator alive, bounding the peak to size/g + accumulator. This
    is the plan-uniform-mode path (mixed-mode plans get per-segment
    arrays via `sharding.specs.seg_matmul` instead).
  * the Pallas `split_matmul` kernel (kernels/split_matmul.py) — the
    same idea pushed to the on-chip level: VMEM block tiling with a
    K-grid accumulator, so at most one (bk, bn) weight tile is resident.

`chunked_ffn` applies the scan form to a whole SwiGLU FFN so the
(tokens, d_ff) hidden never fully materializes either.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def chunked_matmul(x: jax.Array, w: jax.Array, granularity: int,
                   accum_dtype=jnp.float32) -> jax.Array:
    """y = x @ w computed as sum over `granularity` contraction slices.

    x: (..., K), w: (K, N) -> (..., N). K must be divisible by g (the
    caller pads or lowers g otherwise).
    """
    k = x.shape[-1]
    g = max(1, granularity)
    if g == 1 or k % g != 0:
        return x @ w
    c = k // g
    xs = x.reshape(*x.shape[:-1], g, c)
    xs = jnp.moveaxis(xs, -2, 0)                   # (g, ..., c)
    ws = w.reshape(g, c, w.shape[-1])              # (g, c, N)

    def body(acc, slc):
        xg, wg = slc
        return acc + jnp.matmul(
            xg, wg, preferred_element_type=accum_dtype), None

    init = jnp.zeros((*x.shape[:-1], w.shape[-1]), accum_dtype)
    acc, _ = jax.lax.scan(body, init, (xs, ws))
    return acc.astype(x.dtype)


def chunked_ffn(x: jax.Array, w13: jax.Array, w2: jax.Array,
                granularity: int, act: str = "swiglu") -> jax.Array:
    """SwiGLU/GeLU FFN with the d_ff dimension processed in g chunks.

    x:(...,d) w13:(d,2*ff|ff) w2:(ff,d). Peak hidden activation is
    ff/g wide; outputs accumulate in fp32.
    """
    ff = w2.shape[0]
    g = max(1, granularity)
    if g == 1 or ff % g != 0:
        h = _act(x @ w13, act)
        return (h @ w2).astype(x.dtype)
    c = ff // g
    two = 2 if act == "swiglu" else 1
    w13s = w13.reshape(w13.shape[0], two, g, c)    # split ff dim
    w13s = jnp.moveaxis(w13s, 2, 0)                # (g, d, two, c)
    w2s = w2.reshape(g, c, w2.shape[-1])

    def body(acc, slc):
        w13g, w2g = slc
        hg = _act(jnp.tensordot(x, w13g.reshape(w13g.shape[0], two * c),
                                axes=1), act, chunk=c)
        return acc + jnp.matmul(hg, w2g,
                                preferred_element_type=jnp.float32), None

    init = jnp.zeros((*x.shape[:-1], w2.shape[-1]), jnp.float32)
    acc, _ = jax.lax.scan(body, init, (w13s, w2s))
    return acc.astype(x.dtype)


def _act(h: jax.Array, act: str, chunk: Optional[int] = None) -> jax.Array:
    if act == "swiglu":
        c = chunk if chunk is not None else h.shape[-1] // 2
        g1, g3 = h[..., :c], h[..., c:]
        return jax.nn.silu(g1.astype(jnp.float32)).astype(h.dtype) * g3
    return jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
