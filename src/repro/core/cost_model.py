"""OSDP cost model — the paper's §3.1 Profiler, on TPU constants.

Memory:
    M_i(p_i, b) = M_model_i / (1 or N_shard) + b * M_act_i + M_extra_i

Time ((alpha, beta, gamma) model, ring collectives):
    T_i(p_i, b) = k (N-1)(alpha + S_i beta / N) + b * gamma_i
with k = 2 for DP (all-reduce = reduce-scatter + all-gather) and
k = 3 for ZDP (two all-gathers + one reduce-scatter); +1 for ZDP when
activation checkpointing forces a third parameter gather before the
recompute pass (§4.3).

Activation checkpointing (remat) is a per-slice decision, not only a
global switch: a `Decision` may carry explicit remat bits per slice
(the 4-mode axis, DP/ZDP x remat/no-remat).  A remat'd slice trades
its live activations (b * M_act_i -> /remat_layers) for the ~30%
recompute compute term and — in ZDP modes — the §4.3 4th parameter
gather; a no-remat slice keeps its activations and skips both costs.
`Decision.remat is None` reproduces the legacy global behaviour of
`CostEnv.checkpointing` byte-for-byte.  The full formula set lives in
docs/cost_model.md.

Beyond-paper additions, all flagged explicitly:
  * ZDP_POD — hierarchical sharding across only the in-pod `data` axis:
    memory /N_pod-local, collectives stay on fast ICI.
  * per-mode gathered-weight peak (M_extra): in ZDP the un-sharded
    weight must transiently exist; operator splitting divides it by g.
  * MoE awareness: expert FLOPs scale with top-k, not E.
  * per-slice selective remat (this module + core/search.py), above.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import cached_property
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.calibrate.profile import CalibrationProfile
from repro.configs.base import DeviceInfo, MeshConfig
from repro.cluster.topology import ClusterSpec
from repro.core.descriptions import (ACT_BYTES, BYTES_PER_PARAM,
                                     ModelDescription, OperatorDesc,
                                     STATE_BYTES_PER_PARAM)

# parallel modes -------------------------------------------------------------
#
# Sharding generalizes to "ZDP at level k" of a hierarchical
# `ClusterSpec` (see repro.cluster.topology): shard model states across
# the innermost k levels, gather over that span with a hierarchical
# ring, all-reduce grads across the rest.  The legacy triple below is
# the depth-2 case (ZDP = full span, ZDP_POD = level 1); deeper specs
# add "ZDP@k" modes.  The authoritative per-env mode list is
# `CostEnv.topo.mode_names`.
DP = "DP"
ZDP = "ZDP"
ZDP_POD = "ZDP_POD"      # beyond-paper hierarchical mode (level 1 of 2)
MODES = (DP, ZDP, ZDP_POD)

# per-slice remat states (the second axis of the 4-mode decision space)
REMAT_INHERIT = 0        # follow CostEnv.checkpointing (legacy global flag)
REMAT_OFF = 1            # explicit: keep activations, no recompute
REMAT_ON = 2             # explicit: rematerialize this slice (§4.3 terms)
N_REMAT_STATES = 3
# PlanEvaluator extended column: e = mode_index + len(MODES) * remat_state
N_EXT = len(MODES) * N_REMAT_STATES


@dataclass(frozen=True)
class Decision:
    """Plan entry for one operator: per-slice modes (+ remat bits).

    `modes` has length 1 for unsplit operators, length g for split ones
    (paper §3.3: each slice is independently DP or ZDP).  `remat` is
    the second decision axis: None means every slice inherits the
    legacy global `CostEnv.checkpointing` flag; otherwise it holds one
    entry per slice — True (rematerialize), False (keep activations),
    or None (inherit) — the searched selective-remat plan.
    """

    op: str
    modes: Tuple[str, ...]
    remat: Optional[Tuple[Optional[bool], ...]] = None

    def __post_init__(self):
        if self.remat is not None and len(self.remat) != len(self.modes):
            raise ValueError(
                f"{self.op}: remat length {len(self.remat)} != "
                f"modes length {len(self.modes)}")

    @property
    def split(self) -> int:
        return len(self.modes)

    def uniform(self) -> Optional[str]:
        return self.modes[0] if len(set(self.modes)) == 1 else None

    def remat_states(self) -> Tuple[int, ...]:
        """Per-slice REMAT_* state (0 inherit / 1 off / 2 on)."""
        if self.remat is None:
            return (REMAT_INHERIT,) * self.split
        return tuple(REMAT_INHERIT if r is None
                     else (REMAT_ON if r else REMAT_OFF)
                     for r in self.remat)

    def remat_bits(self, default: bool) -> Tuple[bool, ...]:
        """Per-slice effective remat with inherits resolved to `default`."""
        if self.remat is None:
            return (bool(default),) * self.split
        return tuple(bool(default) if r is None else bool(r)
                     for r in self.remat)

    def uniform_remat(self) -> Optional[bool]:
        """The single explicit remat bit if uniform-explicit, else None."""
        if self.remat is None or None in self.remat:
            return None
        vals = {bool(r) for r in self.remat}
        return vals.pop() if len(vals) == 1 else None


@dataclass(frozen=True)
class CostEnv:
    """Everything the Profiler needs besides the plan.

    `cluster` is the hierarchical device information for the
    data-parallel extent; when absent it is derived from the flat
    (device, mesh) pair via the depth-2 adapter
    `ClusterSpec.from_flat` — on single-pod meshes every price then
    collapses to the legacy flat-ring formulas exactly.  When a
    `cluster` is given, `mesh` may be None (derived from the spec).
    """

    device: DeviceInfo
    mesh: Optional[MeshConfig] = None
    checkpointing: bool = True
    # TP already divides each operator's params across the model axis;
    # OSDP decides the data-axis story for the per-TP-shard residue.
    include_tp: bool = True
    # training = fwd + bwd (2x fwd) compute; False for serving estimates
    train: bool = True
    cluster: Optional[ClusterSpec] = None
    # measured constants (repro calibrate): an efficiency curve in
    # place of the scalar mxu_efficiency, fitted per-level alpha/bw in
    # place of the datasheet link constants, a fitted recompute factor
    # in place of the literal 1.30.  None keeps the legacy scalar path
    # byte-identical — every committed golden is pinned on it.
    profile: Optional["CalibrationProfile"] = None

    def __post_init__(self):
        if self.mesh is None:
            if self.cluster is None:
                raise ValueError("CostEnv needs a mesh or a cluster")
            object.__setattr__(self, "mesh",
                               self.cluster.mesh_config())

    @cached_property
    def topo(self) -> ClusterSpec:
        """The hierarchical cluster spec all collectives are priced
        against (the explicit `cluster`, else the depth-2 adapter),
        with fitted link constants substituted when a calibration
        profile carries any."""
        spec = (self.cluster if self.cluster is not None
                else ClusterSpec.from_flat(self.device, self.mesh))
        if self.profile is not None and self.profile.links:
            spec = spec.with_links(self.profile.links)
        return spec

    @property
    def n_data(self) -> int:
        return self.topo.n_devices              # full data extent

    @property
    def n_data_local(self) -> int:
        return self.topo.span_ways(1)           # innermost level

    @property
    def n_tp(self) -> int:
        return self.mesh.model_parallel if self.include_tp else 1

    @property
    def peak_compute(self) -> float:
        """FLOP/s the step can sustain: the slowest device group's
        peak (uniform clusters: the device's), derated by efficiency.
        This is the scalar (uncalibrated) derating; operator pricing
        goes through `op_peak_compute` so a fitted curve can resolve
        it per size."""
        return self.topo.effective_peak_flops * self.device.mxu_efficiency

    def op_peak_compute(self, op_work: float) -> float:
        """Sustained FLOP/s for one operator.  Without a profile this
        is exactly `peak_compute` (legacy scalar path, byte-identical).
        With one, the fitted curve is consulted at `op_work` — the
        operator's per-TP-shard flops for ONE batch element
        (`flops_per_token * seq / tp`), the batch-independent proxy
        for its matmul size, so the PlanEvaluator's batch-linear
        compute slopes survive calibration unchanged."""
        if self.profile is None:
            return self.peak_compute
        frac = self.profile.efficiency.at(op_work)
        return self.topo.effective_peak_flops * frac

    @property
    def remat_factor(self) -> float:
        """Recompute multiplier on checkpointed compute: the model's
        hand-set 1.30 (§4.3) unless a profile fitted one."""
        return 1.30 if self.profile is None else self.profile.remat_factor

    @property
    def remat_compute_delta(self) -> float:
        """The *extra* compute fraction remat adds (`remat_factor - 1`).
        Kept as the literal 0.30 on the uncalibrated path: in floats
        `1.30 - 1.0` is one ulp off 0.30 and the committed goldens pin
        the literal."""
        if self.profile is None:
            return 0.30
        return self.profile.remat_factor - 1.0

    @cached_property
    def overlaps(self) -> Tuple[float, ...]:
        """Per-level comm/compute overlap factors (innermost-first)."""
        return self.topo.overlaps

    @cached_property
    def has_overlap(self) -> bool:
        """True when any level hides comm under compute.  Every scalar
        price below keeps its exact legacy float order when this is
        False — the committed goldens are all pinned at overlap 0."""
        return self.topo.has_overlap


def shard_ways(mode: str, env: CostEnv) -> float:
    """State divisor of a sharding mode (1 for DP; the spanned device
    count for level-k ZDP; capacity-weighted for full-span ZDP on a
    heterogeneous cluster)."""
    return env.topo.shard_ways(mode)


def _ring_time(bytes_total: float, n: int, alpha: float, bw: float) -> float:
    """One ring all-gather / reduce-scatter over n ranks."""
    if n <= 1:
        return 0.0
    return (n - 1) * (alpha + bytes_total / n / bw)


def _rings_pass(nbytes: float, rings, n_span: int,
                alpha_scale: float = 1.0) -> float:
    """One hierarchical ring pass over a span: the sum of per-level
    `_ring_time`-shaped terms from `ClusterSpec.span_rings` (kept in
    the exact floating-point shape of the legacy flat formula, so a
    single-ring span prices bit-identically to `_ring_time`)."""
    t = 0.0
    for w, alpha, bw, prefix in rings:
        b = nbytes if prefix == 1 else nbytes * prefix
        t += (w - 1) * (alpha * alpha_scale + b / n_span / bw)
    return t


def _rings_pass_b(nbytes: float, rings, ring_levels, n_span: int,
                  buckets: List[float], scale: float,
                  alpha_scale: float = 1.0) -> float:
    """`_rings_pass` that also accumulates each ring's seconds (times
    `scale`, the caller's round multiplier) into the per-level
    `buckets` — the network-resource timeline the overlap model
    consumes.  The returned scalar is term-for-term identical to
    `_rings_pass`, so callers keep the legacy float shape by applying
    `scale` outside as before."""
    t = 0.0
    for (w, alpha, bw, prefix), li in zip(rings, ring_levels):
        b = nbytes if prefix == 1 else nbytes * prefix
        term = (w - 1) * (alpha * alpha_scale + b / n_span / bw)
        t += term
        buckets[li] += scale * term
    return t


def exposed_step_time(compute: float, comm_by_level, overlaps) -> float:
    """Two-resource (compute, network) timeline combine.

    `comm_by_level[l]` is the network time the step spends on level l's
    links; `overlaps[l]` is the fraction of the step's compute that
    level's collectives can hide behind (prefetched gathers, async
    all-reduce).  Each level exposes only what does not fit under the
    compute window:

        T = T_comp + sum_l max(0, T_net_l - overlap_l * T_comp)

    Properties the planner relies on: at overlap 0 this is the serial
    sum; it is non-increasing in every overlap factor; and it never
    drops below max(T_comp, any single level's residual) — levels are
    optimistically hidden independently, which upper-bounds what a real
    scheduler can do and is exact when one level dominates."""
    t = compute
    for c, ov in zip(comm_by_level, overlaps):
        if c <= 0.0:
            continue
        t += c if ov <= 0.0 else max(0.0, c - ov * compute)
    return t


@dataclass
class OpCost:
    memory: float          # steady per-device bytes for this op's states
    peak_extra: float      # transient gathered-weight bytes
    time: float            # seconds per step (comm + compute, serial)
    comm_time: float
    compute_time: float
    comm_by_level: Tuple[float, ...] = ()   # comm_time split by level


def op_cost(op: OperatorDesc, decision: Decision, batch_per_device: int,
            seq_len: int, env: CostEnv) -> OpCost:
    """Cost of one operator under `decision` (§3.1 equations + per-slice
    remat, §4.3).  Decisions without explicit remat bits take the exact
    legacy code path (byte-identical to the global-flag Profiler)."""
    if decision.remat is not None and any(r is not None
                                          for r in decision.remat):
        return _op_cost_per_slice(op, decision, batch_per_device, seq_len,
                                  env)
    g = decision.split
    topo = env.topo
    tp = env.n_tp
    # per-TP-shard sizes; OSDP reasons about the per-device residue
    # training holds optimizer states; serving only the bf16 weights
    state_bytes = (op.state_bytes if env.train else op.param_bytes) / tp
    param_bytes = op.param_bytes / tp
    tokens = batch_per_device * seq_len
    act = op.act_bytes_per_token / tp * tokens
    if env.checkpointing:
        # activations inside a layer are rematerialized: only one layer's
        # working set is live (the layer-boundary checkpoints are counted
        # once in ModelDescription.resident_act_bytes_per_token)
        act /= max(1, op.layers)
    compute = op.flops_per_token * tokens / tp \
        / env.op_peak_compute(op.flops_per_token * seq_len / tp)
    if env.train:
        compute *= 3.0            # fwd + bwd (2x fwd)
    if env.checkpointing:
        compute *= env.remat_factor   # ~30% recompute overhead (fitted
        #                               when a calibration profile is on)

    # merge adjacent same-mode slices: the implementation stores them as
    # one array -> one collective (sharding.specs._merge_modes), so the
    # cost model must too, or uniform split plans would be over-charged
    # (N-1) alpha per slice.
    runs: List[Tuple[str, int]] = []
    for mode in decision.modes:
        if runs and runs[-1][0] == mode:
            runs[-1] = (mode, runs[-1][1] + 1)
        else:
            runs.append((mode, 1))

    full_rings = topo.gather_rings(topo.depth)
    full_lv = topo.gather_ring_levels(topo.depth)
    n_full = topo.span_ways(topo.depth)
    mem = 0.0
    peak = 0.0
    comm = 0.0
    comm_lv = [0.0] * topo.depth    # network seconds bucketed by level
    for mode, run_len in runs:
        s_bytes = state_bytes * run_len / g
        p_bytes = param_bytes * run_len / g
        k = topo.mode_span(mode)
        mem += s_bytes / topo.shard_ways(mode)
        if k == 0:               # DP
            # grads all-reduced over the full data extent (training
            # only): one hierarchical ring per reduce/gather pass
            if env.train:
                comm += 2 * _rings_pass_b(p_bytes, full_rings, full_lv,
                                          n_full, comm_lv, 2.0)
        else:
            if env.train:
                rounds = 3 + (1 if env.checkpointing else 0)
            else:
                rounds = 1    # serving: one forward gather, no grad sync
            # splitting processes the run's slices sequentially: one
            # collective per slice -> alpha charged run_len times, beta
            # on the total bytes (matches chunked execution).
            n_k = topo.span_ways(k)
            comm += rounds * _rings_pass_b(p_bytes, topo.gather_rings(k),
                                           topo.gather_ring_levels(k),
                                           n_k, comm_lv, float(rounds),
                                           run_len)
            if k < topo.depth:
                # grads of the level-k shard all-reduced across the
                # outer (replicated) extent
                comm += 2 * _rings_pass_b(p_bytes / n_k,
                                          topo.outer_rings(k),
                                          topo.outer_ring_levels(k),
                                          n_full // n_k, comm_lv, 2.0)
            # M_extra (paper §3.1/§3.3): the gathered slice is transient
            # but counted additively per op, at the granularity actually
            # gathered — one layer's slice (scan gathers per layer).
            gathered = param_bytes / (max(1, op.layers) * g)
            mem += gathered
            peak = max(peak, gathered)
    return OpCost(memory=mem + act, peak_extra=peak, time=comm + compute,
                  comm_time=comm, compute_time=compute,
                  comm_by_level=tuple(comm_lv))


def _op_cost_per_slice(op: OperatorDesc, decision: Decision,
                       batch_per_device: int, seq_len: int,
                       env: CostEnv) -> OpCost:
    """op_cost for decisions carrying explicit per-slice remat bits.

    Sharding runs still merge by mode only (storage = sharding; remat
    re-gathers, it does not re-segment the arrays), so the state memory,
    M_extra, and base collectives match the legacy path.  Per slice:

      * remat ON  — activations / eff_remat_layers live, compute x1.30,
        and (ZDP modes, training) one extra ring gather over the slice
        before the recompute pass (§4.3's 4th gather);
      * remat OFF — full activations live, no recompute, 3 ZDP rounds;
      * inherit   — the legacy CostEnv.checkpointing scaling.
    """
    g = decision.split
    topo = env.topo
    tp = env.n_tp
    state_bytes = (op.state_bytes if env.train else op.param_bytes) / tp
    param_bytes = op.param_bytes / tp
    tokens = batch_per_device * seq_len
    act_slice = op.act_bytes_per_token / tp * tokens / g
    comp_slice = (op.flops_per_token * tokens / tp
                  / env.op_peak_compute(op.flops_per_token * seq_len
                                        / tp)) / g
    if env.train:
        comp_slice *= 3.0
    rl = op.eff_remat_layers
    states = decision.remat_states()
    bits = decision.remat_bits(env.checkpointing)
    rf = env.remat_factor

    act = compute = 0.0
    for st, r in zip(states, bits):
        if st == REMAT_INHERIT:
            act += act_slice / (max(1, op.layers)
                                if env.checkpointing else 1)
        elif r:
            act += act_slice / rl
        else:
            act += act_slice
        compute += comp_slice * (rf if r else 1.0)

    runs: List[Tuple[str, List[int]]] = []
    for j, mode in enumerate(decision.modes):
        if runs and runs[-1][0] == mode:
            runs[-1][1].append(j)
        else:
            runs.append((mode, [j]))

    mem = 0.0
    peak = 0.0
    comm = 0.0
    comm_lv = [0.0] * topo.depth
    for mode, idxs in runs:
        run_len = len(idxs)
        s_bytes = state_bytes * run_len / g
        p_bytes = param_bytes * run_len / g
        k = topo.mode_span(mode)
        mem += s_bytes / topo.shard_ways(mode)
        if k == 0:               # DP
            if env.train:
                comm += 2 * _rings_pass_b(
                    p_bytes, topo.gather_rings(topo.depth),
                    topo.gather_ring_levels(topo.depth),
                    topo.span_ways(topo.depth), comm_lv, 2.0)
            continue
        base_rounds = 3 if env.train else 1
        # maximal remat sub-runs within the sharding run: the §4.3
        # recompute gather re-fetches exactly the remat'd slices
        subs: List[int] = []
        cur = 0
        for j in idxs:
            if env.train and bits[j]:
                cur += 1
            else:
                if cur:
                    subs.append(cur)
                cur = 0
        if cur:
            subs.append(cur)
        n_k = topo.span_ways(k)
        grings = topo.gather_rings(k)
        glv = topo.gather_ring_levels(k)
        comm += base_rounds * _rings_pass_b(p_bytes, grings, glv, n_k,
                                            comm_lv, float(base_rounds),
                                            run_len)
        for sl in subs:
            comm += _rings_pass_b(param_bytes * sl / g, grings, glv, n_k,
                                  comm_lv, 1.0, sl)
        if k < topo.depth:       # cross-outer grad all-reduce
            comm += 2 * _rings_pass_b(p_bytes / n_k, topo.outer_rings(k),
                                      topo.outer_ring_levels(k),
                                      topo.span_ways(topo.depth) // n_k,
                                      comm_lv, 2.0)
        gathered = param_bytes / (max(1, op.layers) * g)
        mem += gathered
        peak = max(peak, gathered)
    return OpCost(memory=mem + act, peak_extra=peak, time=comm + compute,
                  comm_time=comm, compute_time=compute,
                  comm_by_level=tuple(comm_lv))


@dataclass
class PlanCost:
    memory: float        # steady per-device bytes
    peak_memory: float   # steady + worst transient gather
    time: float          # seconds per step (timeline-combined when the
                         # env's topology declares overlap, serial else)
    comm_time: float     # total network seconds (resource time, not
                         # necessarily exposed on the critical path)
    compute_time: float
    throughput: float    # tokens / s (global)
    comm_by_level: Tuple[float, ...] = ()   # comm_time split by level


def plan_cost(desc: ModelDescription, decisions: Dict[str, Decision],
              global_batch: int, env: CostEnv) -> PlanCost:
    """The paper's T(p, b), M(p, b) over the whole operator list.

    With per-level overlap factors on the env's topology, step time is
    the two-resource timeline `exposed_step_time` instead of the serial
    comm+compute sum; at overlap 0 the serial accumulation below is
    kept untouched (byte-identical to the committed goldens)."""
    bpd = max(1, global_batch // env.n_data)
    seq = desc.shape.seq_len
    mem = desc.resident_act_bytes_per_token * bpd * seq / env.n_tp
    peak = 0.0
    time = comm = compute = 0.0
    comm_lv = [0.0] * env.topo.depth
    for op in desc.operators:
        dec = decisions.get(op.name)
        if dec is None:
            dec = Decision(op.name, (DP,))
        c = op_cost(op, dec, bpd, seq, env)
        mem += c.memory
        peak = max(peak, c.peak_extra)
        time += c.time
        comm += c.comm_time
        compute += c.compute_time
        for li, x in enumerate(c.comm_by_level):
            comm_lv[li] += x
    if env.has_overlap:
        time = exposed_step_time(compute, comm_lv, env.overlaps)
    tokens = global_batch * seq
    return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                    comm_time=comm, compute_time=compute,
                    throughput=tokens / time if time > 0 else 0.0,
                    comm_by_level=tuple(comm_lv))


# ---------------------------------------------------------------------------
# PlanEvaluator: incremental, vectorized Profiler
# ---------------------------------------------------------------------------

class PlanEvaluator:
    """Table-driven plan evaluation with O(1) per-slice delta updates.

    ``plan_cost`` walks every operator in Python and re-derives each
    run's collective terms from scratch — fine for scoring one plan,
    quadratic when a search evaluates thousands of neighbouring plans
    (the repair loop flips one slice at a time, the Scheduler re-scores
    per batch candidate).  This class precomputes, once per
    (description, env, slice layout):

      * per-slice, per-extended-mode additive terms — sharded state
        bytes and the run-length-linear part of the collective time
        (ZDP's per-slice ``alpha`` and everyone's beta term scale with
        run length, so they distribute exactly over slices),
      * per-op, per-sharding-mode *run* constants — the terms
        ``op_cost`` charges once per merged same-sharding run: the
        transiently gathered slice (M_extra) for ZDP runs, the
        2(N-1)·alpha grad-all-reduce latency for DP runs, the cross-pod
        alpha for ZDP_POD.  Remat never re-segments storage, so run
        boundaries depend on the sharding mode only,
      * per-slice batch slopes — activation and compute scale linearly
        with the per-device batch AND with each slice's remat state, so
        changing the batch re-uses every table.

    Slices address an *extended* mode ``e = mode + 3 * remat_state``
    with remat_state in {REMAT_INHERIT, REMAT_OFF, REMAT_ON}: columns
    0..2 are the legacy global-flag semantics (byte-compatible with the
    pre-selective-remat engine), 3..5 force activations kept, 6..8
    force rematerialization (recompute x1.30 + the §4.3 4th gather in
    ZDP modes, activations / eff_remat_layers).

    A full plan evaluation is then a vectorized table gather, and
    flipping one slice's extended mode only touches that slice's
    additive terms plus (when the sharding part changes) the run
    boundaries next to it: an O(1) update (``begin`` / ``flip``).
    Results match ``plan_cost`` to float-summation-order (~1e-12
    relative; asserted at 1e-9 by tests/test_plan_evaluator.py).

    Slice layout: every operator contributes ``granularity[op.name]``
    slices (default 1 — ``plan_cost``'s layout for missing decisions).
    """

    def __init__(self, desc: ModelDescription, env: CostEnv,
                 granularity: Optional[Dict[str, int]] = None):
        self.desc = desc
        self.env = env
        gran = granularity or {}
        topo = env.topo
        tp = env.n_tp
        seq = desc.shape.seq_len
        # dynamic sharding-mode list: DP, full ZDP, then one column per
        # intermediate hierarchy level (depth-2 specs keep the legacy
        # (DP, ZDP, ZDP_POD) layout -> N_EXT == 9, byte-compatible)
        self.modes: Tuple[str, ...] = topo.mode_names
        self.n_modes = len(self.modes)
        self.n_ext = self.n_modes * N_REMAT_STATES
        self.mode_index = {m: i for i, m in enumerate(self.modes)}
        n_m = self.n_modes
        # ZDP gather rounds per remat state: inherit follows the env
        # flag; explicit off/on pin 3 / 4 (§4.3); serving gathers once
        if env.train:
            rounds = (3 + (1 if env.checkpointing else 0), 3, 4)
        else:
            rounds = (1, 1, 1)

        ops = desc.operators
        self.n_ops = len(ops)
        self.op_names = [op.name for op in ops]
        self.granularity = np.array(
            [max(1, gran.get(op.name, 1)) for op in ops], dtype=np.int64)
        self.op_start = np.zeros(self.n_ops, dtype=np.int64)
        np.cumsum(self.granularity[:-1], out=self.op_start[1:])
        self.n_slices = int(self.granularity.sum())
        self.slice_op = np.repeat(np.arange(self.n_ops), self.granularity)

        g = self.granularity.astype(np.float64)
        state_b = np.array(
            [(op.state_bytes if env.train else op.param_bytes) / tp
             for op in ops])
        param_b = np.array([op.param_bytes / tp for op in ops])
        layers = np.array([max(1, op.layers) for op in ops],
                          dtype=np.float64)
        remat_layers = np.array([op.eff_remat_layers for op in ops],
                                dtype=np.float64)
        self.gathered = param_b / (layers * g)       # per non-DP run M_extra

        # per-slice batch slopes per remat state (per unit of
        # per-device batch); independent of the sharding mode
        self._resident_slope = desc.resident_act_bytes_per_token * seq / tp
        act = np.array([op.act_bytes_per_token / tp for op in ops]) \
            * seq / g
        act_states = np.stack(
            [act / layers if env.checkpointing else act,   # inherit
             act,                                          # explicit off
             act / remat_layers], axis=1)                  # explicit on
        # per-op sustained peak: the scalar derating, or the fitted
        # curve at each op's size (elementwise divide keeps the legacy
        # float order bit-identical when every entry is the scalar)
        op_peak = np.array([env.op_peak_compute(op.flops_per_token
                                                * seq / tp)
                            for op in ops])
        comp = np.array([op.flops_per_token for op in ops]) * seq / tp \
            / op_peak / g
        if env.train:
            comp = comp * 3.0
        rf = env.remat_factor
        comp_states = np.stack(
            [comp * rf if env.checkpointing else comp,
             comp,
             comp * rf], axis=1)

        # per-op per-extended-mode tables; e = mode + n_modes * state.
        # Collective prices iterate the spec's per-level rings in the
        # exact floating-point shape of the legacy flat formula
        # (bit-identical on depth-2 single-pod adapters).
        #
        # When the topology declares overlap, the same terms are also
        # bucketed per hierarchy level (`*_lv` tables, one extra trailing
        # depth axis) so the timeline combine can expose each level's
        # residual independently; at overlap 0 the tables are skipped
        # and every price below is the untouched legacy scalar.
        self.depth = topo.depth
        self.overlaps = np.array(topo.overlaps)
        self.has_overlap = topo.has_overlap
        mem_op = np.zeros((self.n_ops, n_m))
        comm_op = np.zeros((self.n_ops, self.n_ext))     # per-slice additive
        self.mem_run = np.zeros((self.n_ops, n_m))
        self.comm_run = np.zeros((self.n_ops, n_m))
        comm_op_lv = (np.zeros((self.n_ops, self.n_ext, self.depth))
                      if self.has_overlap else None)
        self.comm_run_lv = (np.zeros((self.n_ops, n_m, self.depth))
                            if self.has_overlap else None)
        sliced = param_b / g                              # per-slice bytes
        n_full = topo.span_ways(topo.depth)
        # DP: states replicated; grads all-reduced hierarchically over
        # the full data extent (training only): alpha once per run,
        # beta per slice; remat does not change DP collectives
        mem_op[:, 0] = state_b / g
        if env.train:
            for (w, alpha, bw, prefix), li in zip(
                    topo.gather_rings(topo.depth),
                    topo.gather_ring_levels(topo.depth)):
                b = sliced if prefix == 1 else sliced * prefix
                dp_beta = 2 * (w - 1) * (b / n_full / bw)
                for st in range(N_REMAT_STATES):
                    comm_op[:, 0 + n_m * st] += dp_beta
                    if comm_op_lv is not None:
                        comm_op_lv[:, 0 + n_m * st, li] += dp_beta
                self.comm_run[:, 0] += 2 * (w - 1) * alpha
                if self.comm_run_lv is not None:
                    self.comm_run_lv[:, 0, li] += 2 * (w - 1) * alpha
        # level-k ZDP columns (ZDP = full span): hierarchical gather
        # over the innermost k levels — alpha scales with run length
        # (chunked execution), so it is fully per-slice, including the
        # remat-state-dependent 4th gather; the cross-outer grad
        # all-reduce is remat-independent (beta per slice, alpha once
        # per run)
        for mi in range(1, n_m):
            mode = self.modes[mi]
            k = topo.mode_span(mode)
            n_k = topo.span_ways(k)
            mem_op[:, mi] = state_b / g / topo.shard_ways(mode)
            for (w, alpha, bw, prefix), li in zip(
                    topo.gather_rings(k), topo.gather_ring_levels(k)):
                b = sliced if prefix == 1 else sliced * prefix
                for st in range(N_REMAT_STATES):
                    term = rounds[st] * (w - 1) * (alpha + b / n_k / bw)
                    comm_op[:, mi + n_m * st] += term
                    if comm_op_lv is not None:
                        comm_op_lv[:, mi + n_m * st, li] += term
            if k < topo.depth:
                shard = sliced / n_k
                n_outer = n_full // n_k
                for (w, alpha, bw, prefix), li in zip(
                        topo.outer_rings(k), topo.outer_ring_levels(k)):
                    b = shard if prefix == 1 else shard * prefix
                    xout = 2 * (w - 1) * (b / n_outer / bw)
                    for st in range(N_REMAT_STATES):
                        comm_op[:, mi + n_m * st] += xout
                        if comm_op_lv is not None:
                            comm_op_lv[:, mi + n_m * st, li] += xout
                    self.comm_run[:, mi] += 2 * (w - 1) * alpha
                    if self.comm_run_lv is not None:
                        self.comm_run_lv[:, mi, li] += 2 * (w - 1) * alpha
            self.mem_run[:, mi] = self.gathered
        # tile/repeat op tables into (n_slices, n_ext): state-
        # independent mem cycles over modes; act/comp repeat each state
        # n_m times so column e = mode + n_m*state lands right
        self.mem_slice = np.tile(mem_op, (1, N_REMAT_STATES))[self.slice_op]
        self.comm_slice = comm_op[self.slice_op]
        self.comm_slice_lv = (comm_op_lv[self.slice_op]
                              if comm_op_lv is not None else None)
        self.act_slice = np.repeat(act_states, n_m, axis=1)[self.slice_op]
        self.comp_slice = np.repeat(comp_states, n_m, axis=1)[self.slice_op]

        # incremental state (begin/flip)
        self._modes: Optional[np.ndarray] = None
        self._batch = 0

    # -- layout helpers ------------------------------------------------------

    @classmethod
    def for_decisions(cls, desc: ModelDescription, env: CostEnv,
                      decisions: Dict[str, Decision]) -> "PlanEvaluator":
        """Evaluator whose slice layout matches an existing plan."""
        gran = {name: d.split for name, d in decisions.items()}
        return cls(desc, env, gran)

    def modes_from_decisions(
            self, decisions: Dict[str, Decision]) -> np.ndarray:
        modes = np.zeros(self.n_slices, dtype=np.int8)
        index = self.mode_index
        for k, name in enumerate(self.op_names):
            dec = decisions.get(name)
            if dec is None:
                continue
            s = int(self.op_start[k])
            if dec.split != int(self.granularity[k]):
                raise ValueError(
                    f"{name}: decision split {dec.split} != evaluator "
                    f"layout {int(self.granularity[k])}")
            states = dec.remat_states()
            for j, (m, st) in enumerate(zip(dec.modes, states)):
                modes[s + j] = index[m] + self.n_modes * st
        return modes

    def decisions(self, modes: np.ndarray) -> Dict[str, Decision]:
        out: Dict[str, Decision] = {}
        n_m = self.n_modes
        for k, name in enumerate(self.op_names):
            s = int(self.op_start[k])
            e = s + int(self.granularity[k])
            ms = tuple(self.modes[int(m) % n_m] for m in modes[s:e])
            states = [int(m) // n_m for m in modes[s:e]]
            remat = None
            if any(states):
                remat = tuple(None if st == REMAT_INHERIT
                              else st == REMAT_ON for st in states)
            out[name] = Decision(name, ms, remat)
        return out

    # -- vectorized full evaluation ------------------------------------------

    def _bpd(self, global_batch: int) -> int:
        return max(1, global_batch // self.env.n_data)

    def all_dp_memory(self, global_batch: int,
                      remat: Optional[bool] = None) -> float:
        """Steady memory of the all-DP plan (the search's base cost).

        `remat` None takes the legacy inherit columns (env default);
        True / False pin the explicit remat state — the selective
        search's base plan is all-DP all-no-remat (`remat=False`).
        """
        st = REMAT_INHERIT if remat is None else (
            REMAT_ON if remat else REMAT_OFF)
        e = self.n_modes * st
        bpd = self._bpd(global_batch)
        return float(self.mem_slice[:, e].sum()
                     + (self._resident_slope
                        + self.act_slice[:, e].sum()) * bpd)

    def _static_sums(self, modes: np.ndarray
                     ) -> Tuple[float, float, float, float, float,
                                Optional[np.ndarray]]:
        """(steady memory w/o batch terms, comm seconds, peak extra,
        act slope, compute slope, per-level comm vector or None) for
        extended-mode array `modes`."""
        idx = np.arange(self.n_slices)
        shard = modes % self.n_modes
        mem = float(self.mem_slice[idx, modes].sum())
        comm = float(self.comm_slice[idx, modes].sum())
        act = float(self.act_slice[idx, modes].sum())
        comp = float(self.comp_slice[idx, modes].sum())
        starts = np.empty(self.n_slices, dtype=bool)
        starts[0] = True
        np.logical_or(shard[1:] != shard[:-1],
                      self.slice_op[1:] != self.slice_op[:-1],
                      out=starts[1:])
        ops_r = self.slice_op[starts]
        shard_r = shard[starts]
        mem += float(self.mem_run[ops_r, shard_r].sum())
        comm += float(self.comm_run[ops_r, shard_r].sum())
        comm_lv = None
        if self.has_overlap:
            comm_lv = self.comm_slice_lv[idx, modes].sum(axis=0)
            comm_lv += self.comm_run_lv[ops_r, shard_r].sum(axis=0)
        nonzero = np.add.reduceat(
            (shard != 0).astype(np.int64), self.op_start)
        peak = float(self.gathered[nonzero > 0].max()) \
            if bool((nonzero > 0).any()) else 0.0
        return mem, comm, peak, act, comp, comm_lv

    def _combine(self, comm: float, compute: float,
                 comm_lv: Optional[np.ndarray]) -> float:
        """Step time: the serial sum (legacy float order) at overlap 0,
        the exposed-comm timeline otherwise."""
        if comm_lv is None:
            return comm + compute
        return exposed_step_time(compute, comm_lv, self.overlaps)

    def plan_cost(self, modes: np.ndarray,
                  global_batch: int) -> PlanCost:
        """Full vectorized evaluation — `cost_model.plan_cost` semantics."""
        mem_s, comm, peak, act_sl, comp_sl, comm_lv = \
            self._static_sums(modes)
        bpd = self._bpd(global_batch)
        mem = float(mem_s + (self._resident_slope + act_sl) * bpd)
        compute = comp_sl * bpd
        time = self._combine(comm, compute, comm_lv)
        tokens = global_batch * self.desc.shape.seq_len
        return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                        comm_time=comm, compute_time=compute,
                        throughput=tokens / time if time > 0 else 0.0,
                        comm_by_level=() if comm_lv is None
                        else tuple(float(x) for x in comm_lv))

    # -- incremental evaluation ----------------------------------------------

    def begin(self, modes: np.ndarray, global_batch: int) -> None:
        """Start an incremental evaluation from `modes` (copied)."""
        self._modes = np.asarray(modes, dtype=np.int8).copy()
        self._batch = global_batch
        mem_s, comm, _, act_sl, comp_sl, comm_lv = \
            self._static_sums(self._modes)
        self._mem_static = mem_s
        self._comm = comm
        self._comm_lv = comm_lv
        self._act_sl = act_sl
        self._comp_sl = comp_sl
        self._nonzero = np.add.reduceat(
            ((self._modes % self.n_modes) != 0).astype(np.int64),
            self.op_start)

    def _run_const_window(self, j: int, k: int, shard_j: int) -> \
            Tuple[float, float, Optional[np.ndarray]]:
        """Run-constant contribution of the boundaries at j and j+1 if
        slice j had sharding mode `shard_j` (neighbours read from
        current state; run boundaries ignore the remat state).  The
        third element is the per-level comm vector (None at overlap 0)."""
        modes = self._modes
        n_m = self.n_modes
        mem = comm = 0.0
        lv = np.zeros(self.depth) if self.has_overlap else None
        left_same = j > 0 and int(self.slice_op[j - 1]) == k
        if (not left_same) or int(modes[j - 1]) % n_m != shard_j:
            mem += self.mem_run[k, shard_j]
            comm += self.comm_run[k, shard_j]
            if lv is not None:
                lv += self.comm_run_lv[k, shard_j]
        right = j + 1
        if right < self.n_slices and int(self.slice_op[right]) == k:
            mr = int(modes[right]) % n_m
            if mr != shard_j:
                mem += self.mem_run[k, mr]
                comm += self.comm_run[k, mr]
                if lv is not None:
                    lv += self.comm_run_lv[k, mr]
        return mem, comm, lv

    def flip(self, j: int, new_mode: int) -> None:
        """O(1): change slice j's extended mode in the running
        evaluation (sharding and/or remat state).  The per-level comm
        vector updates are O(depth) — depth <= 3 on every preset, so
        the flip stays constant-time."""
        assert self._modes is not None, "begin() first"
        old = int(self._modes[j])
        if old == new_mode:
            return
        k = int(self.slice_op[j])
        self._mem_static += float(self.mem_slice[j, new_mode]
                                  - self.mem_slice[j, old])
        self._comm += float(self.comm_slice[j, new_mode]
                            - self.comm_slice[j, old])
        if self._comm_lv is not None:
            self._comm_lv += (self.comm_slice_lv[j, new_mode]
                              - self.comm_slice_lv[j, old])
        self._act_sl += float(self.act_slice[j, new_mode]
                              - self.act_slice[j, old])
        self._comp_sl += float(self.comp_slice[j, new_mode]
                               - self.comp_slice[j, old])
        n_m = self.n_modes
        old_s, new_s = old % n_m, new_mode % n_m
        if old_s != new_s:
            # only a sharding change can create/destroy run boundaries
            mem_b, comm_b, lv_b = self._run_const_window(j, k, old_s)
            mem_a, comm_a, lv_a = self._run_const_window(j, k, new_s)
            self._mem_static += float(mem_a - mem_b)
            self._comm += float(comm_a - comm_b)
            if self._comm_lv is not None:
                self._comm_lv += lv_a - lv_b
            self._nonzero[k] += (new_s != 0) - (old_s != 0)
        self._modes[j] = new_mode

    @property
    def current_modes(self) -> np.ndarray:
        """Extended mode indices of the running evaluation (live view)."""
        assert self._modes is not None, "begin() first"
        return self._modes

    @property
    def memory(self) -> float:
        """Steady per-device bytes of the running evaluation."""
        bpd = self._bpd(self._batch)
        return (self._mem_static
                + (self._resident_slope + self._act_sl) * bpd)

    def result(self) -> PlanCost:
        """PlanCost of the running evaluation (peak recomputed exactly)."""
        bpd = self._bpd(self._batch)
        mem = self.memory
        compute = self._comp_sl * bpd
        time = self._combine(self._comm, compute, self._comm_lv)
        peak = float(self.gathered[self._nonzero > 0].max()) \
            if bool((self._nonzero > 0).any()) else 0.0
        tokens = self._batch * self.desc.shape.seq_len
        return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                        comm_time=self._comm, compute_time=compute,
                        throughput=tokens / time if time > 0 else 0.0,
                        comm_by_level=() if self._comm_lv is None
                        else tuple(float(x) for x in self._comm_lv))


# convenience whole-model plans ----------------------------------------------

def count_remat_slices(decisions: Dict[str, Decision],
                       value: bool = True) -> int:
    """Slices across a plan whose explicit remat bit equals `value`
    (inherit slices are never counted)."""
    return sum(sum(1 for r in (d.remat or ())
                   if r is not None and bool(r) == value)
               for d in decisions.values())


def uniform_plan(desc: ModelDescription, mode: str,
                 split: int = 1) -> Dict[str, Decision]:
    out = {}
    for op in desc.operators:
        if not op.decidable:
            out[op.name] = Decision(op.name, (DP,))
        else:
            g = split if (split > 1 and op.splittable) else 1
            out[op.name] = Decision(op.name, (mode,) * g)
    return out


def zdp_saving(op: OperatorDesc, env: CostEnv, mode: str = ZDP,
               split: int = 1) -> float:
    """Net memory bytes saved by moving op from DP to `mode` at slice
    granularity `split`: sharded model states minus the transiently
    gathered per-layer slice (paper M_extra; shrinks with splitting).
    Serving envs (train=False) hold only the bf16 weights, so the
    sharding saving is 8x smaller than the optimizer-state saving."""
    n = shard_ways(mode, env)
    s = (op.state_bytes if env.train else op.param_bytes) / env.n_tp
    gathered = op.param_bytes / env.n_tp / (max(1, op.layers) * max(1, split))
    return max(0.0, s * (1 - 1 / n) - gathered)


def zdp_extra_time(op: OperatorDesc, env: CostEnv, mode: str = ZDP) -> float:
    """Per-step seconds added by moving op from DP to `mode`.

    Under an overlapped topology the solvers' additive surrogate
    discounts each level's comm by its hideable fraction (1 - overlap):
    a second of level-l traffic only costs (1 - ov_l) seconds at the
    margin when that level's collectives ride behind compute.  The
    exposed-comm max() makes the true objective non-additive; the
    surrogate ranks items, the timeline evaluator scores the final
    plan exactly (and the repair loop judges memory only, which is
    overlap-independent)."""
    d_dp = Decision(op.name, (DP,))
    d_z = Decision(op.name, (mode,))
    # batch/seq affect only compute, identical across modes -> use 1,1
    c_dp = op_cost(op, d_dp, 1, 1, env)
    c_z = op_cost(op, d_z, 1, 1, env)
    if not env.has_overlap:
        return c_z.comm_time - c_dp.comm_time
    ov = env.overlaps
    return (sum((1.0 - o) * c for c, o in zip(c_z.comm_by_level, ov))
            - sum((1.0 - o) * c for c, o in zip(c_dp.comm_by_level, ov)))


# selective-remat per-slice terms (the 4-mode axis item costs) ---------------

def remat_gather_time(op: OperatorDesc, env: CostEnv, mode: str = ZDP,
                      split: int = 1) -> float:
    """Seconds of the §4.3 recompute-pass parameter gather for ONE
    remat'd slice of `op` at granularity `split` (training only; DP
    recomputes from local weights at no collective cost)."""
    if not env.train or mode == DP:
        return 0.0
    topo = env.topo
    k = topo.mode_span(mode)
    p = op.param_bytes / env.n_tp / max(1, split)
    return _rings_pass(p, topo.gather_rings(k), topo.span_ways(k))


def remat_act_saving_slope(op: OperatorDesc, env: CostEnv, seq_len: int,
                           split: int = 1) -> float:
    """Steady activation bytes ONE remat'd slice stops holding, per unit
    of per-device batch: act_slice * (1 - 1/eff_remat_layers)."""
    act_slice = op.act_bytes_per_token / env.n_tp * seq_len / max(1, split)
    return act_slice * (1.0 - 1.0 / op.eff_remat_layers)


def remat_compute_slope(op: OperatorDesc, env: CostEnv, seq_len: int,
                        split: int = 1) -> float:
    """Recompute seconds ONE remat'd slice adds, per unit of per-device
    batch: the recompute fraction (30%, or the fitted factor minus 1)
    of the slice's (train) compute."""
    comp = (op.flops_per_token * seq_len / env.n_tp
            / env.op_peak_compute(op.flops_per_token * seq_len
                                  / env.n_tp)) / max(1, split)
    if env.train:
        comp *= 3.0
    return env.remat_compute_delta * comp


# ---------------------------------------------------------------------------
# Serving workload model: prefill/decode asymmetry + the KV-cache budget
# ---------------------------------------------------------------------------
#
# Inference is the same §3.1 trade — memory vs hardware utilization per
# operator under a device budget — with two twists the training model
# cannot see:
#
#   * the dominant memory term is the per-sequence KV/SSM cache
#     (OperatorDesc.kv_cache_bytes_per_token / cache_bytes_per_seq),
#     which scales with the *admitted concurrency*, not the batch of one
#     step — so the planner trades sharded weights against cache slots;
#   * the two phases price differently: prefill is compute-bound
#     (batch x prompt_len tokens amortize every gather), decode is
#     bandwidth-bound (batch x 1 token must still stream the full
#     weight set + all live caches from HBM every step).
#
# `serving_plan_cost` therefore evaluates one plan at BOTH shapes and
# adds an HBM-roofline floor to each phase's compute term; the
# prefill/decode formulas live in docs/cost_model.md §8.

@dataclass(frozen=True)
class ServingWorkload:
    """Steady-state serving traffic: requests arrive with
    `prompt_len`-token prompts and decode `decode_len` tokens, so an
    admitted sequence pins a cache of `cache_len` attended tokens."""

    prompt_len: int = 512
    decode_len: int = 128

    def __post_init__(self):
        if self.prompt_len < 1 or self.decode_len < 1:
            raise ValueError("workload needs prompt_len/decode_len >= 1")

    @property
    def cache_len(self) -> int:
        return self.prompt_len + self.decode_len


@dataclass(frozen=True)
class RequestClass:
    """One class of serving traffic: its shape (`prompt_len`,
    `decode_len`), its offered load (`arrival_rate`, requests/s into
    the fleet), and its tail-latency targets (`ttft_slo` / `tpot_slo`,
    seconds; `inf` = no SLO).  A `RequestClassMix` weights several of
    these; the single-class mix is an exact alias of the legacy
    `ServingWorkload`."""

    name: str
    prompt_len: int = 512
    decode_len: int = 128
    arrival_rate: float = 1.0
    ttft_slo: float = math.inf
    tpot_slo: float = math.inf

    def __post_init__(self):
        if not self.name:
            raise ValueError("request class needs a name")
        if self.prompt_len < 1 or self.decode_len < 1:
            raise ValueError("class needs prompt_len/decode_len >= 1")
        if self.arrival_rate <= 0:
            raise ValueError("class needs arrival_rate > 0")
        if self.ttft_slo <= 0 or self.tpot_slo <= 0:
            raise ValueError("SLOs must be positive (inf = none)")

    @property
    def cache_len(self) -> int:
        return self.prompt_len + self.decode_len

    def workload(self) -> ServingWorkload:
        """The class's single-class `ServingWorkload` projection."""
        return ServingWorkload(self.prompt_len, self.decode_len)


@dataclass(frozen=True)
class RequestClassMix:
    """Weighted request classes — the fleet-serving workload model.

    Slot occupancy weighting: every admitted sequence shares the same
    batched decode step, so a class's steady-state share of the slot
    pool is proportional to `arrival_rate * decode_len` (Little's law
    with a common per-token service time).  `slot_share` drives both
    the expected per-slot cache bytes the planner budgets for and the
    per-class throughput split; with one class every share is exactly
    1.0, which is what makes the single-class mix an exact alias of
    `ServingWorkload`."""

    classes: Tuple[RequestClass, ...]

    def __post_init__(self):
        if not self.classes:
            raise ValueError("mix needs at least one class")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names: {names}")

    @classmethod
    def single(cls, prompt_len: int = 512, decode_len: int = 128,
               name: str = "default", **kw) -> "RequestClassMix":
        return cls((RequestClass(name, prompt_len, decode_len, **kw),))

    @classmethod
    def of(cls, workload: "WorkloadLike") -> "RequestClassMix":
        """Normalize a `ServingWorkload` (or mix) to a mix."""
        if isinstance(workload, RequestClassMix):
            return workload
        return cls.single(workload.prompt_len, workload.decode_len)

    def __len__(self) -> int:
        return len(self.classes)

    def __iter__(self):
        return iter(self.classes)

    def __getitem__(self, name: str) -> RequestClass:
        for c in self.classes:
            if c.name == name:
                return c
        raise KeyError(name)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.classes)

    @property
    def total_rate(self) -> float:
        return sum(c.arrival_rate for c in self.classes)

    @property
    def offered_tokens_per_s(self) -> float:
        """Decode tokens/s the mix demands at its arrival rates."""
        return sum(c.arrival_rate * c.decode_len for c in self.classes)

    def slot_share(self, c: Union[RequestClass, str]) -> float:
        """Class `c`'s steady-state fraction of the slot pool (by
        object or name)."""
        if isinstance(c, str):
            c = self[c]
        total = sum(k.arrival_rate * k.decode_len for k in self.classes)
        return c.arrival_rate * c.decode_len / total

    @property
    def max_cache_len(self) -> int:
        """Slot sizing: slots must hold the largest class's cache."""
        return max(c.cache_len for c in self.classes)

    def workload(self) -> ServingWorkload:
        """Single-class projection (exact only for one class; multi-
        class mixes project to the worst-case shape for slot sizing)."""
        if len(self.classes) == 1:
            return self.classes[0].workload()
        return ServingWorkload(max(c.prompt_len for c in self.classes),
                               max(c.decode_len for c in self.classes))

    def subset(self, names: Sequence[str]) -> "RequestClassMix":
        """The sub-mix of the named classes (shares renormalize)."""
        keep = tuple(c for c in self.classes if c.name in set(names))
        if not keep:
            raise ValueError(f"no classes left from {names}")
        return RequestClassMix(keep)


WorkloadLike = Union[ServingWorkload, RequestClassMix]


@dataclass
class ServingCost:
    """One plan's serving economics at a fixed per-device concurrency."""

    weight_memory: float       # per-device plan-sharded weights (+M_extra)
    cache_bytes_per_seq: float  # per-device cache one sequence pins
    slots_per_device: int      # admitted concurrency per device
    concurrency: int           # global in-flight requests (slots x n_data)
    memory: float              # steady per-device bytes, caches included
    prefill_time: float        # one admitted request's prefill (batch 1)
    decode_step_time: float    # one decode step at full concurrency
    ttft: float                # time to first token ~= prefill_time
    tpot: float                # inter-token latency ~= decode_step_time
    request_latency: float     # ttft + decode_len * tpot
    throughput: float          # steady-state output tokens/s, global


def plan_weight_bytes(desc: ModelDescription,
                      decisions: Dict[str, Decision],
                      env: CostEnv) -> float:
    """Per-device bytes the plan's sharded model states occupy
    (batch-independent: op_cost at zero tokens)."""
    total = 0.0
    for op in desc.operators:
        dec = decisions.get(op.name) or Decision(op.name, (DP,))
        total += op_cost(op, dec, 0, 1, env).memory
    return total


def inference_act_bytes(desc: ModelDescription, env: CostEnv,
                        batch_per_device: int, seq_len: int) -> float:
    """Live activation bytes of one inference forward pass.

    No backward pass retains anything: the layer scan holds the
    residual stream plus ONE layer's working set (the widest op's),
    and the head materializes last-position fp32 logits.  This is the
    serving analogue of the training act term, which counts every
    layer's activations."""
    tokens = batch_per_device * seq_len
    tp = env.n_tp
    per_layer = max((op.act_bytes_per_token / max(1, op.layers)
                     for op in desc.operators), default=0.0)
    residual = desc.model.d_model * ACT_BYTES * tokens
    logits = desc.model.padded_vocab * 4.0 * batch_per_device
    return (residual + per_layer * tokens) / tp + logits


def weight_read_bytes(desc: ModelDescription, env: CostEnv) -> float:
    """HBM bytes of weights one forward step streams per device.

    Matmul ops read their full (per-TP-shard) weights; MoE experts are
    read at the top-k/E active fraction — recovered exactly from the
    flops/param ratio (a matmul's flops_per_token is 2 x params, so the
    ratio is the active fraction); param-less and zero-flop ops stream
    nothing that scales with the model."""
    total = 0.0
    for op in desc.operators:
        if op.param_count <= 0 or op.flops_per_token <= 0:
            continue
        frac = min(1.0, op.flops_per_token / (2.0 * op.param_count))
        total += frac * op.param_bytes
    return total / env.n_tp


def serving_plan_cost(desc_prefill: ModelDescription,
                      desc_decode: ModelDescription,
                      decisions: Dict[str, Decision],
                      workload: ServingWorkload, env: CostEnv,
                      slots_per_device: int) -> ServingCost:
    """Score one sharding plan for serving at a fixed concurrency.

    `desc_prefill` / `desc_decode` describe the same model at the two
    phase shapes (seq_len = prompt_len and 1); `env` must be a serving
    env (train=False: one forward gather per ZDP run, no grad sync).
    Prefill runs one request per device (continuous batching admits
    requests one at a time); decode runs all `slots_per_device` slots.
    Each phase's compute is floored by its HBM streaming time:
    weights for both, plus every live cache for decode.  Memory is
    weights + caches + the worst phase's live activations
    (`inference_act_bytes` — inference keeps nothing for a backward
    pass, so the training act term does not apply)."""
    if env.train:
        raise ValueError("serving_plan_cost needs a train=False CostEnv")
    n = env.n_data
    slots = max(1, slots_per_device)
    cache_seq = desc_decode.cache_bytes_per_seq(workload.cache_len,
                                                env.n_tp)
    dec = plan_cost(desc_decode, decisions, slots * n, env)
    pre = plan_cost(desc_prefill, decisions, n, env)
    bw = env.device.hbm_bw
    reads = weight_read_bytes(desc_decode, env)
    if env.has_overlap:
        # the HBM-floor streaming (weights + live caches) is the busy
        # window the phase's collectives can hide behind
        decode_step = exposed_step_time(
            max(dec.compute_time, (reads + slots * cache_seq) / bw),
            dec.comm_by_level, env.overlaps)
        prefill = exposed_step_time(max(pre.compute_time, reads / bw),
                                    pre.comm_by_level, env.overlaps)
    else:
        decode_step = (max(dec.compute_time,
                           (reads + slots * cache_seq) / bw)
                       + dec.comm_time)
        prefill = max(pre.compute_time, reads / bw) + pre.comm_time
    latency = prefill + workload.decode_len * decode_step
    weight_mem = plan_weight_bytes(desc_decode, decisions, env)
    act = max(inference_act_bytes(desc_prefill, env, 1,
                                  workload.prompt_len),
              inference_act_bytes(desc_decode, env, slots, 1))
    return ServingCost(
        weight_memory=weight_mem,
        cache_bytes_per_seq=cache_seq,
        slots_per_device=slots,
        concurrency=slots * n,
        memory=weight_mem + act + slots * cache_seq,
        prefill_time=prefill,
        decode_step_time=decode_step,
        ttft=prefill,
        tpot=decode_step,
        request_latency=latency,
        throughput=(slots * n * workload.decode_len / latency
                    if latency > 0 else 0.0))


@dataclass
class MixServingCost:
    """One plan's serving economics under a `RequestClassMix`.

    `per_class` prices each class through the same phase machinery as
    `serving_plan_cost` — its own prefill shape, the shared batched
    decode step — with the decode HBM floor and the memory budget
    charged at the occupancy-weighted *expected* cache bytes
    (`cache_bytes_per_slot`).  `memory` is the binding (max) per-class
    figure, so feasibility is judged at the worst phase of the worst
    class."""

    per_class: Dict[str, ServingCost]
    slots_per_device: int
    concurrency: int
    weight_memory: float
    cache_bytes_per_slot: float
    memory: float
    decode_step_time: float
    throughput: float             # aggregate output tokens/s
    offered_tokens_per_s: float   # decode tokens/s the mix demands

    def slo_attained(self, mix: RequestClassMix) -> Dict[str, bool]:
        """Analytic per-class SLO check: phase latencies within the
        class targets AND the class's throughput share covers its
        offered load (otherwise queues grow without bound)."""
        out = {}
        for c in mix.classes:
            sc = self.per_class[c.name]
            out[c.name] = (sc.ttft <= c.ttft_slo
                           and sc.tpot <= c.tpot_slo
                           and sc.throughput + 1e-12
                           >= c.arrival_rate * c.decode_len)
        return out


def serving_mix_cost(desc_prefills: Dict[int, ModelDescription],
                     desc_decode: ModelDescription,
                     decisions: Dict[str, Decision],
                     mix: RequestClassMix, env: CostEnv,
                     slots_per_device: int) -> MixServingCost:
    """Score one sharding plan for serving a `RequestClassMix`.

    `desc_prefills` maps each class's prompt_len to the model described
    at that prefill shape (`desc_decode` is shared — decode is always
    seq_len 1).  Every class sees the same decode step (all admitted
    sequences decode in one batch), floored by streaming the weights
    plus the *expected* live cache (slot-share weighted over class
    cache lengths); each class pays its own prefill.  Class throughput
    is its slot share of the pool.  With a single class every figure
    reduces exactly to `serving_plan_cost` (share = 1.0)."""
    if env.train:
        raise ValueError("serving_mix_cost needs a train=False CostEnv")
    n = env.n_data
    slots = max(1, slots_per_device)
    cache_exp = sum(
        mix.slot_share(c)
        * desc_decode.cache_bytes_per_seq(c.cache_len, env.n_tp)
        for c in mix.classes)
    dec = plan_cost(desc_decode, decisions, slots * n, env)
    bw = env.device.hbm_bw
    reads = weight_read_bytes(desc_decode, env)
    if env.has_overlap:
        decode_step = exposed_step_time(
            max(dec.compute_time, (reads + slots * cache_exp) / bw),
            dec.comm_by_level, env.overlaps)
    else:
        decode_step = (max(dec.compute_time,
                           (reads + slots * cache_exp) / bw)
                       + dec.comm_time)
    weight_mem = plan_weight_bytes(desc_decode, decisions, env)
    act_dec = inference_act_bytes(desc_decode, env, slots, 1)
    per_class: Dict[str, ServingCost] = {}
    for c in mix.classes:
        desc_pre = desc_prefills[c.prompt_len]
        pre = plan_cost(desc_pre, decisions, n, env)
        if env.has_overlap:
            prefill = exposed_step_time(
                max(pre.compute_time, reads / bw),
                pre.comm_by_level, env.overlaps)
        else:
            prefill = max(pre.compute_time, reads / bw) + pre.comm_time
        latency = prefill + c.decode_len * decode_step
        act = max(inference_act_bytes(desc_pre, env, 1, c.prompt_len),
                  act_dec)
        share = mix.slot_share(c)
        per_class[c.name] = ServingCost(
            weight_memory=weight_mem,
            cache_bytes_per_seq=desc_decode.cache_bytes_per_seq(
                c.cache_len, env.n_tp),
            slots_per_device=slots,
            concurrency=slots * n,
            memory=weight_mem + act + slots * cache_exp,
            prefill_time=prefill,
            decode_step_time=decode_step,
            ttft=prefill,
            tpot=decode_step,
            request_latency=latency,
            throughput=(share * slots * n * c.decode_len / latency
                        if latency > 0 else 0.0))
    return MixServingCost(
        per_class=per_class,
        slots_per_device=slots,
        concurrency=slots * n,
        weight_memory=weight_mem,
        cache_bytes_per_slot=cache_exp,
        memory=max(sc.memory for sc in per_class.values()),
        decode_step_time=decode_step,
        throughput=sum(sc.throughput for sc in per_class.values()),
        offered_tokens_per_s=mix.offered_tokens_per_s)
