"""OSDP cost model — the paper's §3.1 Profiler, on TPU constants.

Memory:
    M_i(p_i, b) = M_model_i / (1 or N_shard) + b * M_act_i + M_extra_i

Time ((alpha, beta, gamma) model, ring collectives):
    T_i(p_i, b) = k (N-1)(alpha + S_i beta / N) + b * gamma_i
with k = 2 for DP (all-reduce = reduce-scatter + all-gather) and
k = 3 for ZDP (two all-gathers + one reduce-scatter); +1 for ZDP when
activation checkpointing forces a third parameter gather before the
recompute pass (§4.3).

Beyond-paper additions, all flagged explicitly:
  * ZDP_POD — hierarchical sharding across only the in-pod `data` axis:
    memory /N_pod-local, collectives stay on fast ICI.
  * per-mode gathered-weight peak (M_extra): in ZDP the un-sharded
    weight must transiently exist; operator splitting divides it by g.
  * MoE awareness: expert FLOPs scale with top-k, not E.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DeviceInfo, MeshConfig
from repro.core.descriptions import (ACT_BYTES, BYTES_PER_PARAM,
                                     ModelDescription, OperatorDesc,
                                     STATE_BYTES_PER_PARAM)

# parallel modes -------------------------------------------------------------
DP = "DP"
ZDP = "ZDP"
ZDP_POD = "ZDP_POD"      # beyond-paper hierarchical mode
MODES = (DP, ZDP, ZDP_POD)


@dataclass(frozen=True)
class Decision:
    """Plan entry for one operator: per-slice modes.

    `modes` has length 1 for unsplit operators, length g for split ones
    (paper §3.3: each slice is independently DP or ZDP).
    """

    op: str
    modes: Tuple[str, ...]

    @property
    def split(self) -> int:
        return len(self.modes)

    def uniform(self) -> Optional[str]:
        return self.modes[0] if len(set(self.modes)) == 1 else None


@dataclass(frozen=True)
class CostEnv:
    """Everything the Profiler needs besides the plan."""

    device: DeviceInfo
    mesh: MeshConfig
    checkpointing: bool = True
    # TP already divides each operator's params across the model axis;
    # OSDP decides the data-axis story for the per-TP-shard residue.
    include_tp: bool = True
    # training = fwd + bwd (2x fwd) compute; False for serving estimates
    train: bool = True

    @property
    def n_data(self) -> int:
        return self.mesh.data_parallel          # pod x data ways

    @property
    def n_data_local(self) -> int:
        for s, a in zip(self.mesh.shape, self.mesh.axes):
            if a == "data":
                return s
        return 1

    @property
    def n_tp(self) -> int:
        return self.mesh.model_parallel if self.include_tp else 1


def shard_ways(mode: str, env: CostEnv) -> int:
    if mode == DP:
        return 1
    if mode == ZDP:
        return env.n_data
    if mode == ZDP_POD:
        return env.n_data_local
    raise ValueError(mode)


def _ring_time(bytes_total: float, n: int, alpha: float, bw: float) -> float:
    """One ring all-gather / reduce-scatter over n ranks."""
    if n <= 1:
        return 0.0
    return (n - 1) * (alpha + bytes_total / n / bw)


@dataclass
class OpCost:
    memory: float          # steady per-device bytes for this op's states
    peak_extra: float      # transient gathered-weight bytes
    time: float            # seconds per step (comm + compute)
    comm_time: float
    compute_time: float


def op_cost(op: OperatorDesc, decision: Decision, batch_per_device: int,
            seq_len: int, env: CostEnv) -> OpCost:
    """Cost of one operator under `decision` (§3.1 equations)."""
    g = decision.split
    dev = env.device
    tp = env.n_tp
    # per-TP-shard sizes; OSDP reasons about the per-device residue
    # training holds optimizer states; serving only the bf16 weights
    state_bytes = (op.state_bytes if env.train else op.param_bytes) / tp
    param_bytes = op.param_bytes / tp
    tokens = batch_per_device * seq_len
    act = op.act_bytes_per_token / tp * tokens
    if env.checkpointing:
        # activations inside a layer are rematerialized: only one layer's
        # working set is live (the layer-boundary checkpoints are counted
        # once in ModelDescription.resident_act_bytes_per_token)
        act /= max(1, op.layers)
    compute = (op.flops_per_token * tokens / tp
               / (dev.peak_flops * dev.mxu_efficiency))
    if env.train:
        compute *= 3.0            # fwd + bwd (2x fwd)
    if env.checkpointing:
        compute *= 1.30           # the paper's ~30% recompute overhead

    # merge adjacent same-mode slices: the implementation stores them as
    # one array -> one collective (sharding.specs._merge_modes), so the
    # cost model must too, or uniform split plans would be over-charged
    # (N-1) alpha per slice.
    runs: List[Tuple[str, int]] = []
    for mode in decision.modes:
        if runs and runs[-1][0] == mode:
            runs[-1] = (mode, runs[-1][1] + 1)
        else:
            runs.append((mode, 1))

    mem = 0.0
    peak = 0.0
    comm = 0.0
    for mode, run_len in runs:
        s_bytes = state_bytes * run_len / g
        p_bytes = param_bytes * run_len / g
        n = shard_ways(mode, env)
        mem += s_bytes / n
        if mode == DP:
            # grads all-reduced over the full data extent (training only)
            if env.train:
                comm += 2 * _ring_time(p_bytes, env.n_data, dev.alpha,
                                       dev.link_bw("data"))
        else:
            if env.train:
                rounds = 3 + (1 if env.checkpointing else 0)
            else:
                rounds = 1    # serving: one forward gather, no grad sync
            # splitting processes the run's slices sequentially: one
            # collective per slice -> alpha charged run_len times, beta
            # on the total bytes (matches chunked execution).
            alpha_eff = dev.alpha * run_len
            if mode == ZDP:
                # flat all-gather over pod x data; bottleneck link is the
                # slowest axis crossed
                bw = min(dev.link_bw(a) for a in env.mesh.axes
                         if a in ("pod", "data"))
                comm += rounds * _ring_time(p_bytes, env.n_data, alpha_eff,
                                            bw)
            else:  # ZDP_POD: gather within pod over ICI; grads still
                # all-reduced across pods (DP over the pod axis)
                comm += rounds * _ring_time(p_bytes, env.n_data_local,
                                            alpha_eff, dev.link_bw("data"))
                n_pods = env.n_data // env.n_data_local
                comm += 2 * _ring_time(p_bytes / env.n_data_local, n_pods,
                                       dev.alpha, dev.link_bw("pod"))
            # M_extra (paper §3.1/§3.3): the gathered slice is transient
            # but counted additively per op, at the granularity actually
            # gathered — one layer's slice (scan gathers per layer).
            gathered = param_bytes / (max(1, op.layers) * g)
            mem += gathered
            peak = max(peak, gathered)
    return OpCost(memory=mem + act, peak_extra=peak, time=comm + compute,
                  comm_time=comm, compute_time=compute)


@dataclass
class PlanCost:
    memory: float        # steady per-device bytes
    peak_memory: float   # steady + worst transient gather
    time: float          # seconds per step
    comm_time: float
    compute_time: float
    throughput: float    # tokens / s (global)


def plan_cost(desc: ModelDescription, decisions: Dict[str, Decision],
              global_batch: int, env: CostEnv) -> PlanCost:
    """The paper's T(p, b), M(p, b) over the whole operator list."""
    bpd = max(1, global_batch // env.n_data)
    seq = desc.shape.seq_len
    mem = desc.resident_act_bytes_per_token * bpd * seq / env.n_tp
    peak = 0.0
    time = comm = compute = 0.0
    for op in desc.operators:
        dec = decisions.get(op.name)
        if dec is None:
            dec = Decision(op.name, (DP,))
        c = op_cost(op, dec, bpd, seq, env)
        mem += c.memory
        peak = max(peak, c.peak_extra)
        time += c.time
        comm += c.comm_time
        compute += c.compute_time
    tokens = global_batch * seq
    return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                    comm_time=comm, compute_time=compute,
                    throughput=tokens / time if time > 0 else 0.0)


# ---------------------------------------------------------------------------
# PlanEvaluator: incremental, vectorized Profiler
# ---------------------------------------------------------------------------

class PlanEvaluator:
    """Table-driven plan evaluation with O(1) per-slice delta updates.

    ``plan_cost`` walks every operator in Python and re-derives each
    run's collective terms from scratch — fine for scoring one plan,
    quadratic when a search evaluates thousands of neighbouring plans
    (the repair loop flips one slice at a time, the Scheduler re-scores
    per batch candidate).  This class precomputes, once per
    (description, env, slice layout):

      * per-slice, per-mode additive terms — sharded state bytes and the
        run-length-linear part of the collective time (ZDP's per-slice
        ``alpha`` and everyone's beta term scale with run length, so
        they distribute exactly over slices),
      * per-op, per-mode *run* constants — the terms ``op_cost`` charges
        once per merged same-mode run: the transiently gathered slice
        (M_extra) for ZDP runs, the 2(N-1)·alpha grad-all-reduce latency
        for DP runs, the cross-pod alpha for ZDP_POD,
      * batch slopes — activation and compute scale linearly with the
        per-device batch, so changing the batch re-uses every table.

    A full plan evaluation is then a vectorized table gather, and
    flipping one slice's mode only touches that slice's additive terms
    plus the run boundaries next to it: an O(1) update (``begin`` /
    ``flip``).  Results match ``plan_cost`` to float-summation-order
    (~1e-12 relative; asserted at 1e-9 by tests/test_plan_evaluator.py).

    Slice layout: every operator contributes ``granularity[op.name]``
    slices (default 1 — ``plan_cost``'s layout for missing decisions).
    """

    def __init__(self, desc: ModelDescription, env: CostEnv,
                 granularity: Optional[Dict[str, int]] = None):
        self.desc = desc
        self.env = env
        gran = granularity or {}
        dev = env.device
        tp = env.n_tp
        seq = desc.shape.seq_len
        n_d = env.n_data
        n_l = env.n_data_local
        n_pods = n_d // max(1, n_l)
        rounds = (3 + (1 if env.checkpointing else 0)) if env.train else 1
        bw_data = dev.link_bw("data")
        bw_pod = dev.link_bw("pod")
        bw_zdp = min(dev.link_bw(a) for a in env.mesh.axes
                     if a in ("pod", "data"))

        ops = desc.operators
        self.n_ops = len(ops)
        self.op_names = [op.name for op in ops]
        self.granularity = np.array(
            [max(1, gran.get(op.name, 1)) for op in ops], dtype=np.int64)
        self.op_start = np.zeros(self.n_ops, dtype=np.int64)
        np.cumsum(self.granularity[:-1], out=self.op_start[1:])
        self.n_slices = int(self.granularity.sum())
        self.slice_op = np.repeat(np.arange(self.n_ops), self.granularity)

        g = self.granularity.astype(np.float64)
        state_b = np.array(
            [(op.state_bytes if env.train else op.param_bytes) / tp
             for op in ops])
        param_b = np.array([op.param_bytes / tp for op in ops])
        layers = np.array([max(1, op.layers) for op in ops],
                          dtype=np.float64)
        self.gathered = param_b / (layers * g)       # per non-DP run M_extra

        # batch slopes (per unit of per-device batch)
        act = np.array([op.act_bytes_per_token / tp for op in ops]) * seq
        if env.checkpointing:
            act = act / layers
        self._act_slope = float(act.sum())
        self._resident_slope = desc.resident_act_bytes_per_token * seq / tp
        comp = np.array([op.flops_per_token for op in ops]) * seq / tp \
            / (dev.peak_flops * dev.mxu_efficiency)
        if env.train:
            comp = comp * 3.0
        if env.checkpointing:
            comp = comp * 1.30
        self._comp_slope = float(comp.sum())

        # per-op per-mode tables; column order follows MODES
        mem_op = np.zeros((self.n_ops, len(MODES)))
        comm_op = np.zeros((self.n_ops, len(MODES)))     # per-slice additive
        self.mem_run = np.zeros((self.n_ops, len(MODES)))
        self.comm_run = np.zeros((self.n_ops, len(MODES)))
        sliced = param_b / g                              # per-slice bytes
        # DP: states replicated; grads all-reduced over the full data
        # extent (training only): alpha once per run, beta per slice
        mem_op[:, 0] = state_b / g
        if env.train and n_d > 1:
            comm_op[:, 0] = 2 * (n_d - 1) * (sliced / n_d / bw_data)
            self.comm_run[:, 0] = 2 * (n_d - 1) * dev.alpha
        # ZDP: flat gather over pod x data; alpha scales with run length
        # (chunked execution), so it is fully per-slice
        mem_op[:, 1] = state_b / g / n_d
        if n_d > 1:
            comm_op[:, 1] = rounds * (n_d - 1) * (
                dev.alpha + sliced / n_d / bw_zdp)
        self.mem_run[:, 1] = self.gathered
        # ZDP_POD: in-pod gather on ICI + cross-pod grad all-reduce
        mem_op[:, 2] = state_b / g / max(1, n_l)
        if n_l > 1:
            comm_op[:, 2] = rounds * (n_l - 1) * (
                dev.alpha + sliced / n_l / bw_data)
        if n_pods > 1:
            comm_op[:, 2] += 2 * (n_pods - 1) * (
                (sliced / n_l) / n_pods / bw_pod)
            self.comm_run[:, 2] = 2 * (n_pods - 1) * dev.alpha
        self.mem_run[:, 2] = self.gathered
        self.mem_slice = mem_op[self.slice_op]
        self.comm_slice = comm_op[self.slice_op]

        self._all_dp_static = float(self.mem_slice[:, 0].sum())
        # incremental state (begin/flip)
        self._modes: Optional[np.ndarray] = None
        self._batch = 0

    # -- layout helpers ------------------------------------------------------

    @classmethod
    def for_decisions(cls, desc: ModelDescription, env: CostEnv,
                      decisions: Dict[str, Decision]) -> "PlanEvaluator":
        """Evaluator whose slice layout matches an existing plan."""
        gran = {name: d.split for name, d in decisions.items()}
        return cls(desc, env, gran)

    def modes_from_decisions(
            self, decisions: Dict[str, Decision]) -> np.ndarray:
        modes = np.zeros(self.n_slices, dtype=np.int8)
        index = {m: i for i, m in enumerate(MODES)}
        for k, name in enumerate(self.op_names):
            dec = decisions.get(name)
            if dec is None:
                continue
            s = int(self.op_start[k])
            if dec.split != int(self.granularity[k]):
                raise ValueError(
                    f"{name}: decision split {dec.split} != evaluator "
                    f"layout {int(self.granularity[k])}")
            for j, m in enumerate(dec.modes):
                modes[s + j] = index[m]
        return modes

    def decisions(self, modes: np.ndarray) -> Dict[str, Decision]:
        out: Dict[str, Decision] = {}
        for k, name in enumerate(self.op_names):
            s = int(self.op_start[k])
            e = s + int(self.granularity[k])
            out[name] = Decision(
                name, tuple(MODES[m] for m in modes[s:e]))
        return out

    # -- vectorized full evaluation ------------------------------------------

    def _bpd(self, global_batch: int) -> int:
        return max(1, global_batch // self.env.n_data)

    def all_dp_memory(self, global_batch: int) -> float:
        """Steady memory of the all-DP plan (the search's base cost)."""
        bpd = self._bpd(global_batch)
        return (self._all_dp_static + self._resident_slope * bpd
                + self._act_slope * bpd)

    def _static_sums(self, modes: np.ndarray) -> Tuple[float, float, float]:
        """(steady memory w/o batch terms, comm seconds, peak extra)."""
        idx = np.arange(self.n_slices)
        mem = float(self.mem_slice[idx, modes].sum())
        comm = float(self.comm_slice[idx, modes].sum())
        starts = np.empty(self.n_slices, dtype=bool)
        starts[0] = True
        np.logical_or(modes[1:] != modes[:-1],
                      self.slice_op[1:] != self.slice_op[:-1],
                      out=starts[1:])
        ops_r = self.slice_op[starts]
        modes_r = modes[starts]
        mem += float(self.mem_run[ops_r, modes_r].sum())
        comm += float(self.comm_run[ops_r, modes_r].sum())
        nonzero = np.add.reduceat(
            (modes != 0).astype(np.int64), self.op_start)
        peak = float(self.gathered[nonzero > 0].max()) \
            if bool((nonzero > 0).any()) else 0.0
        return mem, comm, peak

    def plan_cost(self, modes: np.ndarray,
                  global_batch: int) -> PlanCost:
        """Full vectorized evaluation — `cost_model.plan_cost` semantics."""
        mem_s, comm, peak = self._static_sums(modes)
        bpd = self._bpd(global_batch)
        mem = float(mem_s + self._resident_slope * bpd
                    + self._act_slope * bpd)
        compute = self._comp_slope * bpd
        time = comm + compute
        tokens = global_batch * self.desc.shape.seq_len
        return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                        comm_time=comm, compute_time=compute,
                        throughput=tokens / time if time > 0 else 0.0)

    # -- incremental evaluation ----------------------------------------------

    def begin(self, modes: np.ndarray, global_batch: int) -> None:
        """Start an incremental evaluation from `modes` (copied)."""
        self._modes = np.asarray(modes, dtype=np.int8).copy()
        self._batch = global_batch
        mem_s, comm, _ = self._static_sums(self._modes)
        self._mem_static = mem_s
        self._comm = comm
        self._nonzero = np.add.reduceat(
            (self._modes != 0).astype(np.int64), self.op_start)

    def _run_const_window(self, j: int, k: int, mode_j: int) -> \
            Tuple[float, float]:
        """Run-constant contribution of the boundaries at j and j+1 if
        slice j had mode `mode_j` (neighbours read from current state)."""
        modes = self._modes
        mem = comm = 0.0
        left_same = j > 0 and int(self.slice_op[j - 1]) == k
        if (not left_same) or int(modes[j - 1]) != mode_j:
            mem += self.mem_run[k, mode_j]
            comm += self.comm_run[k, mode_j]
        right = j + 1
        if right < self.n_slices and int(self.slice_op[right]) == k:
            mr = int(modes[right])
            if mr != mode_j:
                mem += self.mem_run[k, mr]
                comm += self.comm_run[k, mr]
        return mem, comm

    def flip(self, j: int, new_mode: int) -> None:
        """O(1): change slice j's mode in the running evaluation."""
        assert self._modes is not None, "begin() first"
        old = int(self._modes[j])
        if old == new_mode:
            return
        k = int(self.slice_op[j])
        self._mem_static += float(self.mem_slice[j, new_mode]
                                  - self.mem_slice[j, old])
        self._comm += float(self.comm_slice[j, new_mode]
                            - self.comm_slice[j, old])
        mem_b, comm_b = self._run_const_window(j, k, old)
        mem_a, comm_a = self._run_const_window(j, k, new_mode)
        self._mem_static += float(mem_a - mem_b)
        self._comm += float(comm_a - comm_b)
        self._modes[j] = new_mode
        self._nonzero[k] += (new_mode != 0) - (old != 0)

    @property
    def current_modes(self) -> np.ndarray:
        """Mode indices of the running evaluation (live view)."""
        assert self._modes is not None, "begin() first"
        return self._modes

    @property
    def memory(self) -> float:
        """Steady per-device bytes of the running evaluation."""
        bpd = self._bpd(self._batch)
        return (self._mem_static + self._resident_slope * bpd
                + self._act_slope * bpd)

    def result(self) -> PlanCost:
        """PlanCost of the running evaluation (peak recomputed exactly)."""
        bpd = self._bpd(self._batch)
        mem = self.memory
        compute = self._comp_slope * bpd
        time = self._comm + compute
        peak = float(self.gathered[self._nonzero > 0].max()) \
            if bool((self._nonzero > 0).any()) else 0.0
        tokens = self._batch * self.desc.shape.seq_len
        return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                        comm_time=self._comm, compute_time=compute,
                        throughput=tokens / time if time > 0 else 0.0)


# convenience whole-model plans ----------------------------------------------

def uniform_plan(desc: ModelDescription, mode: str,
                 split: int = 1) -> Dict[str, Decision]:
    out = {}
    for op in desc.operators:
        if not op.decidable:
            out[op.name] = Decision(op.name, (DP,))
        else:
            g = split if (split > 1 and op.splittable) else 1
            out[op.name] = Decision(op.name, (mode,) * g)
    return out


def zdp_saving(op: OperatorDesc, env: CostEnv, mode: str = ZDP,
               split: int = 1) -> float:
    """Net memory bytes saved by moving op from DP to `mode` at slice
    granularity `split`: sharded model states minus the transiently
    gathered per-layer slice (paper M_extra; shrinks with splitting)."""
    n = shard_ways(mode, env)
    s = op.state_bytes / env.n_tp
    gathered = op.param_bytes / env.n_tp / (max(1, op.layers) * max(1, split))
    return max(0.0, s * (1 - 1 / n) - gathered)


def zdp_extra_time(op: OperatorDesc, env: CostEnv, mode: str = ZDP) -> float:
    """Per-step seconds added by moving op from DP to `mode`."""
    d_dp = Decision(op.name, (DP,))
    d_z = Decision(op.name, (mode,))
    # batch/seq affect only compute, identical across modes -> use 1,1
    c_dp = op_cost(op, d_dp, 1, 1, env)
    c_z = op_cost(op, d_z, 1, 1, env)
    return c_z.comm_time - c_dp.comm_time
