"""OSDP cost model — the paper's §3.1 Profiler, on TPU constants.

Memory:
    M_i(p_i, b) = M_model_i / (1 or N_shard) + b * M_act_i + M_extra_i

Time ((alpha, beta, gamma) model, ring collectives):
    T_i(p_i, b) = k (N-1)(alpha + S_i beta / N) + b * gamma_i
with k = 2 for DP (all-reduce = reduce-scatter + all-gather) and
k = 3 for ZDP (two all-gathers + one reduce-scatter); +1 for ZDP when
activation checkpointing forces a third parameter gather before the
recompute pass (§4.3).

Beyond-paper additions, all flagged explicitly:
  * ZDP_POD — hierarchical sharding across only the in-pod `data` axis:
    memory /N_pod-local, collectives stay on fast ICI.
  * per-mode gathered-weight peak (M_extra): in ZDP the un-sharded
    weight must transiently exist; operator splitting divides it by g.
  * MoE awareness: expert FLOPs scale with top-k, not E.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import DeviceInfo, MeshConfig
from repro.core.descriptions import (ACT_BYTES, BYTES_PER_PARAM,
                                     ModelDescription, OperatorDesc,
                                     STATE_BYTES_PER_PARAM)

# parallel modes -------------------------------------------------------------
DP = "DP"
ZDP = "ZDP"
ZDP_POD = "ZDP_POD"      # beyond-paper hierarchical mode
MODES = (DP, ZDP, ZDP_POD)


@dataclass(frozen=True)
class Decision:
    """Plan entry for one operator: per-slice modes.

    `modes` has length 1 for unsplit operators, length g for split ones
    (paper §3.3: each slice is independently DP or ZDP).
    """

    op: str
    modes: Tuple[str, ...]

    @property
    def split(self) -> int:
        return len(self.modes)

    def uniform(self) -> Optional[str]:
        return self.modes[0] if len(set(self.modes)) == 1 else None


@dataclass(frozen=True)
class CostEnv:
    """Everything the Profiler needs besides the plan."""

    device: DeviceInfo
    mesh: MeshConfig
    checkpointing: bool = True
    # TP already divides each operator's params across the model axis;
    # OSDP decides the data-axis story for the per-TP-shard residue.
    include_tp: bool = True
    # training = fwd + bwd (2x fwd) compute; False for serving estimates
    train: bool = True

    @property
    def n_data(self) -> int:
        return self.mesh.data_parallel          # pod x data ways

    @property
    def n_data_local(self) -> int:
        for s, a in zip(self.mesh.shape, self.mesh.axes):
            if a == "data":
                return s
        return 1

    @property
    def n_tp(self) -> int:
        return self.mesh.model_parallel if self.include_tp else 1


def shard_ways(mode: str, env: CostEnv) -> int:
    if mode == DP:
        return 1
    if mode == ZDP:
        return env.n_data
    if mode == ZDP_POD:
        return env.n_data_local
    raise ValueError(mode)


def _ring_time(bytes_total: float, n: int, alpha: float, bw: float) -> float:
    """One ring all-gather / reduce-scatter over n ranks."""
    if n <= 1:
        return 0.0
    return (n - 1) * (alpha + bytes_total / n / bw)


@dataclass
class OpCost:
    memory: float          # steady per-device bytes for this op's states
    peak_extra: float      # transient gathered-weight bytes
    time: float            # seconds per step (comm + compute)
    comm_time: float
    compute_time: float


def op_cost(op: OperatorDesc, decision: Decision, batch_per_device: int,
            seq_len: int, env: CostEnv) -> OpCost:
    """Cost of one operator under `decision` (§3.1 equations)."""
    g = decision.split
    dev = env.device
    tp = env.n_tp
    # per-TP-shard sizes; OSDP reasons about the per-device residue
    # training holds optimizer states; serving only the bf16 weights
    state_bytes = (op.state_bytes if env.train else op.param_bytes) / tp
    param_bytes = op.param_bytes / tp
    tokens = batch_per_device * seq_len
    act = op.act_bytes_per_token / tp * tokens
    if env.checkpointing:
        # activations inside a layer are rematerialized: only one layer's
        # working set is live (the layer-boundary checkpoints are counted
        # once in ModelDescription.resident_act_bytes_per_token)
        act /= max(1, op.layers)
    compute = (op.flops_per_token * tokens / tp
               / (dev.peak_flops * dev.mxu_efficiency))
    if env.train:
        compute *= 3.0            # fwd + bwd (2x fwd)
    if env.checkpointing:
        compute *= 1.30           # the paper's ~30% recompute overhead

    # merge adjacent same-mode slices: the implementation stores them as
    # one array -> one collective (sharding.specs._merge_modes), so the
    # cost model must too, or uniform split plans would be over-charged
    # (N-1) alpha per slice.
    runs: List[Tuple[str, int]] = []
    for mode in decision.modes:
        if runs and runs[-1][0] == mode:
            runs[-1] = (mode, runs[-1][1] + 1)
        else:
            runs.append((mode, 1))

    mem = 0.0
    peak = 0.0
    comm = 0.0
    for mode, run_len in runs:
        s_bytes = state_bytes * run_len / g
        p_bytes = param_bytes * run_len / g
        n = shard_ways(mode, env)
        mem += s_bytes / n
        if mode == DP:
            # grads all-reduced over the full data extent (training only)
            if env.train:
                comm += 2 * _ring_time(p_bytes, env.n_data, dev.alpha,
                                       dev.link_bw("data"))
        else:
            if env.train:
                rounds = 3 + (1 if env.checkpointing else 0)
            else:
                rounds = 1    # serving: one forward gather, no grad sync
            # splitting processes the run's slices sequentially: one
            # collective per slice -> alpha charged run_len times, beta
            # on the total bytes (matches chunked execution).
            alpha_eff = dev.alpha * run_len
            if mode == ZDP:
                # flat all-gather over pod x data; bottleneck link is the
                # slowest axis crossed
                bw = min(dev.link_bw(a) for a in env.mesh.axes
                         if a in ("pod", "data"))
                comm += rounds * _ring_time(p_bytes, env.n_data, alpha_eff,
                                            bw)
            else:  # ZDP_POD: gather within pod over ICI; grads still
                # all-reduced across pods (DP over the pod axis)
                comm += rounds * _ring_time(p_bytes, env.n_data_local,
                                            alpha_eff, dev.link_bw("data"))
                n_pods = env.n_data // env.n_data_local
                comm += 2 * _ring_time(p_bytes / env.n_data_local, n_pods,
                                       dev.alpha, dev.link_bw("pod"))
            # M_extra (paper §3.1/§3.3): the gathered slice is transient
            # but counted additively per op, at the granularity actually
            # gathered — one layer's slice (scan gathers per layer).
            gathered = param_bytes / (max(1, op.layers) * g)
            mem += gathered
            peak = max(peak, gathered)
    return OpCost(memory=mem + act, peak_extra=peak, time=comm + compute,
                  comm_time=comm, compute_time=compute)


@dataclass
class PlanCost:
    memory: float        # steady per-device bytes
    peak_memory: float   # steady + worst transient gather
    time: float          # seconds per step
    comm_time: float
    compute_time: float
    throughput: float    # tokens / s (global)


def plan_cost(desc: ModelDescription, decisions: Dict[str, Decision],
              global_batch: int, env: CostEnv) -> PlanCost:
    """The paper's T(p, b), M(p, b) over the whole operator list."""
    bpd = max(1, global_batch // env.n_data)
    seq = desc.shape.seq_len
    mem = desc.resident_act_bytes_per_token * bpd * seq / env.n_tp
    peak = 0.0
    time = comm = compute = 0.0
    for op in desc.operators:
        dec = decisions.get(op.name)
        if dec is None:
            dec = Decision(op.name, (DP,))
        c = op_cost(op, dec, bpd, seq, env)
        mem += c.memory
        peak = max(peak, c.peak_extra)
        time += c.time
        comm += c.comm_time
        compute += c.compute_time
    tokens = global_batch * seq
    return PlanCost(memory=mem, peak_memory=mem + peak, time=time,
                    comm_time=comm, compute_time=compute,
                    throughput=tokens / time if time > 0 else 0.0)


# convenience whole-model plans ----------------------------------------------

def uniform_plan(desc: ModelDescription, mode: str,
                 split: int = 1) -> Dict[str, Decision]:
    out = {}
    for op in desc.operators:
        if not op.decidable:
            out[op.name] = Decision(op.name, (DP,))
        else:
            g = split if (split > 1 and op.splittable) else 1
            out[op.name] = Decision(op.name, (mode,) * g)
    return out


def zdp_saving(op: OperatorDesc, env: CostEnv, mode: str = ZDP,
               split: int = 1) -> float:
    """Net memory bytes saved by moving op from DP to `mode` at slice
    granularity `split`: sharded model states minus the transiently
    gathered per-layer slice (paper M_extra; shrinks with splitting)."""
    n = shard_ways(mode, env)
    s = op.state_bytes / env.n_tp
    gathered = op.param_bytes / env.n_tp / (max(1, op.layers) * max(1, split))
    return max(0.0, s * (1 - 1 / n) - gathered)


def zdp_extra_time(op: OperatorDesc, env: CostEnv, mode: str = ZDP) -> float:
    """Per-step seconds added by moving op from DP to `mode`."""
    d_dp = Decision(op.name, (DP,))
    d_z = Decision(op.name, (mode,))
    # batch/seq affect only compute, identical across modes -> use 1,1
    c_dp = op_cost(op, d_dp, 1, 1, env)
    c_z = op_cost(op, d_z, 1, 1, env)
    return c_z.comm_time - c_dp.comm_time
