"""Production mesh construction.

Functions, not module-level constants — importing this module never
touches jax device state (required so smoke tests see 1 CPU device
while the dry-run sees 512 forced host devices).
"""
from __future__ import annotations

import jax

from repro.configs.base import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_mesh_from_config(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axes)


def make_hybrid_mesh(plan_or_factorization):
    """3-axis (data, model, pipe) mesh for a HybridPlan / Factorization.

    Accepts a `core.hybrid.HybridPlan`, a `core.hybrid.Factorization`,
    or anything else exposing `.mesh_config()`.
    """
    cfg = plan_or_factorization.mesh_config()
    return jax.make_mesh(cfg.shape, cfg.axes)


def make_cluster_mesh(spec, model_parallel: int = 1,
                      pipeline_parallel: int = 1):
    """Mesh whose axis order mirrors a `ClusterSpec`'s hierarchy: one
    axis per (ways > 1) level, outermost first, then `model` (and
    `pipe` when pipelined) — so jax's device order walks the innermost
    (fastest) level fastest and every level-k ZDP axis lands on the
    physical links the cost model priced it against.
    """
    cfg = spec.mesh_config(model_parallel=model_parallel,
                           pipeline_parallel=pipeline_parallel)
    return jax.make_mesh(cfg.shape, cfg.axes)


def make_host_mesh():
    """1x1 mesh on the real local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
