import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh).

For each combination this proves, without hardware:
  * the OSDP plan's PartitionSpecs are coherent (no sharding mismatch),
  * the program fits the mesh (memory_analysis reports bytes/device),
  * the collective schedule exists (counted for §Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]

The XLA_FLAGS line above MUST run before any other jax-touching import
(jax locks the device count at first init).
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import (ARCHS, MULTI_POD_MESH, SINGLE_POD_MESH, OSDPConfig,
                           RunConfig, get_arch, get_shape, supported_shapes)
from repro.core.plan import make_plan
from repro.launch.mesh import make_mesh_from_config
from repro.models.registry import (Built, build_model, input_shardings,
                                   input_specs)
from repro.optim import init_state, state_shardings
from repro.roofline.analysis import analyze_lowered, hlo_flops_bytes


def _attach_shardings(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                osdp: Optional[OSDPConfig] = None, compile_: bool = True,
                verbose: bool = True,
                device=None, overlap=None) -> Dict[str, Any]:
    """Lower (+ compile) one (arch, shape, mesh). Returns the record for
    EXPERIMENTS.md §Dry-run / §Roofline.  `device` (a DeviceInfo, e.g.
    from `DeviceInfo.preset`) changes the planner's hardware constants;
    the forced host mesh stays the same.  `overlap` (an
    `sharding.specs.OverlapConfig`) lowers the overlapped runtime —
    prefetch barriers + gradient buckets — instead of the legacy
    program."""
    t_start = time.perf_counter()
    model_cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_cfg = MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH
    osdp = osdp or OSDPConfig()
    run = RunConfig(model=model_cfg, shape=shape, mesh=mesh_cfg, osdp=osdp)
    plan = make_plan(run, device)
    mesh = make_mesh_from_config(mesh_cfg)
    built = build_model(run, plan, mesh, overlap=overlap)
    model = built.model

    abstract_params = _attach_shardings(built.abstract_params(),
                                        built.shardings)
    inputs = input_specs(run)
    in_sh = input_shardings(run, mesh, inputs)
    inputs = _attach_shardings(inputs, in_sh)

    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())

    import jax.numpy as jnp
    from repro.optim import AdamWConfig, AdamWState, apply_update
    from repro.train.loop import loss_and_grads

    with mesh:
        if shape.kind == "train":
            opt_abstract = jax.eval_shape(init_state, abstract_params)
            opt_sh = state_shardings(built.shardings, repl)
            opt_abstract = _attach_shardings(
                opt_abstract._asdict(), opt_sh._asdict())

            def train_step(params, master, m, v, stepc, batch):
                st = AdamWState(stepc, master, m, v)
                loss, metrics, grads = loss_and_grads(model, params, batch)
                p2, st2, _ = apply_update(AdamWConfig(), params, grads, st,
                                          jnp.float32(1.0))
                return p2, st2.master, st2.m, st2.v, st2.step, loss

            psh = built.shardings
            lowered = jax.jit(
                train_step,
                in_shardings=(psh, psh, psh, psh, repl, in_sh),
                out_shardings=(psh, psh, psh, psh, repl, repl),
            ).lower(abstract_params,
                    opt_abstract["master"], opt_abstract["m"],
                    opt_abstract["v"], opt_abstract["step"], inputs)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill(params, batch)
            lowered = jax.jit(prefill_step).lower(abstract_params, inputs)
        else:  # decode
            def serve_step(params, caches, tokens, t, positions3=None):
                return model.decode_step(params, caches, tokens, t,
                                         positions3=positions3)
            args = [abstract_params, inputs["caches"], inputs["tokens"],
                    inputs["t"]]
            if "positions3" in inputs:
                args.append(inputs["positions3"])
            lowered = jax.jit(serve_step).lower(*args)

        t_lower = time.perf_counter()
        rec: Dict[str, Any] = {
            "arch": arch, "shape": shape_name, "kind": shape.kind,
            "mesh": "x".join(map(str, mesh_cfg.shape)),
            "n_chips": mesh_cfg.n_devices,
            "params": model_cfg.param_count(),
            "active_params": model_cfg.active_param_count(),
            "tokens": (shape.tokens if shape.kind != "decode"
                       else shape.global_batch),
            "plan": _plan_digest(plan),
            "est_memory_gib": plan.cost.memory / 2**30,
            "lower_s": t_lower - t_start,
        }

        if compile_:
            compiled = lowered.compile()
            t_compile = time.perf_counter()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # collectives exist only after SPMD partitioning -> compiled text
            rec["collectives"] = analyze_lowered(compiled.as_text())
            rec.update({
                "compile_s": t_compile - t_lower,
                "memory_analysis": _mem_dict(mem),
                "cost_analysis": hlo_flops_bytes(cost),
            })
        else:
            rec["collectives"] = analyze_lowered(lowered.as_text())
        if verbose:
            _print_rec(rec)
        return rec


def _plan_digest(plan) -> Dict[str, Any]:
    from repro.core.cost_model import DP
    modes: Dict[str, str] = {}
    for name, dec in plan.decisions.items():
        u = dec.uniform()
        modes[name] = u if u else "MIXED(" + ",".join(dec.modes) + ")"
    return modes


def _mem_dict(mem) -> Dict[str, float]:
    keys = ("temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def _print_rec(rec: Dict[str, Any]) -> None:
    mem = rec.get("memory_analysis", {})
    cost = rec.get("cost_analysis", {})
    coll = rec.get("collectives", {})
    arg_gib = mem.get("argument_size_in_bytes", 0) / 2**30
    tmp_gib = mem.get("temp_size_in_bytes", 0) / 2**30
    print(f"[dryrun] {rec['arch']} x {rec['shape']} @ {rec['mesh']}: "
          f"lower {rec['lower_s']:.1f}s compile {rec.get('compile_s', 0):.1f}s"
          f" | args {arg_gib:.2f} GiB temp {tmp_gib:.2f} GiB"
          f" | flops {cost.get('flops', 0):.3e}"
          f" | coll bytes {coll.get('total_bytes', 0):.3e}")
    sys.stdout.flush()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--force-mode", default=None, choices=["DP", "ZDP"])
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="DeviceInfo preset for the planner "
                         "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
    ap.add_argument("--overlap", default=None, metavar="FACTOR",
                    help="comm/compute overlap factor in [0, 1] (or "
                         "'auto' with --device) for the planner's "
                         "timeline model; also lowers the overlapped "
                         "runtime (prefetch + gradient buckets)")
    ap.add_argument("--out", default=None, help="write records JSON here")
    args = ap.parse_args(argv)

    import dataclasses as _dc
    from repro.configs import DeviceInfo
    overlap = None
    if args.overlap is not None:
        ov = args.overlap if args.overlap == "auto" else float(args.overlap)
        if args.device:
            device = DeviceInfo.preset(args.device, overlap=ov)
        elif ov == "auto":
            ap.error("--overlap auto needs a --device preset")
        else:
            device = _dc.replace(DeviceInfo(), overlap=ov)
        from repro.sharding.specs import OverlapConfig
        overlap = OverlapConfig()
    else:
        device = DeviceInfo.preset(args.device) if args.device else None
    osdp = OSDPConfig(force_mode=args.force_mode) if args.force_mode \
        else None
    combos = []
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    if args.all:
        for arch, cfg in ARCHS.items():
            for shape in supported_shapes(cfg):
                for mp in meshes:
                    combos.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            combos.append((args.arch, args.shape, mp))

    records, failures = [], []
    for arch, shape, mp in combos:
        try:
            records.append(lower_combo(arch, shape, multi_pod=mp,
                                       osdp=osdp, device=device,
                                       overlap=overlap,
                                       compile_=not args.no_compile))
        except Exception as e:  # noqa: BLE001 - report and continue
            traceback.print_exc()
            failures.append((arch, shape, mp, repr(e)))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    print(f"\n[dryrun] {len(records)}/{len(combos)} combos OK, "
          f"{len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
