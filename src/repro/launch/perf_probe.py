import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf probe CLI: lower one combo with configurable knobs, dump the
roofline-relevant evidence (memory_analysis, collective census by
scope, largest buffers) so hypothesis -> change -> measure cycles can
diff variants.

  python -m repro.launch.perf_probe --arch llama3-405b --shape train_4k \
      [--multi-pod] [--force-mode ZDP] [--no-remat] [--microbatch 4] \
      [--tag baseline]
"""
import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force-mode", default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-split", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--memory-gib", type=float, default=16.0)
    ap.add_argument("--tag", default="probe")
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args(argv)

    import dataclasses
    import jax
    from repro.configs import (MULTI_POD_MESH, SINGLE_POD_MESH, OSDPConfig,
                               RunConfig, get_arch, get_shape)
    from repro.core.plan import make_plan
    from repro.launch.dryrun import (_attach_shardings, _mem_dict)
    from repro.launch.mesh import make_mesh_from_config
    from repro.models.registry import (build_model, input_shardings,
                                       input_specs)
    from repro.roofline.analysis import analyze_lowered, hlo_flops_bytes
    from repro.roofline.probe import collectives_by_scope, largest_tensors

    t0 = time.perf_counter()
    model_cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    mesh_cfg = MULTI_POD_MESH if args.multi_pod else SINGLE_POD_MESH
    osdp = OSDPConfig(
        memory_limit_bytes=args.memory_gib * 2**30,
        force_mode=args.force_mode,
        checkpointing=not args.no_remat,
        operator_splitting=not args.no_split,
    )
    run = RunConfig(model=model_cfg, shape=shape, mesh=mesh_cfg, osdp=osdp,
                    microbatch=args.microbatch)
    plan = make_plan(run)
    mesh = make_mesh_from_config(mesh_cfg)
    built = build_model(run, plan, mesh)
    model = built.model

    abstract_params = _attach_shardings(built.abstract_params(),
                                        built.shardings)
    inputs = input_specs(run)
    in_sh = input_shardings(run, mesh, inputs)
    inputs = _attach_shardings(inputs, in_sh)

    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.optim import AdamWConfig, AdamWState, apply_update, init_state
    from repro.optim import state_shardings
    from repro.train.loop import loss_and_grads
    repl = NamedSharding(mesh, P())

    with mesh:
        if shape.kind == "train":
            opt_abstract = jax.eval_shape(init_state, abstract_params)
            opt_sh = state_shardings(built.shardings, repl)
            opt_abstract = _attach_shardings(opt_abstract._asdict(),
                                             opt_sh._asdict())

            def train_step(params, master, m, v, stepc, batch):
                st = AdamWState(stepc, master, m, v)
                loss, metrics, grads = loss_and_grads(
                    model, params, batch, run.microbatch)
                p2, st2, _ = apply_update(AdamWConfig(), params, grads, st,
                                          jnp.float32(1.0))
                return p2, st2.master, st2.m, st2.v, st2.step, loss

            psh = built.shardings
            lowered = jax.jit(
                train_step,
                in_shardings=(psh, psh, psh, psh, repl, in_sh),
                out_shardings=(psh, psh, psh, psh, repl, repl),
            ).lower(abstract_params, opt_abstract["master"],
                    opt_abstract["m"], opt_abstract["v"],
                    opt_abstract["step"], inputs)
        elif shape.kind == "prefill":
            lowered = jax.jit(lambda p, b: model.prefill(p, b)).lower(
                abstract_params, inputs)
        else:
            def serve_step(params, caches, tokens, t, positions3=None):
                return model.decode_step(params, caches, tokens, t,
                                         positions3=positions3)
            a = [abstract_params, inputs["caches"], inputs["tokens"],
                 inputs["t"]]
            if "positions3" in inputs:
                a.append(inputs["positions3"])
            lowered = jax.jit(serve_step).lower(*a)

        compiled = lowered.compile()
        txt = compiled.as_text()
        rec = {
            "tag": args.tag, "arch": args.arch, "shape": args.shape,
            "mesh": "x".join(map(str, mesh_cfg.shape)),
            "elapsed_s": time.perf_counter() - t0,
            "memory_analysis": _mem_dict(compiled.memory_analysis()),
            "cost_analysis": hlo_flops_bytes(compiled.cost_analysis()),
            "collectives": analyze_lowered(txt),
            "collectives_by_scope": collectives_by_scope(txt),
            "largest_gib": [
                (round(g, 3), n) for g, n in largest_tensors(txt)],
        }
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(txt)
    print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
