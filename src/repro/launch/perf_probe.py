"""§Perf probe CLI: lower one combo with configurable knobs, dump the
roofline-relevant evidence (memory_analysis, collective census by
scope, largest buffers) so hypothesis -> change -> measure cycles can
diff variants.

  python -m repro.launch.perf_probe --arch llama3-405b --shape train_4k \
      [--multi-pod] [--force-mode ZDP] [--no-remat] [--microbatch 4] \
      [--measure-bw] [--device tpu-v5e] [--tag baseline]

`--measure-bw` times an all-gather over every mesh axis and reports
the *achieved* per-level bandwidth; with `--device` the record pairs
those numbers against the preset ClusterSpec's assumed
`ClusterLevel.bandwidth`/`overlap`, a sanity check for the overlap
factors fed to the two-resource timeline (docs/cost_model.md §9).

The 512-host-device XLA flag is set inside `main()` — before jax is
imported — so importing this module (e.g. pytest collection) leaves
the process environment untouched.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_XLA_FLAG = "--xla_force_host_platform_device_count=512"


def measure_level_bandwidth(mesh, size_mib: float = 4.0,
                            repeats: int = 3) -> dict:
    """Timed all-gather over each mesh axis: achieved bytes/s per
    level of the hierarchy the mesh spans.  Axes of span 1 move no
    bytes and report ``achieved_bytes_per_s: None``.  On the forced
    host platform the numbers measure the emulation backend — still
    useful for relative axis-to-axis comparison; on real hardware
    they bound how much overlap credit a level can honestly claim.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    out = {}
    n = max(1, int(size_mib * 2**20) // 4)
    for axis in mesh.axis_names:
        ways = int(mesh.shape[axis])
        if ways < 2:
            out[axis] = {"ways": ways, "bytes_moved": 0, "seconds": 0.0,
                         "achieved_bytes_per_s": None}
            continue
        n_ax = max(ways, (n // ways) * ways)
        x = jax.device_put(jnp.zeros((n_ax,), jnp.float32),
                           NamedSharding(mesh, P(axis)))
        gather = jax.jit(lambda v: v + 1.0,
                         out_shardings=NamedSharding(mesh, P()))
        jax.block_until_ready(gather(x))          # compile + warm up
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(gather(x))
        dt = (time.perf_counter() - t0) / repeats
        # ring all-gather: each device receives (ways-1)/ways of the array
        moved = 4 * n_ax * (ways - 1) // ways
        out[axis] = {"ways": ways, "bytes_moved": moved, "seconds": dt,
                     "achieved_bytes_per_s": moved / dt if dt > 0 else None}
    return out


def bandwidth_sweep(mesh, sizes_mib=(0.25, 1.0, 4.0, 16.0),
                    repeats: int = 3) -> dict:
    """`measure_level_bandwidth` swept over message sizes, with a
    per-axis alpha-beta fit: ``t(B) = alpha + B / bandwidth``.  The
    fitted constants are what `repro calibrate` ships as per-level
    `LinkCalibration`s; span-1 axes report ``fit: None``.  Returns
    ``{axis: {"samples": [(bytes, seconds), ...], "fit": {"alpha":
    s, "bandwidth": bytes/s} | None}}``."""
    from repro.calibrate.fit import fit_alpha_beta

    out = {str(a): {"samples": [], "fit": None}
           for a in mesh.axis_names}
    for mib in sizes_mib:
        rec = measure_level_bandwidth(mesh, size_mib=mib,
                                      repeats=repeats)
        for axis, row in rec.items():
            if row["bytes_moved"] > 0:
                out[str(axis)]["samples"].append(
                    (row["bytes_moved"], row["seconds"]))
    for axis, row in out.items():
        if len({b for b, _ in row["samples"]}) >= 2:
            alpha, bw = fit_alpha_beta(row["samples"])
            row["fit"] = {"alpha": alpha, "bandwidth": bw}
    return out


def overlap_sanity(measured: dict, device_name: str,
                   n_devices: int) -> list:
    """Pair measured per-axis bandwidth with the preset ClusterSpec's
    assumed level bandwidths (innermost axis <-> innermost level).
    ``achieved_over_spec`` far below 1 says the level's `overlap`
    factor is optimistic for this backend."""
    from repro.cluster.topology import ClusterSpec
    from repro.configs import DeviceInfo

    spec = ClusterSpec.from_device(DeviceInfo.preset(device_name),
                                   n_devices)
    rows = []
    axes = [a for a in reversed(list(measured))
            if measured[a]["ways"] > 1]
    for axis, level in zip(axes, spec.levels):
        got = measured[axis]["achieved_bytes_per_s"]
        rows.append({
            "axis": axis, "level": level.name,
            "spec_bytes_per_s": level.bandwidth,
            "spec_overlap": level.overlap,
            "achieved_bytes_per_s": got,
            "achieved_over_spec":
                round(got / level.bandwidth, 6) if got else None,
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force-mode", default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-split", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--memory-gib", type=float, default=16.0)
    ap.add_argument("--tag", default="probe")
    ap.add_argument("--dump-hlo", default=None)
    ap.add_argument("--measure-bw", action="store_true",
                    help="time an all-gather per mesh axis (achieved "
                         "per-level bandwidth)")
    ap.add_argument("--bw-mib", type=float, default=4.0)
    ap.add_argument("--bw-sweep", action="store_true",
                    help="sweep message sizes per axis and fit "
                         "alpha-beta link constants (the collective "
                         "half of `repro calibrate`)")
    ap.add_argument("--device", default=None,
                    help="DeviceInfo preset to compare measured "
                         "bandwidth against (overlap sanity check)")
    args = ap.parse_args(argv)

    # Must land before the first jax import; setdefault lets callers
    # (tests, small hosts) force a smaller fake-device count.
    os.environ.setdefault("XLA_FLAGS", _XLA_FLAG)

    import jax
    from repro.configs import (MULTI_POD_MESH, SINGLE_POD_MESH, OSDPConfig,
                               RunConfig, get_arch, get_shape)
    from repro.core.plan import make_plan
    from repro.launch.dryrun import (_attach_shardings, _mem_dict)
    from repro.launch.mesh import make_mesh_from_config
    from repro.models.registry import (build_model, input_shardings,
                                       input_specs)
    from repro.roofline.analysis import analyze_lowered, hlo_flops_bytes
    from repro.roofline.probe import collectives_by_scope, largest_tensors

    t0 = time.perf_counter()
    model_cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    mesh_cfg = MULTI_POD_MESH if args.multi_pod else SINGLE_POD_MESH
    osdp = OSDPConfig(
        memory_limit_bytes=args.memory_gib * 2**30,
        force_mode=args.force_mode,
        checkpointing=not args.no_remat,
        operator_splitting=not args.no_split,
    )
    run = RunConfig(model=model_cfg, shape=shape, mesh=mesh_cfg, osdp=osdp,
                    microbatch=args.microbatch)
    plan = make_plan(run)
    mesh = make_mesh_from_config(mesh_cfg)
    built = build_model(run, plan, mesh)
    model = built.model

    abstract_params = _attach_shardings(built.abstract_params(),
                                        built.shardings)
    inputs = input_specs(run)
    in_sh = input_shardings(run, mesh, inputs)
    inputs = _attach_shardings(inputs, in_sh)

    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.optim import AdamWConfig, AdamWState, apply_update, init_state
    from repro.optim import state_shardings
    from repro.train.loop import loss_and_grads
    repl = NamedSharding(mesh, P())

    with mesh:
        if shape.kind == "train":
            opt_abstract = jax.eval_shape(init_state, abstract_params)
            opt_sh = state_shardings(built.shardings, repl)
            opt_abstract = _attach_shardings(opt_abstract._asdict(),
                                             opt_sh._asdict())

            def train_step(params, master, m, v, stepc, batch):
                st = AdamWState(stepc, master, m, v)
                loss, metrics, grads = loss_and_grads(
                    model, params, batch, run.microbatch)
                p2, st2, _ = apply_update(AdamWConfig(), params, grads, st,
                                          jnp.float32(1.0))
                return p2, st2.master, st2.m, st2.v, st2.step, loss

            psh = built.shardings
            lowered = jax.jit(
                train_step,
                in_shardings=(psh, psh, psh, psh, repl, in_sh),
                out_shardings=(psh, psh, psh, psh, repl, repl),
            ).lower(abstract_params, opt_abstract["master"],
                    opt_abstract["m"], opt_abstract["v"],
                    opt_abstract["step"], inputs)
        elif shape.kind == "prefill":
            lowered = jax.jit(lambda p, b: model.prefill(p, b)).lower(
                abstract_params, inputs)
        else:
            def serve_step(params, caches, tokens, t, positions3=None):
                return model.decode_step(params, caches, tokens, t,
                                         positions3=positions3)
            a = [abstract_params, inputs["caches"], inputs["tokens"],
                 inputs["t"]]
            if "positions3" in inputs:
                a.append(inputs["positions3"])
            lowered = jax.jit(serve_step).lower(*a)

        compiled = lowered.compile()
        txt = compiled.as_text()
        rec = {
            "tag": args.tag, "arch": args.arch, "shape": args.shape,
            "mesh": "x".join(map(str, mesh_cfg.shape)),
            "elapsed_s": time.perf_counter() - t0,
            "memory_analysis": _mem_dict(compiled.memory_analysis()),
            "cost_analysis": hlo_flops_bytes(compiled.cost_analysis()),
            "collectives": analyze_lowered(txt),
            "collectives_by_scope": collectives_by_scope(txt),
            "largest_gib": [
                (round(g, 3), n) for g, n in largest_tensors(txt)],
        }
        if args.measure_bw:
            measured = measure_level_bandwidth(mesh, size_mib=args.bw_mib)
            rec["measured_bandwidth"] = measured
            if args.device:
                rec["overlap_sanity"] = overlap_sanity(
                    measured, args.device, mesh.size)
        if args.bw_sweep:
            rec["bandwidth_sweep"] = bandwidth_sweep(mesh)
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(txt)
    print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
