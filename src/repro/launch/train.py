"""Training launcher.

    python -m repro.launch.train --arch qwen1.5-0.5b --steps 100 \
        [--reduced] [--seq 256 --batch 8] [--force-mode ZDP] \
        [--memory-gib 16] [--ckpt-dir /tmp/ckpt]

Runs the OSDP pipeline (describe -> search -> plan), builds the model
with the planned shardings on the local mesh, and trains on the
synthetic pipeline. On a real TPU slice the same RunConfig lowers
against make_production_mesh() instead (see launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax

from repro.configs import (DeviceInfo, MeshConfig, OSDPConfig, RunConfig,
                           get_arch, get_shape, reduced)
from repro.core.plan import make_plan
from repro.models.registry import build_model
from repro.optim import AdamWConfig
from repro.sharding.specs import OverlapConfig
from repro.train.loop import train


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-sized)")
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--memory-gib", type=float, default=16.0)
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="DeviceInfo preset the planner prices against "
                         "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
    ap.add_argument("--overlap", default=None, metavar="FACTOR",
                    help="comm/compute overlap: a factor in [0, 1] for "
                         "the planner's timeline model, or 'auto' for "
                         "the --device preset's catalog value; also "
                         "turns on the runtime prefetch + gradient-"
                         "bucketing transforms (default: off, serial "
                         "model, legacy program)")
    ap.add_argument("--overlap-prefetch", type=int, default=1,
                    help="segment-weight gather prefetch depth "
                         "(slices ahead, with --overlap)")
    ap.add_argument("--overlap-bucket-mib", type=float, default=4.0,
                    help="gradient all-reduce bucket size in MiB "
                         "(with --overlap)")
    ap.add_argument("--force-mode", default=None, choices=["DP", "ZDP"])
    ap.add_argument("--no-osdp", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--keep-last", type=int, default=0,
                    help="checkpoint retention: keep the newest N "
                         "completed steps (0 = keep everything)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest valid checkpoint under "
                         "--ckpt-dir and treat --steps as the TOTAL "
                         "step target (completed steps are skipped)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    model_cfg = get_arch(args.arch)
    if args.reduced:
        model_cfg = reduced(model_cfg)
    shape = get_shape(args.shape)
    if args.seq or args.batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq or shape.seq_len,
            global_batch=args.batch or shape.global_batch)

    n_dev = len(jax.devices())
    mesh_cfg = MeshConfig((n_dev, 1), ("data", "model"))
    osdp = OSDPConfig(enabled=not args.no_osdp,
                      memory_limit_bytes=args.memory_gib * 2**30,
                      force_mode=args.force_mode)
    run = RunConfig(model=model_cfg, shape=shape, mesh=mesh_cfg, osdp=osdp)
    overlap_cfg = None
    if args.overlap is not None:
        ov = args.overlap if args.overlap == "auto" else float(args.overlap)
        if args.device:
            device = DeviceInfo.preset(args.device, overlap=ov)
        elif ov == "auto":
            ap.error("--overlap auto needs a --device preset")
        else:
            device = dataclasses.replace(DeviceInfo(), overlap=ov)
        overlap_cfg = OverlapConfig(
            prefetch=args.overlap_prefetch,
            bucket_bytes=int(args.overlap_bucket_mib * 2**20))
    else:
        device = DeviceInfo.preset(args.device) if args.device else None
    plan = make_plan(run, device)
    print(plan.summary())
    mesh = jax.make_mesh(mesh_cfg.shape, mesh_cfg.axes) if n_dev > 1 else None
    built = build_model(run, plan, mesh, overlap=overlap_cfg)
    res = train(built, args.steps, seed=args.seed,
                opt_cfg=AdamWConfig(lr=args.lr), warmup=args.warmup,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                keep_last=args.keep_last, resume=args.resume)
    if not res.steps:
        print(f"nothing to train: checkpoint already at step "
              f"{res.start_step} >= target {args.steps}")
        return 0
    print(f"done: {res.steps} steps, loss {res.losses[0]:.4f} -> "
          f"{res.losses[-1]:.4f}, {res.tokens_per_s:.0f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
