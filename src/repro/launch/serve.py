"""Serving launcher: OSDP-planned continuous batching.

Default path: run the serving search (`repro.core.api.search_serve`)
for the target device / fleet, print the plan (sharding decisions +
KV-budget admission limit), build the model with the plan's decisions,
and serve a synthetic request stream through the continuous-batching
engine.  `--no-plan` restores the legacy path — a hardcoded (1,1)
mesh with OSDP disabled and the static batch engine.

    python -m repro.launch.serve --arch mamba2-2.7b --reduced \
        --prompt-len 64 --new-tokens 32 --requests 8
    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --no-plan --batch 4 --prompt-len 64 --new-tokens 32

`--fleet` switches to multi-replica planning (`search_fleet`): the
cluster is partitioned into replica groups for a request-class mix
(`--classes name:prompt:decode:rate[:ttft_slo[:tpot_slo]],...`), and
with `--reduced` the plan is exercised by the deterministic traffic
simulator — one reduced-model engine per group, seeded `--arrival`
poisson traffic (or a "tick,class" CSV trace), per-class latency
percentiles in ticks:

    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --fleet --n-devices 8 --memory-limit-gib 4 \
        --classes interactive:16:8:4:0.05:0.02,batch:64:32:0.5 \
        --arrival poisson --horizon 48
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import (DeviceInfo, MeshConfig, OSDPConfig, RunConfig,
                           get_arch, get_shape, reduced)
from repro.core.api import search_serve
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Engine, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size (legacy / --engine static)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # --- planning ----------------------------------------------------------
    ap.add_argument("--no-plan", action="store_true",
                    help="legacy path: (1,1) mesh, OSDP disabled, "
                         "static batching")
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="DeviceInfo preset to plan for "
                         "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
    ap.add_argument("--n-devices", type=int, default=1,
                    help="data extent the plan targets")
    ap.add_argument("--memory-limit-gib", type=float, default=16.0)
    ap.add_argument("--max-slots", type=int, default=0,
                    help="cap the admission limit (0 = searched)")
    # --- workload ----------------------------------------------------------
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=0,
                    help="synthetic requests to serve (0 = 2x batch)")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed decode lengths (every 4th request "
                         "decodes the full --new-tokens, the rest 1/4)")
    # --- fleet -------------------------------------------------------------
    ap.add_argument("--fleet", action="store_true",
                    help="multi-replica planning (search_fleet) + "
                         "traffic simulation instead of one engine")
    ap.add_argument("--classes", default=None, metavar="SPEC",
                    help="request-class mix, comma-separated "
                         "name:prompt:decode:rate[:ttft_slo[:tpot_slo]] "
                         "(rates in requests/s at plan scale)")
    ap.add_argument("--arrival", default="poisson", metavar="KIND",
                    help="'poisson' (seeded, default) or a CSV trace "
                         "file of 'tick,class' lines")
    ap.add_argument("--horizon", type=int, default=64,
                    help="simulated traffic horizon in ticks")
    # --- hardening ---------------------------------------------------------
    ap.add_argument("--max-queue", type=int, default=-1,
                    help="queue-depth backpressure: REJECT requests "
                         "beyond max_slots + this many waiting "
                         "(-1 = unbounded)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget for transiently-failed attempts")
    ap.add_argument("--backoff-steps", type=int, default=2,
                    help="base engine-step backoff between retries "
                         "(doubles per attempt)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request engine-step deadline "
                         "(0 = none); expired requests end TIMED_OUT")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if not cfg.is_decoder:
        print(f"{cfg.name} is encoder-only; nothing to decode")
        return 1

    if args.fleet:
        return _serve_fleet(cfg, args)

    rng = np.random.default_rng(args.seed)
    if args.no_plan:
        return _serve_static(cfg, args, rng, plan=None)

    device = DeviceInfo.preset(args.device) if args.device else None
    plan = search_serve(
        cfg, prompt_len=args.prompt_len, decode_len=args.new_tokens,
        n_devices=args.n_devices,
        memory_limit_gib=args.memory_limit_gib, device=device)
    print(plan.summary())
    if not plan.feasible:
        print("plan infeasible: no concurrency fits the memory limit "
              "(shrink the workload or add devices)")
        return 2
    if args.engine == "static":
        return _serve_static(cfg, args, rng, plan=plan)

    n_req = args.requests or 2 * args.batch
    slots = plan.max_slots_per_device
    if args.max_slots:
        slots = min(slots, args.max_slots)
    slots = max(1, min(slots, n_req))
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(
                        enabled=True, checkpointing=False,
                        memory_limit_bytes=args.memory_limit_gib * 2**30))
    built = build_model(run, plan)
    params = built.init(jax.random.PRNGKey(args.seed))
    eng = ContinuousEngine(built, params, max_slots=slots,
                           cache_len=args.prompt_len + args.new_tokens,
                           temperature=args.temperature,
                           max_queue=(None if args.max_queue < 0
                                      else args.max_queue),
                           max_retries=args.max_retries,
                           backoff_steps=args.backoff_steps)
    reqs = []
    for i in range(n_req):
        n_new = args.new_tokens
        if args.mixed and i % 4 != 0:
            n_new = max(1, args.new_tokens // 4)
        prompt = rng.integers(0, cfg.vocab_size,
                              args.prompt_len).astype(np.int32)
        reqs.append(Request(i, prompt, n_new,
                            deadline_steps=args.deadline_steps or None))
    results, stats = eng.run(reqs, seed=args.seed)
    print(f"served {stats.completed} requests "
          f"({stats.useful_tokens} tokens) in {stats.wall_s:.2f}s: "
          f"{stats.tokens_per_s:.1f} tok/s, {stats.prefill_steps} "
          f"prefills + {stats.decode_steps} decode steps on {slots} "
          f"slots (utilization {stats.slot_utilization:.0%})")
    if stats.terminal > stats.completed:
        print(f"  non-OK terminals: {stats.rejected} rejected, "
              f"{stats.invalid} invalid, {stats.timed_out} timed out, "
              f"{stats.failed} failed ({stats.retries} retries, "
              f"{stats.wasted_tokens} wasted tokens)")
    for r in results[:3]:
        print(f"  req {r.rid}: {r.n_generated} tokens, queue "
              f"{r.queue_wait_s * 1e3:.0f} ms, ttft "
              f"{r.ttft_s * 1e3:.0f} ms, latency "
              f"{r.latency_s * 1e3:.0f} ms")
    return 0


DEFAULT_CLASSES = "interactive:16:8:4:0.05:0.02,batch:64:32:0.5"


def _parse_classes(spec: str):
    from repro.core.cost_model import RequestClass, RequestClassMix
    classes = []
    for part in spec.split(","):
        f = part.split(":")
        if len(f) < 4:
            raise SystemExit(
                f"bad class spec {part!r} (want "
                f"name:prompt:decode:rate[:ttft_slo[:tpot_slo]])")
        kw = {}
        if len(f) > 4:
            kw["ttft_slo"] = float(f[4])
        if len(f) > 5:
            kw["tpot_slo"] = float(f[5])
        classes.append(RequestClass(f[0], int(f[1]), int(f[2]),
                                    float(f[3]), **kw))
    return RequestClassMix(tuple(classes))


def _serve_fleet(cfg, args) -> int:
    """Fleet path: search_fleet over the class mix, then (with
    --reduced) drive the plan with the deterministic traffic
    simulator — one reduced engine per replica group."""
    import math

    from repro.core.api import search_fleet

    mix = _parse_classes(args.classes or DEFAULT_CLASSES)
    device = DeviceInfo.preset(args.device) if args.device else None
    plan = search_fleet(cfg, mix=mix, n_devices=args.n_devices,
                        memory_limit_gib=args.memory_limit_gib,
                        device=device)
    print(plan.summary())
    if not plan.feasible:
        print("fleet plan infeasible: no replica split fits the "
              "memory limit (shrink the workload or add devices)")
        return 2
    if not args.reduced:
        print("(pass --reduced to exercise the plan with simulated "
              "traffic through real engines)")
        return 0

    from repro.serving.simulator import (TrafficSimulator,
                                         fleet_replicas,
                                         poisson_arrivals,
                                         trace_arrivals)
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(args.seed))
    slots = args.max_slots or 4
    cache_len = mix.max_cache_len

    def make(_group):
        return ContinuousEngine(built, params, max_slots=slots,
                                cache_len=cache_len, max_queue=64,
                                temperature=args.temperature)

    replicas = fleet_replicas(plan, make, max_replicas_per_group=1)
    # the planner's 2x-occupancy admission rule at sim scale
    admission: dict = {}
    for g in plan.groups:
        sub = mix.subset(g.classes)
        for name in g.classes:
            admission[name] = admission.get(name, 0.0) \
                + 2.0 * slots * sub.slot_share(name)
    admission = {k: max(1, math.ceil(v)) for k, v in admission.items()}

    if args.arrival == "poisson":
        # normalize the plan-scale rates to ~0.5 requests/tick offered
        scale = 0.5 / mix.total_rate
        arrivals = poisson_arrivals(
            mix, horizon=args.horizon, seed=args.seed,
            rate_scale=scale, cap_scale=max(16.0, scale))
    else:
        pairs = []
        with open(args.arrival) as fh:
            for line in fh:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                t, name = line.split(",")
                pairs.append((int(t), name.strip()))
        arrivals = trace_arrivals(pairs)

    sim = TrafficSimulator(replicas, mix, routing=plan.routing,
                           admission=admission, seed=args.seed)
    rep = sim.run(arrivals)
    print(f"simulated {len(arrivals)} arrivals over {rep.ticks} ticks "
          f"on {len(replicas)} replicas ({slots} slots each): "
          f"{rep.completed} completed, "
          f"{rep.goodput_tokens_per_tick:.2f} tok/tick")
    for name, cr in sorted(rep.per_class.items()):
        print(f"  {name}: {cr.completed}/{cr.arrived} ok "
              f"({cr.rejected} rejected), ttft p50/p99 "
              f"{cr.ttft_p50:.1f}/{cr.ttft_p99:.1f} ticks, tpot "
              f"p50/p99 {cr.tpot_p50:.2f}/{cr.tpot_p99:.2f}")
    print(f"  fingerprint {rep.fingerprint()}")
    return 0


def _serve_static(cfg, args, rng, plan=None) -> int:
    """The pre-plan engine: one batch, lockstep decode."""
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=(OSDPConfig(enabled=True, checkpointing=False)
                          if plan is not None
                          else OSDPConfig(enabled=False)))
    built = build_model(run, plan)
    params = built.init(jax.random.PRNGKey(args.seed))
    cache_len = (args.prompt_len + args.new_tokens
                 if plan is not None else None)
    eng = Engine(built, params, temperature=args.temperature,
                 cache_len=cache_len)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    res = eng.generate(prompts, args.new_tokens, seed=args.seed)
    print(f"prefill {args.batch}x{args.prompt_len} in {res.prefill_s:.2f}s; "
          f"decoded {args.new_tokens} tokens/seq in {res.decode_s:.2f}s "
          f"({res.tokens_per_s:.1f} tok/s)")
    print("first sequence:", res.tokens[0][:16], "...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
