"""Serving launcher: OSDP-planned continuous batching.

Default path: run the serving search (`repro.core.api.search_serve`)
for the target device / fleet, print the plan (sharding decisions +
KV-budget admission limit), build the model with the plan's decisions,
and serve a synthetic request stream through the continuous-batching
engine.  `--no-plan` restores the legacy path — a hardcoded (1,1)
mesh with OSDP disabled and the static batch engine.

    python -m repro.launch.serve --arch mamba2-2.7b --reduced \
        --prompt-len 64 --new-tokens 32 --requests 8
    python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --no-plan --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import (DeviceInfo, MeshConfig, OSDPConfig, RunConfig,
                           get_arch, get_shape, reduced)
from repro.core.api import search_serve
from repro.models.registry import build_model
from repro.serving.engine import ContinuousEngine, Engine, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size (legacy / --engine static)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # --- planning ----------------------------------------------------------
    ap.add_argument("--no-plan", action="store_true",
                    help="legacy path: (1,1) mesh, OSDP disabled, "
                         "static batching")
    ap.add_argument("--device", default=None, metavar="PRESET",
                    help="DeviceInfo preset to plan for "
                         "(tpu-v5e, tpu-v4, a100-80g, h100-sxm)")
    ap.add_argument("--n-devices", type=int, default=1,
                    help="data extent the plan targets")
    ap.add_argument("--memory-limit-gib", type=float, default=16.0)
    ap.add_argument("--max-slots", type=int, default=0,
                    help="cap the admission limit (0 = searched)")
    # --- workload ----------------------------------------------------------
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=0,
                    help="synthetic requests to serve (0 = 2x batch)")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed decode lengths (every 4th request "
                         "decodes the full --new-tokens, the rest 1/4)")
    # --- hardening ---------------------------------------------------------
    ap.add_argument("--max-queue", type=int, default=-1,
                    help="queue-depth backpressure: REJECT requests "
                         "beyond max_slots + this many waiting "
                         "(-1 = unbounded)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget for transiently-failed attempts")
    ap.add_argument("--backoff-steps", type=int, default=2,
                    help="base engine-step backoff between retries "
                         "(doubles per attempt)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request engine-step deadline "
                         "(0 = none); expired requests end TIMED_OUT")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if not cfg.is_decoder:
        print(f"{cfg.name} is encoder-only; nothing to decode")
        return 1

    rng = np.random.default_rng(args.seed)
    if args.no_plan:
        return _serve_static(cfg, args, rng, plan=None)

    device = DeviceInfo.preset(args.device) if args.device else None
    plan = search_serve(
        cfg, prompt_len=args.prompt_len, decode_len=args.new_tokens,
        n_devices=args.n_devices,
        memory_limit_gib=args.memory_limit_gib, device=device)
    print(plan.summary())
    if not plan.feasible:
        print("plan infeasible: no concurrency fits the memory limit "
              "(shrink the workload or add devices)")
        return 2
    if args.engine == "static":
        return _serve_static(cfg, args, rng, plan=plan)

    n_req = args.requests or 2 * args.batch
    slots = plan.max_slots_per_device
    if args.max_slots:
        slots = min(slots, args.max_slots)
    slots = max(1, min(slots, n_req))
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(
                        enabled=True, checkpointing=False,
                        memory_limit_bytes=args.memory_limit_gib * 2**30))
    built = build_model(run, plan)
    params = built.init(jax.random.PRNGKey(args.seed))
    eng = ContinuousEngine(built, params, max_slots=slots,
                           cache_len=args.prompt_len + args.new_tokens,
                           temperature=args.temperature,
                           max_queue=(None if args.max_queue < 0
                                      else args.max_queue),
                           max_retries=args.max_retries,
                           backoff_steps=args.backoff_steps)
    reqs = []
    for i in range(n_req):
        n_new = args.new_tokens
        if args.mixed and i % 4 != 0:
            n_new = max(1, args.new_tokens // 4)
        prompt = rng.integers(0, cfg.vocab_size,
                              args.prompt_len).astype(np.int32)
        reqs.append(Request(i, prompt, n_new,
                            deadline_steps=args.deadline_steps or None))
    results, stats = eng.run(reqs, seed=args.seed)
    print(f"served {stats.completed} requests "
          f"({stats.useful_tokens} tokens) in {stats.wall_s:.2f}s: "
          f"{stats.tokens_per_s:.1f} tok/s, {stats.prefill_steps} "
          f"prefills + {stats.decode_steps} decode steps on {slots} "
          f"slots (utilization {stats.slot_utilization:.0%})")
    if stats.terminal > stats.completed:
        print(f"  non-OK terminals: {stats.rejected} rejected, "
              f"{stats.invalid} invalid, {stats.timed_out} timed out, "
              f"{stats.failed} failed ({stats.retries} retries, "
              f"{stats.wasted_tokens} wasted tokens)")
    for r in results[:3]:
        print(f"  req {r.rid}: {r.n_generated} tokens, queue "
              f"{r.queue_wait_s * 1e3:.0f} ms, ttft "
              f"{r.ttft_s * 1e3:.0f} ms, latency "
              f"{r.latency_s * 1e3:.0f} ms")
    return 0


def _serve_static(cfg, args, rng, plan=None) -> int:
    """The pre-plan engine: one batch, lockstep decode."""
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=(OSDPConfig(enabled=True, checkpointing=False)
                          if plan is not None
                          else OSDPConfig(enabled=False)))
    built = build_model(run, plan)
    params = built.init(jax.random.PRNGKey(args.seed))
    cache_len = (args.prompt_len + args.new_tokens
                 if plan is not None else None)
    eng = Engine(built, params, temperature=args.temperature,
                 cache_len=cache_len)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    res = eng.generate(prompts, args.new_tokens, seed=args.seed)
    print(f"prefill {args.batch}x{args.prompt_len} in {res.prefill_s:.2f}s; "
          f"decoded {args.new_tokens} tokens/seq in {res.decode_s:.2f}s "
          f"({res.tokens_per_s:.1f} tok/s)")
    print("first sequence:", res.tokens[0][:16], "...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
