"""Serving launcher: prefill a batch of synthetic prompts, decode N
tokens with the KV/SSM cache engine.

    python -m repro.launch.serve --arch mamba2-2.7b --reduced \
        --prompt-len 64 --new-tokens 32 --batch 4
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import (MeshConfig, OSDPConfig, RunConfig, get_arch,
                           get_shape, reduced)
from repro.models.registry import build_model
from repro.serving.engine import Engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if not cfg.is_decoder:
        print(f"{cfg.name} is encoder-only; nothing to decode")
        return 1
    run = RunConfig(model=cfg, shape=get_shape("decode_32k"),
                    mesh=MeshConfig((1, 1), ("data", "model")),
                    osdp=OSDPConfig(enabled=False))
    built = build_model(run)
    params = built.init(jax.random.PRNGKey(args.seed))
    eng = Engine(built, params, temperature=args.temperature)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    res = eng.generate(prompts, args.new_tokens, seed=args.seed)
    print(f"prefill {args.batch}x{args.prompt_len} in {res.prefill_s:.2f}s; "
          f"decoded {args.new_tokens} tokens/seq in {res.decode_s:.2f}s "
          f"({res.tokens_per_s:.1f} tok/s)")
    print("first sequence:", res.tokens[0][:16], "...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
