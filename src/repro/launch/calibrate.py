"""`repro calibrate` — measure and fit this backend's cost-model
constants into a CalibrationProfile.

  python -m repro calibrate [--device tpu-v5e] [--out profile.json] \
      [--fake-devices 8] [--quick] [--matmul-sizes 64,128,...] \
      [--bw-mib 0.25,1,4] [--repeats 3]

Three timed sweeps (repro.calibrate.bench) feed three fits
(repro.calibrate.fit):

  1. square matmuls over a size ladder  -> EfficiencyCurve
     (achieved fraction of peak vs log-flops),
  2. all-gathers over a message-size ladder per mesh axis
     -> per-level LinkCalibration (alpha + bytes/bandwidth),
  3. grad of a matmul chain, plain vs jax.checkpoint -> remat factor.

The profile is normalized against `--peak-flops` when given (fractions
of a datasheet peak), else against the best achieved matmul rate.  On
CPU emulation the numbers calibrate the emulation backend — exactly
what `benchmarks/calibration.py` needs to make predicted-vs-measured
step times comparable; on real hardware the same sweeps calibrate the
chip.  The JSON written by `--out` round-trips through
`CalibrationProfile.load` and plugs into `CostEnv(..., profile=...)`
or `repro.calibrate.store.register`.

Like perf_probe, XLA_FLAGS is set inside main() before the first jax
import, so importing this module leaves the environment untouched.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _csv_ints(text: str):
    return tuple(int(x) for x in text.split(",") if x)


def _csv_floats(text: str):
    return tuple(float(x) for x in text.split(",") if x)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro calibrate")
    ap.add_argument("--device", default="host",
                    help="profile name: a DeviceInfo preset to "
                         "calibrate against, or a free name for this "
                         "backend (default: host)")
    ap.add_argument("--out", default=None, metavar="PROFILE_JSON",
                    help="write the fitted CalibrationProfile here")
    ap.add_argument("--fake-devices", type=int, default=8,
                    help="host devices to emulate for the collective "
                         "sweep (XLA_FLAGS, set before jax imports)")
    ap.add_argument("--matmul-sizes", type=_csv_ints,
                    default=(64, 128, 256, 512, 1024))
    ap.add_argument("--bw-mib", type=_csv_floats,
                    default=(0.25, 1.0, 4.0, 16.0))
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--peak-flops", type=float, default=0.0,
                    help="normalize the efficiency curve against this "
                         "peak instead of the best achieved rate")
    ap.add_argument("--remat-depth", type=int, default=8)
    ap.add_argument("--remat-width", type=int, default=256)
    ap.add_argument("--quick", action="store_true",
                    help="small sweeps (CI / smoke): 3 matmul sizes, "
                         "2 message sizes, 1 repeat")
    args = ap.parse_args(argv)

    if args.quick:
        args.matmul_sizes = args.matmul_sizes[:3]
        args.bw_mib = args.bw_mib[:2]
        args.repeats = 1

    # must land before the first jax import (same contract as
    # perf_probe); setdefault lets callers force their own count
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.fake_devices}")

    import jax
    from repro.calibrate import bench, fit
    from repro.calibrate.profile import CalibrationProfile

    t0 = time.perf_counter()

    # 1. compute: matmul ladder -> efficiency curve
    mm = bench.matmul_sweep(args.matmul_sizes, repeats=args.repeats)
    peak = args.peak_flops or bench.measured_peak_flops(mm)
    curve = fit.fit_efficiency_curve(mm, peak_flops=peak)

    # 2. collectives: all-gather ladder per mesh axis -> link fits.
    # Axis names match ClusterSpec.from_flat's level names so the
    # fitted links bind by name on flat specs (and positionally,
    # innermost-first, elsewhere).
    n_dev = len(jax.devices())
    links = ()
    if n_dev >= 2:
        mesh = jax.make_mesh((n_dev,), ("data",))
        sweeps = bench.collective_sweep(mesh, args.bw_mib,
                                        repeats=args.repeats)
        links = fit.fit_link_calibrations(sweeps)

    # 3. remat: plain vs checkpointed grad step -> recompute factor
    t_plain, t_remat = bench.remat_sweep(
        depth=args.remat_depth, width=args.remat_width,
        repeats=args.repeats)
    remat = fit.fit_remat_factor(t_plain, t_remat)

    profile = CalibrationProfile(
        device=args.device, efficiency=curve, links=links,
        remat_factor=remat, peak_flops=peak,
        source=f"repro calibrate ({jax.default_backend()}, "
               f"{n_dev} devices, repeats={args.repeats})")

    # the round-trip guarantee the planner relies on
    assert CalibrationProfile.from_json(profile.to_json()) == profile

    rec = {
        "profile": profile.to_dict(),
        "measured": {
            "matmul": [{"flops": f, "seconds": s} for f, s in mm],
            "peak_flops": peak,
            "remat_plain_s": t_plain,
            "remat_remat_s": t_remat,
        },
        "elapsed_s": time.perf_counter() - t0,
    }
    if args.out:
        profile.save(args.out)
        rec["out"] = args.out
    print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
