"""Degraded-cluster replanning supervisors.

When a `DeviceGroupLoss` fires, the runtime raises
`faults.DeviceLost`; the supervisors here catch it and drive the
paper's planner through the recovery loop:

    degrade the ClusterSpec  ->  re-score the stale plan (is the old
    sharding even feasible on the survivors?)  ->  re-run the OSDP
    search on the degraded spec  ->  verify feasibility  ->  resume.

* `ServeSupervisor` wraps `ContinuousEngine.run`: on a loss it keeps
  every acknowledged `RequestResult` (completed work is never lost or
  re-run), rebuilds the engine from the re-searched `ServePlan` —
  whose `max_slots_per_device` admission limit may have shrunk — and
  re-admits the pending requests (queued + in-flight whose KV state
  died with the devices).
* `TrainSupervisor` wraps `train.loop.train`: on a loss it replans,
  then resumes from the latest *valid* checkpoint
  (`restore_or_init` inside `train`), so progress since the last save
  is lost — exactly like the real failure — but nothing else.  An
  injected `CheckpointCrashError` is survived the same way: the
  atomic-save protocol guarantees the previous checkpoint is intact.

Every recovery is recorded as a `RecoveryEvent` (what died, whether
the stale plan still fit, what the replan decided, and how long
recovery took) — the benchmark rows in `benchmarks/resilience.py` are
built from these.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from repro.checkpoint.io import CheckpointCrashError
from repro.cluster.topology import ClusterSpec
from repro.resilience.faults import DeviceLost, FaultSchedule


@dataclass
class RecoveryEvent:
    """One handled failure: what fired, what the planner decided,
    and what recovery cost."""

    kind: str                     # "device_loss" | "checkpoint_crash"
    step: int                     # engine / train step when it fired
    description: str
    n_devices_before: int = 0
    n_devices_after: int = 0
    stale_feasible: Optional[bool] = None   # old plan on new cluster
    replan_feasible: Optional[bool] = None
    replanned: bool = False
    requeued: int = 0             # serving: in-flight + queued re-admitted
    resumed_from_step: Optional[int] = None  # training: checkpoint used
    recovery_s: float = 0.0       # catch -> new plan + engine/loop ready


@dataclass
class SupervisedServeRun:
    """Outcome of `ServeSupervisor.run`: the union of every engine
    segment's results (acknowledged results from before each loss are
    kept verbatim), merged stats, and the recovery log."""

    results: list
    stats: object
    recoveries: List[RecoveryEvent] = field(default_factory=list)
    plans: list = field(default_factory=list)

    @property
    def cluster_losses(self) -> int:
        return sum(1 for r in self.recoveries if r.kind == "device_loss")


def merge_stats(parts: Sequence) -> object:
    """Sum `ServeStats` across engine segments (counters add; the
    derived properties recompute from the sums)."""
    from repro.serving.engine import ServeStats
    out = ServeStats(wall_s=0.0, prefill_steps=0, decode_steps=0,
                     slots=0, useful_tokens=0, completed=0)
    for s in parts:
        if s is None:
            continue
        out.wall_s += s.wall_s
        out.prefill_steps += s.prefill_steps
        out.decode_steps += s.decode_steps
        out.slots = max(out.slots, s.slots)
        out.useful_tokens += s.useful_tokens
        out.completed += s.completed
        out.wasted_tokens += s.wasted_tokens
        out.retries += s.retries
        out.rejected += s.rejected
        out.invalid += s.invalid
        out.timed_out += s.timed_out
        out.failed += s.failed
    return out


class ServeSupervisor:
    """Crash-safe serving: plan -> run -> (on loss: degrade, replan,
    drain, re-admit) -> merged results.

    `plan_fn(cluster)` searches a `ServePlan` for a cluster state
    (typically a closure over `repro.core.api.search_serve`);
    `engine_factory(plan, cluster)` builds the `ContinuousEngine` that
    executes it (slots from `plan.max_slots_per_device`).
    `rescore_fn(plan, cluster)`, when given, answers whether the STALE
    plan still fits the degraded cluster (see
    `repro.core.api.rescore_serve`) — recorded per recovery, and when
    it says "still feasible" the supervisor skips the re-search and
    keeps the old plan (drain + re-admit only).
    """

    def __init__(self, plan_fn: Callable[[ClusterSpec], object],
                 engine_factory: Callable[[object, ClusterSpec], object],
                 cluster: ClusterSpec,
                 rescore_fn: Optional[Callable[[object, ClusterSpec],
                                               Tuple[object, bool]]] = None,
                 print_fn: Callable[[str], None] = print):
        self.plan_fn = plan_fn
        self.engine_factory = engine_factory
        self.cluster = cluster
        self.rescore_fn = rescore_fn
        self.print_fn = print_fn

    def run(self, requests: Sequence, seed: int = 0,
            faults: Optional[FaultSchedule] = None,
            max_losses: int = 8) -> SupervisedServeRun:
        cluster = self.cluster
        plan = self.plan_fn(cluster)
        if not getattr(plan, "feasible", True):
            raise RuntimeError("initial serving plan infeasible on the "
                               "healthy cluster")
        engine = self.engine_factory(plan, cluster)
        pending = list(requests)
        acked: list = []
        stats_parts: list = []
        recoveries: List[RecoveryEvent] = []
        plans = [plan]
        faults = FaultSchedule() if faults is None else faults
        for _ in range(max_losses + 1):
            try:
                results, stats = engine.run(pending, seed=seed,
                                            faults=faults)
                acked.extend(results)
                stats_parts.append(stats)
                return SupervisedServeRun(acked, merge_stats(stats_parts),
                                          recoveries, plans)
            except DeviceLost as e:
                t0 = time.perf_counter()
                # acknowledged work survives the loss verbatim
                acked.extend(e.results)
                stats_parts.append(e.stats)
                ev = e.event
                degraded = cluster.degrade(group=ev.group, level=ev.level,
                                           ways=ev.ways)
                rec = RecoveryEvent(
                    kind="device_loss", step=e.step,
                    description=ev.describe(),
                    n_devices_before=cluster.n_devices,
                    n_devices_after=degraded.n_devices,
                    requeued=len(e.pending))
                if self.rescore_fn is not None:
                    _, rec.stale_feasible = self.rescore_fn(plan, degraded)
                if rec.stale_feasible:
                    # survivors can keep running the old sharding —
                    # drain + re-admit without paying a re-search
                    rec.replan_feasible = True
                else:
                    plan = self.plan_fn(degraded)
                    rec.replanned = True
                    rec.replan_feasible = bool(
                        getattr(plan, "feasible", True))
                    if not rec.replan_feasible:
                        rec.recovery_s = time.perf_counter() - t0
                        recoveries.append(rec)
                        raise RuntimeError(
                            f"no feasible serving plan on the degraded "
                            f"cluster ({degraded.n_devices} devices "
                            f"after losing {ev.describe()})") from e
                    plans.append(plan)
                cluster = degraded
                engine = self.engine_factory(plan, cluster)
                # re-admit in-flight + queued work on the new engine
                # (attempt counters reset: a loss is not the request's
                # fault — retries within a run stay bounded regardless)
                pending = list(e.pending)
                faults = faults.without(ev)
                rec.recovery_s = time.perf_counter() - t0
                recoveries.append(rec)
                action = ("replanned" if rec.replanned
                          else "stale plan kept")
                self.print_fn(
                    f"[supervisor] device loss at step {e.step} "
                    f"({ev.describe()}): {cluster.n_devices} devices "
                    f"remain, {action}, {rec.requeued} requests "
                    f"re-admitted in {rec.recovery_s * 1e3:.0f} ms")
        raise RuntimeError(f"gave up after {max_losses} device losses")


@dataclass
class SupervisedTrainRun:
    result: object                # the final TrainResult
    recoveries: List[RecoveryEvent] = field(default_factory=list)
    plans: list = field(default_factory=list)


class TrainSupervisor:
    """Crash-safe training: on a device loss, degrade the spec,
    re-score the stale plan, re-search, and resume the loop from the
    latest valid checkpoint; on an (injected) checkpoint crash,
    restart — the atomic save left the previous checkpoint intact.

    `train_fn(faults)` runs the training loop to the TOTAL step target
    and must restore from `ckpt_dir` itself (a closure over
    `train.loop.train(..., resume=True)`); `plan_fn(cluster)` re-runs
    the OSDP search and returns a plan whose `.search.feasible` (or
    `.feasible`) gates the resume; `stale_fit_fn(cluster)`, when
    given, reports whether the ORIGINAL plan fits the degraded
    cluster (recorded per recovery — the benchmark's "stale plan
    infeasible, replanned plan feasible" assertion reads it)."""

    def __init__(self, train_fn: Callable[[Optional[FaultSchedule]], object],
                 plan_fn: Callable[[ClusterSpec], object],
                 cluster: ClusterSpec,
                 ckpt_dir: Optional[str] = None,
                 stale_fit_fn: Optional[Callable[[ClusterSpec],
                                                 bool]] = None,
                 print_fn: Callable[[str], None] = print):
        self.train_fn = train_fn
        self.plan_fn = plan_fn
        self.cluster = cluster
        self.ckpt_dir = ckpt_dir
        self.stale_fit_fn = stale_fit_fn
        self.print_fn = print_fn

    def run(self, faults: Optional[FaultSchedule] = None,
            max_failures: int = 8) -> SupervisedTrainRun:
        from repro.checkpoint import io as ckpt_io
        cluster = self.cluster
        recoveries: List[RecoveryEvent] = []
        plans: list = []
        for _ in range(max_failures + 1):
            try:
                res = self.train_fn(faults)
                return SupervisedTrainRun(res, recoveries, plans)
            except DeviceLost as e:
                t0 = time.perf_counter()
                ev = e.event
                degraded = cluster.degrade(group=ev.group, level=ev.level,
                                           ways=ev.ways)
                rec = RecoveryEvent(
                    kind="device_loss", step=e.step,
                    description=ev.describe(),
                    n_devices_before=cluster.n_devices,
                    n_devices_after=degraded.n_devices)
                if self.stale_fit_fn is not None:
                    rec.stale_feasible = bool(self.stale_fit_fn(degraded))
                plan = self.plan_fn(degraded)
                feas = getattr(plan, "feasible", None)
                if feas is None:
                    feas = getattr(getattr(plan, "search", None),
                                   "feasible", True)
                rec.replanned = True
                rec.replan_feasible = bool(feas)
                if not rec.replan_feasible:
                    rec.recovery_s = time.perf_counter() - t0
                    recoveries.append(rec)
                    raise RuntimeError(
                        f"no feasible training plan on the degraded "
                        f"cluster ({degraded.n_devices} devices after "
                        f"losing {ev.describe()})") from e
                plans.append(plan)
                cluster = degraded
                if self.ckpt_dir:
                    rec.resumed_from_step = ckpt_io.latest_step(
                        self.ckpt_dir)
                faults = faults.without(ev) if faults is not None else None
                rec.recovery_s = time.perf_counter() - t0
                recoveries.append(rec)
                self.print_fn(
                    f"[supervisor] device loss at train step {e.step} "
                    f"({ev.describe()}): replanned for "
                    f"{cluster.n_devices} devices, resuming from "
                    f"checkpoint step {rec.resumed_from_step}")
            except CheckpointCrashError as e:
                # the injected mid-save kill: consume the event so the
                # restart's save succeeds, then simply run again — the
                # atomic protocol guarantees the newest visible
                # checkpoint is complete
                step = getattr(e, "step", None)
                ev = (faults.checkpoint_crash_at(step)
                      if faults is not None and step is not None else None)
                if ev is None:
                    raise
                faults = faults.without(ev)
                rec = RecoveryEvent(
                    kind="checkpoint_crash", step=ev.at_step,
                    description=f"save crashed after "
                                f"{ev.after_leaves} leaves",
                    n_devices_before=cluster.n_devices,
                    n_devices_after=cluster.n_devices)
                if self.ckpt_dir:
                    rec.resumed_from_step = ckpt_io.latest_step(
                        self.ckpt_dir)
                recoveries.append(rec)
                self.print_fn(
                    f"[supervisor] checkpoint crash at step "
                    f"{ev.at_step}: previous checkpoint "
                    f"{rec.resumed_from_step} intact, restarting")
        raise RuntimeError(f"gave up after {max_failures} failures")
