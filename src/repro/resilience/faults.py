"""Deterministic fault injection — every failure mode in the
resilience layer is driven from one seeded `FaultSchedule`, so tests
and benchmarks replay failures exactly (same seed -> same terminal
states, same recovery path).

The schedule is pure data + stateless pure functions of
(seed, identifiers): the runtime hooks (`ContinuousEngine.run`,
`train.loop.train`, `checkpoint.io.save`) *query* it and never mutate
it, which is what makes replay trivial.  `FaultSchedule()` (the empty
schedule) answers "no fault" to every query, and the hooks are written
so the empty schedule leaves the no-fault paths byte-identical.

Failure modes:

  * `DeviceGroupLoss` — a `ClusterSpec` group (or `ways` spans of a
    level) dies at step T.  The engine / train loop raises
    `DeviceLost`; a supervisor (`resilience.supervisor`) catches it,
    degrades the spec (`ClusterSpec.degrade`), re-plans, and resumes.
  * `TransientFailures` — each admission attempt of a request fails
    with probability p, deterministically per (seed, rid, attempt).
    The engine retries with exponential backoff up to its retry
    budget, then marks the request FAILED.
  * `CheckpointCrash` — the checkpoint write at step T crashes after
    k leaf files (simulating a mid-write process kill): the atomic
    tmp-dir protocol must leave the previous checkpoint intact.
  * `SlowRequest` — a request stalls for `stall_steps` decode steps
    after admission (a stuck client / straggler shard); per-request
    deadlines turn unbounded stalls into TIMED_OUT.
  * `MemoryPressure` — between two engine steps the admission limit
    shrinks by `factor` (graceful degradation: shed load before the
    engine OOMs).
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Optional, Tuple


def _unit_hash(*parts) -> float:
    """Deterministic uniform [0, 1) from arbitrary identifiers."""
    key = ":".join(str(p) for p in parts).encode()
    h = hashlib.blake2b(key, digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


@dataclass(frozen=True)
class DeviceGroupLoss:
    """Lose part of the fleet at (engine or train) step `at_step`:
    either a named heterogeneous `group`, or `ways` spans of the
    cluster `level` with that name (outermost level by default)."""

    at_step: int
    group: Optional[str] = None
    level: Optional[str] = None
    ways: int = 1

    def describe(self) -> str:
        if self.group is not None:
            return f"group={self.group}"
        return f"level={self.level or '<outermost>'} ways={self.ways}"


@dataclass(frozen=True)
class TransientFailures:
    """Each admission attempt of a request fails with probability `p`
    (deterministic per (schedule.seed, rid, attempt)); the failing
    attempt aborts after a hash-picked number of decoded tokens."""

    p: float

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError("p must be in [0, 1]")


@dataclass(frozen=True)
class CheckpointCrash:
    """The checkpoint save at training step `at_step` crashes after
    writing `after_leaves` leaf files (before the atomic rename)."""

    at_step: int
    after_leaves: int = 0


@dataclass(frozen=True)
class SlowRequest:
    """Request `rid` stalls for `stall_steps` decode steps after every
    admission (its slot burns steps without producing tokens)."""

    rid: int
    stall_steps: int


@dataclass(frozen=True)
class MemoryPressure:
    """Between engine steps [at_step, until_step) the effective
    admission limit is `ceil(max_slots * factor)` — the engine sheds
    load instead of OOMing."""

    at_step: int
    until_step: int
    factor: float

    def __post_init__(self):
        if not 0.0 < self.factor <= 1.0:
            raise ValueError("factor must be in (0, 1]")


@dataclass(frozen=True)
class FaultSchedule:
    """Seeded, immutable fault plan.  All queries are pure functions of
    the schedule, so a run is replayable from (schedule, request set,
    engine seed) alone."""

    seed: int = 0
    device_losses: Tuple[DeviceGroupLoss, ...] = ()
    transient: Optional[TransientFailures] = None
    ckpt_crashes: Tuple[CheckpointCrash, ...] = ()
    slow: Tuple[SlowRequest, ...] = ()
    pressure: Tuple[MemoryPressure, ...] = ()

    @property
    def empty(self) -> bool:
        return (not self.device_losses and self.transient is None
                and not self.ckpt_crashes and not self.slow
                and not self.pressure)

    # -- device loss ---------------------------------------------------------

    def device_loss_at(self, step: int) -> Optional[DeviceGroupLoss]:
        """The earliest not-yet-consumed loss due at or before `step`
        (supervisors consume events with `without`)."""
        due = [e for e in self.device_losses if e.at_step <= step]
        return min(due, key=lambda e: e.at_step) if due else None

    def without(self, event) -> "FaultSchedule":
        """The schedule minus one consumed event (a supervisor resumes
        the run with this, so a handled fault does not re-fire)."""
        if isinstance(event, DeviceGroupLoss):
            return dataclasses.replace(self, device_losses=tuple(
                e for e in self.device_losses if e != event))
        if isinstance(event, CheckpointCrash):
            return dataclasses.replace(self, ckpt_crashes=tuple(
                e for e in self.ckpt_crashes if e != event))
        raise TypeError(f"cannot consume {type(event).__name__}")

    # -- transient request failures ------------------------------------------

    def attempt_fails(self, rid: int, attempt: int) -> bool:
        if self.transient is None or self.transient.p <= 0.0:
            return False
        return _unit_hash(self.seed, "transient", rid,
                          attempt) < self.transient.p

    def fail_after_tokens(self, rid: int, attempt: int,
                          max_new_tokens: int) -> Optional[int]:
        """Token count after which this attempt aborts (None = the
        attempt succeeds).  Uniform over [1, max_new_tokens]."""
        if not self.attempt_fails(rid, attempt):
            return None
        u = _unit_hash(self.seed, "fail-at", rid, attempt)
        return 1 + int(u * max_new_tokens)

    # -- checkpoint crashes --------------------------------------------------

    def checkpoint_crash_at(self, step: int) -> Optional[CheckpointCrash]:
        for e in self.ckpt_crashes:
            if e.at_step == step:
                return e
        return None

    # -- stalls / pressure ---------------------------------------------------

    def stall_steps(self, rid: int) -> int:
        return sum(s.stall_steps for s in self.slow if s.rid == rid)

    def slot_factor(self, step: int) -> float:
        """Effective admission-limit multiplier at an engine step."""
        f = 1.0
        for p in self.pressure:
            if p.at_step <= step < p.until_step:
                f = min(f, p.factor)
        return f


EMPTY_SCHEDULE = FaultSchedule()


class DeviceLost(RuntimeError):
    """Raised by a runtime hook when a `DeviceGroupLoss` fires.

    Carries everything a supervisor needs to recover:
      * `event` — the schedule entry that fired (names what died);
      * `step` — the engine / train step at which it fired;
      * `results` / `stats` — work acknowledged before the loss
        (serving: completed `RequestResult`s — these must never be
        re-run or lost);
      * `pending` — serving requests that must be re-admitted on the
        replanned engine (queued + requeued in-flight work whose KV
        state died with the devices).
    """

    def __init__(self, event: DeviceGroupLoss, step: int,
                 results=(), stats=None, pending=()):
        self.event = event
        self.step = step
        self.results = list(results)
        self.stats = stats
        self.pending = list(pending)
        super().__init__(
            f"device loss at step {step}: {event.describe()}")
