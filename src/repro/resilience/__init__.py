"""Elastic resilience layer: deterministic fault injection,
degraded-cluster replanning, and crash-safe serving/training.

`faults` is stdlib-only and imported by the runtime hooks
(`serving.engine`, `train.loop`, `checkpoint.io`); the supervisors
import those hooks back, so they load lazily here to keep the package
cycle-free.
"""
from repro.resilience.faults import (CheckpointCrash, DeviceGroupLoss,
                                     DeviceLost, EMPTY_SCHEDULE,
                                     FaultSchedule, MemoryPressure,
                                     SlowRequest, TransientFailures)

__all__ = [
    "CheckpointCrash", "DeviceGroupLoss", "DeviceLost", "EMPTY_SCHEDULE",
    "FaultSchedule", "MemoryPressure", "SlowRequest", "TransientFailures",
    "RecoveryEvent", "ServeSupervisor", "SupervisedServeRun",
    "SupervisedTrainRun", "TrainSupervisor", "merge_stats",
]

_LAZY = {"RecoveryEvent", "ServeSupervisor", "SupervisedServeRun",
         "SupervisedTrainRun", "TrainSupervisor", "merge_stats"}


def __getattr__(name):
    if name in _LAZY:
        from repro.resilience import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
