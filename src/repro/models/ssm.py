"""Mamba2 / SSD (state-space duality) block. [arXiv:2405.21060]

Block: in_proj -> [z | x | B | C | dt] -> causal depthwise conv on
(x,B,C) -> SSD chunk scan -> gated RMSNorm(z) -> out_proj.

SSD chunk scan (the paper's "quadratic-linear duality"): the sequence
is processed in chunks of Q steps; within a chunk the recurrence is
the quadratic attention-like form, across chunks a linear state
recurrence carries (nh, hd, ns) states. This is O(S·Q) compute and
O(S) memory, and is the algorithm the Pallas `ssd_scan` kernel tiles
for VMEM (kernels/ssd_scan.py), both validated against the naive
sequential oracle `ssd_ref`.

Sharding: d_inner (and therefore the SSD heads) is TP-sharded over
`model`; B/C/dt are small and replicated; the state is head-sharded.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rmsnorm
from repro.sharding.specs import ParamSet, seg_matmul

CONV_K = 4  # depthwise conv kernel width (Mamba2 default)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunk_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                   b: jax.Array, c: jax.Array, chunk: int,
                   init_state: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """SSD over a sequence.

    x: (B,S,nh,hd)  dt: (B,S,nh)  a_log: (nh,) [stores log(-A) > 0]
    b, c: (B,S,ns)  (single group, shared across heads)
    returns (y: (B,S,nh,hd), final_state: (B,nh,hd,ns))
    """
    B, S, nh, hd = x.shape
    ns = b.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    a = -jnp.exp(a_log.astype(jnp.float32))              # (nh,) A < 0
    dt = dt.astype(jnp.float32)
    dA = dt * a                                          # (B,Sp,nh) log-decay
    xd = x.astype(jnp.float32) * dt[..., None]           # dt-weighted input

    # chunked views
    dAc = dA.reshape(B, nc, Q, nh)
    xc = xd.reshape(B, nc, Q, nh, hd)
    bc = b.reshape(B, nc, Q, ns).astype(jnp.float32)
    cc = c.reshape(B, nc, Q, ns).astype(jnp.float32)

    csum = jnp.cumsum(dAc, axis=2)                       # (B,nc,Q,nh)
    # intra-chunk (quadratic within chunk):
    #   att[i,j] = exp(csum_i - csum_j) * (c_i . b_j)  for i >= j
    diff = csum[:, :, :, None, :] - csum[:, :, None, :, :]   # (B,nc,Q,Q,nh)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: masked (i<j) entries have diff>0 and would inf/NaN
    # the backward pass through where(mask, exp(diff), 0)
    att = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    cb = jnp.einsum("bnis,bnjs->bnij", cc, bc)           # (B,nc,Q,Q)
    y_intra = jnp.einsum("bnij,bnijh,bnjhd->bnihd", cb, att, xc)

    # end-of-chunk states: S_n = sum_j exp(csum_last - csum_j) b_j x_j^T
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)    # (B,nc,Q,nh)
    states = jnp.einsum("bnjs,bnjh,bnjhd->bnhds",
                        bc, decay_to_end, xc)            # (B,nc,nh,hd,ns)

    # inter-chunk recurrence over chunks
    chunk_decay = jnp.exp(csum[:, :, -1, :])             # (B,nc,nh)
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, nh, hd, ns), jnp.float32))

    def step(s_prev, inp):
        dec, s_new = inp                                 # (B,nh), (B,nh,hd,ns)
        s = s_prev * dec[:, :, None, None] + s_new
        return s, s_prev

    chunk_decay_t = jnp.moveaxis(chunk_decay, 1, 0)      # (nc,B,nh)
    states_t = jnp.moveaxis(states, 1, 0)                # (nc,B,nh,hd,ns)
    final_state, prev_states = jax.lax.scan(
        step, s0, (chunk_decay_t, states_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,nc,nh,hd,ns)

    # inter-chunk contribution: y_i += (c_i . S_prev) * exp(csum_i)
    y_inter = jnp.einsum("bnis,bnih,bnhds->bnihd",
                         cc, jnp.exp(csum), prev_states)
    y = (y_intra + y_inter).reshape(B, Sp, nh, hd)[:, :S]
    return y, final_state


def ssd_ref(x, dt, a_log, b, c,
            init_state: Optional[jax.Array] = None):
    """Naive O(S) sequential oracle (per-step recurrence)."""
    B, S, nh, hd = x.shape
    ns = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    s = (init_state.astype(jnp.float32) if init_state is not None
         else jnp.zeros((B, nh, hd, ns), jnp.float32))
    dt = dt.astype(jnp.float32)
    ys = []
    for t in range(S):
        dec = jnp.exp(dt[:, t] * a)                      # (B,nh)
        upd = jnp.einsum("bs,bnh->bnhs", b[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32) * dt[:, t][..., None])
        s = s * dec[:, :, None, None] + upd
        ys.append(jnp.einsum("bs,bnhs->bnh", c[:, t].astype(jnp.float32), s))
    return jnp.stack(ys, axis=1), s


def ssd_decode_step(x, dt, a_log, b, c, state):
    """One token: x:(B,nh,hd) dt:(B,nh) b,c:(B,ns) state:(B,nh,hd,ns)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dt.astype(jnp.float32) * a)            # (B,nh)
    upd = jnp.einsum("bs,bnh->bnhs", b.astype(jnp.float32),
                     x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    s = state.astype(jnp.float32) * dec[:, :, None, None] + upd
    y = jnp.einsum("bs,bnhs->bnh", c.astype(jnp.float32), s)
    return y, s


# ---------------------------------------------------------------------------
# conv + block assembly
# ---------------------------------------------------------------------------

def causal_conv(u: jax.Array, w: jax.Array,
                state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u:(B,S,C) w:(K,C). Returns (out, new_state)
    where state is the trailing K-1 inputs (for decode)."""
    K = w.shape[0]
    if state is None:
        ctx = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(ctx[:, i:i + u.shape[1]] * w[i] for i in range(K))
    new_state = ctx[:, -(K - 1):] if K > 1 else ctx[:, :0]
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype), new_state


def _split_proj(cfg: ModelConfig, pset: ParamSet, lp: Dict[str, jax.Array],
                x: jax.Array):
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    zx = seg_matmul(x, lp, pset, "layers/ssm/w_zx", 0)     # (B,S,2di)
    bcdt = seg_matmul(x, lp, pset, "layers/ssm/w_bcdt", 0)  # (B,S,2ns+nh)
    z, xin = zx[..., :di], zx[..., di:]
    b, c, dt_raw = (bcdt[..., :ns], bcdt[..., ns:2 * ns], bcdt[..., 2 * ns:])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["layers/ssm/dt_bias"].astype(jnp.float32))
    return z, xin, b, c, dt


def ssm_forward(cfg: ModelConfig, pset: ParamSet, lp: Dict[str, jax.Array],
                x: jax.Array) -> jax.Array:
    """Training / prefill SSD block. x: (B,S,d) -> (B,S,d)."""
    B, S, _ = x.shape
    di, ns, nh, hd = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
                      cfg.ssm_head_dim)
    z, xin, b, c, dt = _split_proj(cfg, pset, lp, x)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_out, _ = causal_conv(conv_in, lp["layers/ssm/conv_w"])
    xin, b, c = (conv_out[..., :di], conv_out[..., di:di + ns],
                 conv_out[..., di + ns:])
    xh = xin.reshape(B, S, nh, hd)
    y, _ = ssd_chunk_scan(xh, dt, lp["layers/ssm/A_log"], b, c,
                          cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * lp["layers/ssm/D"].astype(
        jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                lp["layers/ssm/gate_norm"])
    return seg_matmul(y, lp, pset, "layers/ssm/wo", 0)


def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    L, di, ns, nh, hd = (cfg.n_layers, cfg.ssm_d_inner, cfg.ssm_state,
                         cfg.ssm_n_heads, cfg.ssm_head_dim)
    return {
        "state": jnp.zeros((L, batch, nh, hd, ns), jnp.float32),
        "conv": jnp.zeros((L, batch, CONV_K - 1, di + 2 * ns), jnp.float32),
    }


def ssm_decode(cfg: ModelConfig, pset: ParamSet, lp: Dict[str, jax.Array],
               x: jax.Array, cache: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x:(B,1,d); cache: this layer's {state, conv}."""
    B = x.shape[0]
    di, ns, nh, hd = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
                      cfg.ssm_head_dim)
    z, xin, b, c, dt = _split_proj(cfg, pset, lp, x)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)        # (B,1,di+2ns)
    conv_out, conv_state = causal_conv(conv_in, lp["layers/ssm/conv_w"],
                                       state=cache["conv"])
    xin, b, c = (conv_out[..., :di], conv_out[..., di:di + ns],
                 conv_out[..., di + ns:])
    y, state = ssd_decode_step(
        xin[:, 0].reshape(B, nh, hd), dt[:, 0], lp["layers/ssm/A_log"],
        b[:, 0], c[:, 0], cache["state"])
    y = y + (xin[:, 0].reshape(B, nh, hd).astype(jnp.float32)
             * lp["layers/ssm/D"].astype(jnp.float32)[None, :, None])
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                lp["layers/ssm/gate_norm"])
    out = seg_matmul(y, lp, pset, "layers/ssm/wo", 0)
    return out, {"state": state, "conv": conv_state.astype(jnp.float32)}
