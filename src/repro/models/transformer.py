"""Unified transformer assembly for all six assigned families.

One `Model` covers dense / MoE / SSM / hybrid / VLM / audio by
composing the block modules according to `ModelConfig`:

    dense/vlm : x += attn(norm(x));            x += ffn(norm(x))
    moe       : x += attn(norm(x));            x += moe(norm(x)) [+dense]
    ssm       : x += ssd(norm(x))
    hybrid    : x += mean(attn(norm_a(x)), ssd(norm_s(x))); x += ffn(...)
    audio     : encoder-only dense (bidirectional, masked-prediction)

Parameters are stacked over layers and iterated with `lax.scan`
(HLO size independent of depth), with `jax.checkpoint` on the body
when remat is enabled. The OSDP plan decides per-operator shardings
through `sharding.specs` and per-operator splitting through
`Decision.split`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core.cost_model import DP, Decision
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import AttnGeom, attn_geometry, norm, positions_for
from repro.sharding.specs import (ParamSet, WeightSpec, build_param_set,
                                  seg_matmul)

LayerParams = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def build_specs(cfg: ModelConfig, tp_size: int) -> List[WeightSpec]:
    d, L, Vp = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    ln = cfg.norm == "layernorm"
    specs: List[WeightSpec] = []

    def w(path, shape, op, tp=None, zdp=None, init="normal", stacked=False,
          scale=0.02):
        specs.append(WeightSpec(path, shape, op, tp_axis=tp, zdp_axis=zdp,
                                init=init, stacked=stacked, init_scale=scale))

    # embeddings / head
    if cfg.family == "audio":
        w("embed/mask", (d,), "embed.tok")
    else:
        w("embed/tok", (Vp, d), "embed.tok", tp=0, zdp=1)
    if (not cfg.tie_embeddings and cfg.is_decoder) or cfg.encoder_only:
        w("head/out", (d, Vp), "head.out", tp=1, zdp=0)
    w("final_norm/scale", (d,), "final_norm", init="ones")
    if ln:
        w("final_norm/bias", (d,), "final_norm", init="zeros")

    geom = attn_geometry(cfg, tp_size) if cfg.has_attention else None
    if geom is not None:
        qf, kf = geom.q_flat, geom.kv_flat
        tp_q = 2 if geom.tp else None
        tp_b = 1 if geom.tp else None
        w("layers/attn/wq", (L, d, qf), "layers.attn_qkv", tp=tp_q, zdp=1,
          stacked=True, init="fan_in")
        w("layers/attn/wk", (L, d, kf), "layers.attn_qkv", zdp=1,
          stacked=True, init="fan_in")
        w("layers/attn/wv", (L, d, kf), "layers.attn_qkv", zdp=1,
          stacked=True, init="fan_in")
        if cfg.qkv_bias:
            w("layers/attn/bq", (L, qf), "layers.attn_qkv", tp=tp_b,
              init="zeros", stacked=True)
            w("layers/attn/bk", (L, kf), "layers.attn_qkv", init="zeros",
              stacked=True)
            w("layers/attn/bv", (L, kf), "layers.attn_qkv", init="zeros",
              stacked=True)
        w("layers/attn/wo", (L, qf, d), "layers.attn_out",
          tp=(1 if geom.tp else None), zdp=2, stacked=True, init="fan_in")
        w("layers/attn/norm_scale", (L, d), "layers.attn_norm", init="ones",
          stacked=True)
        if ln:
            w("layers/attn/norm_bias", (L, d), "layers.attn_norm",
              init="zeros", stacked=True)

    if cfg.has_ssm:
        di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
        w("layers/ssm/w_zx", (L, d, 2 * di), "layers.ssm_in", tp=2, zdp=1,
          stacked=True, init="fan_in")
        w("layers/ssm/w_bcdt", (L, d, 2 * ns + nh), "layers.ssm_in", zdp=1,
          stacked=True, init="fan_in")
        w("layers/ssm/wo", (L, di, d), "layers.ssm_out", tp=1, zdp=2,
          stacked=True, init="fan_in")
        w("layers/ssm/A_log", (L, nh), "layers.ssm_small", init="ssm_a",
          stacked=True)
        w("layers/ssm/D", (L, nh), "layers.ssm_small", init="ones",
          stacked=True)
        w("layers/ssm/dt_bias", (L, nh), "layers.ssm_small", init="zeros",
          stacked=True)
        w("layers/ssm/conv_w", (L, ssm_mod.CONV_K, di + 2 * ns),
          "layers.ssm_small", init="fan_in", stacked=True)
        w("layers/ssm/gate_norm", (L, di), "layers.ssm_small", init="ones",
          tp=1, stacked=True)
        w("layers/ssm/norm_scale", (L, d), "layers.ssm_norm", init="ones",
          stacked=True)

    ff_mult = 2 if cfg.act == "swiglu" else 1
    if cfg.is_moe:
        E, ff = cfg.moe_experts, cfg.d_ff
        w("layers/moe/router", (L, d, E), "layers.moe_router",
          stacked=True, init="fan_in")
        w("layers/moe/w13", (L, E, d, ff_mult * ff), "layers.moe_w13",
          tp=1, zdp=2, stacked=True, init="fan_in")
        w("layers/moe/w2", (L, E, ff, d), "layers.moe_w2", tp=1, zdp=2,
          stacked=True, init="fan_in")
        if cfg.moe_dense_residual:
            dff = cfg.moe_dense_d_ff or ff
            w("layers/moe/dense/w13", (L, d, ff_mult * dff),
              "layers.dense_w13", tp=2, zdp=1, stacked=True, init="fan_in")
            w("layers/moe/dense/w2", (L, dff, d), "layers.dense_w2", tp=1,
              zdp=2, stacked=True, init="fan_in")
        w("layers/moe/norm_scale", (L, d), "layers.ffn_norm", init="ones",
          stacked=True)
    elif cfg.d_ff:
        ff = cfg.d_ff
        w("layers/ffn/w13", (L, d, ff_mult * ff), "layers.ffn_w13", tp=2,
          zdp=1, stacked=True, init="fan_in")
        w("layers/ffn/w2", (L, ff, d), "layers.ffn_w2", tp=1, zdp=2,
          stacked=True, init="fan_in")
        w("layers/ffn/norm_scale", (L, d), "layers.ffn_norm", init="ones",
          stacked=True)
        if ln:
            w("layers/ffn/norm_bias", (L, d), "layers.ffn_norm",
              init="zeros", stacked=True)
    return specs


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig
    geom: Optional[AttnGeom]
    pset: ParamSet
    decisions: Dict[str, Decision]
    # True = full per-layer jax.checkpoint, False = keep everything, or
    # a tuple of checkpoint_name tags to SAVE (selective per-slice
    # remat plans — everything un-named is rematerialized); see
    # models.registry._remat_policy / sharding.specs.seg_matmul tags
    remat: Union[bool, Tuple[str, ...]] = True
    swa_window: int = 0          # override window for long-context decode
    # residual-stream sharding (batch over data, d over model). Without
    # this GSPMD lets the ZDP embedding's d-over-data sharding evict the
    # batch sharding from the whole stack (§Perf iter 1: 16x activation
    # blow-up). None on single-device builds.
    residual_sharding: Optional[Any] = None

    @property
    def _mesh(self):
        return self.residual_sharding[0] if self.residual_sharding else None

    def _constrain(self, x: jax.Array) -> jax.Array:
        if self.residual_sharding is None:
            return x
        mesh, spec_fn = self.residual_sharding
        spec = spec_fn(x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))

    # -- helpers ------------------------------------------------------------
    def _split_g(self, op: str) -> int:
        dec = self.decisions.get(op)
        if dec is None:
            return 1
        return dec.split if dec.uniform() is not None else 1

    def _layer_params(self, params: Dict[str, jax.Array]
                      ) -> Dict[str, jax.Array]:
        return {k: v for k, v in params.items() if k.startswith("layers/")}

    def _norm(self, lp, x, prefix):
        bias = lp.get(prefix + "_bias") if self.cfg.norm == "layernorm" \
            else None
        return norm(self.cfg, x, lp[prefix + "_scale"], bias)

    def _checkpoint(self, body):
        """Wrap a scan body per the plan's remat axis: full checkpoint,
        none, or a save-only-these-names selective policy."""
        if self.remat is True:
            return jax.checkpoint(body)
        if self.remat:   # tuple of checkpoint_name tags to save
            return jax.checkpoint(
                body, policy=jax.checkpoint_policies.save_only_these_names(
                    *self.remat))
        return body

    # -- embedding ----------------------------------------------------------
    def embed(self, params: Dict[str, jax.Array], batch: Dict[str, jax.Array]
              ) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            x = batch["frames"]
            if "mask" in batch:
                m = batch["mask"][..., None]
                x = jnp.where(m, params["embed/mask"].astype(x.dtype), x)
            return x
        tok = jnp.take(params["embed/tok"], batch["tokens"], axis=0)
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate(
                [batch["patches"].astype(tok.dtype), tok], axis=1)
        else:
            x = tok
        return x

    def logits(self, params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
        cfg = self.cfg
        fb = params.get("final_norm/bias")
        x = norm(cfg, x, params["final_norm/scale"], fb)
        if cfg.tie_embeddings:
            logits = x @ params["embed/tok"].T
        else:
            logits = seg_matmul(x, params, self.pset, "head/out", 0)
        # mask padded vocab entries
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask, attn_mod.NEG_INF, logits)
        return logits

    # -- one layer ----------------------------------------------------------
    def _block(self, x: jax.Array, lp: LayerParams, positions: jax.Array,
               window: int) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "hybrid":
            h = self._norm(lp, x, "layers/attn/norm")
            a = attn_mod.attn_forward(cfg, self.geom, self.pset, lp, h,
                                      positions, window=window)
            hs = self._norm(lp, x, "layers/ssm/norm")
            s = ssm_mod.ssm_forward(cfg, self.pset, lp, hs)
            x = x + 0.5 * (a + s)
        elif cfg.has_attention:
            h = self._norm(lp, x, "layers/attn/norm")
            x = x + attn_mod.attn_forward(cfg, self.geom, self.pset, lp, h,
                                          positions, window=window)
        elif cfg.has_ssm:
            h = self._norm(lp, x, "layers/ssm/norm")
            x = x + ssm_mod.ssm_forward(cfg, self.pset, lp, h)
        if cfg.is_moe:
            h = self._norm(lp, x, "layers/moe/norm")
            y, aux = moe_mod.moe_forward(cfg, self.pset, lp, h, mesh=self._mesh)
            if cfg.moe_dense_residual:
                y = y + ffn_mod.ffn_forward(
                    cfg, self.pset, lp, h, prefix="layers/moe/dense",
                    granularity=self._split_g("layers.dense_w13"))
            x = x + y
        elif cfg.d_ff:
            h = self._norm(lp, x, "layers/ffn/norm")
            x = x + ffn_mod.ffn_forward(
                cfg, self.pset, lp, h,
                granularity=self._split_g("layers.ffn_w13"))
        return x, aux

    # -- full-sequence forward (train / prefill) -----------------------------
    def forward(self, params: Dict[str, jax.Array],
                batch: Dict[str, jax.Array], *,
                window: int = 0) -> Tuple[jax.Array, jax.Array]:
        """Returns (hidden_states (B,S,d), aux_loss)."""
        x = self.embed(params, batch)
        positions = positions_for(self.cfg, batch, x.shape[1])
        layer_params = self._layer_params(params)
        win = window or self.cfg.sliding_window

        x = self._constrain(x)

        def body(carry, lp):
            x, aux = carry
            x = self._constrain(x)
            x, a = self._block(x, lp, positions, win)
            x = self._constrain(x)
            return (x, aux + a), None

        body = self._checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   layer_params)
        return x, aux

    # -- losses ---------------------------------------------------------------
    def _ce_block(self, params, x_blk, lab_blk) -> Tuple[jax.Array,
                                                         jax.Array]:
        """Summed NLL + valid count for one (B, c, d) block."""
        logits = self.logits(params, x_blk).astype(jnp.float32)
        valid = lab_blk >= 0
        lab = jnp.where(valid, lab_blk, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        return (jnp.where(valid, nll, 0.0).sum(),
                valid.sum().astype(jnp.float32))

    def loss_fn(self, params: Dict[str, jax.Array],
                batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        x, aux = self.forward(params, batch)
        if cfg.family == "vlm" and "patches" in batch:
            x = x[:, batch["patches"].shape[1]:]   # loss on text positions
        labels = batch["labels"]
        S = x.shape[1]
        # chunk the vocab projection over the sequence so the fp32
        # (B, S, V) logits never fully materialize (beyond-paper;
        # matters for the 128k-200k vocab archs at seq 4k)
        chunk = 512
        if (S % chunk == 0 and S > chunk
                and S * cfg.padded_vocab >= 2**27):
            nb = S // chunk
            xb = jnp.moveaxis(
                x.reshape(x.shape[0], nb, chunk, x.shape[-1]), 1, 0)
            lb = jnp.moveaxis(labels.reshape(labels.shape[0], nb, chunk),
                              1, 0)

            def body(carry, blk):
                s, n = carry
                bs, bn = jax.checkpoint(self._ce_block)(params, *blk)
                return (s + bs, n + bn), None

            (nll_sum, n_valid), _ = jax.lax.scan(
                body, (jnp.zeros(()), jnp.zeros(())), (xb, lb))
        else:
            nll_sum, n_valid = self._ce_block(params, x, labels)
        denom = jnp.maximum(n_valid, 1.0)
        ce = nll_sum / denom
        loss = ce + 0.01 * aux / max(1, cfg.n_layers)
        return loss, {"ce": ce, "aux": aux, "tokens": n_valid}

    # -- serving --------------------------------------------------------------
    def init_caches(self, batch: int, cache_len: int) -> Dict[str, Any]:
        caches: Dict[str, Any] = {}
        cfg = self.cfg
        if cfg.has_attention:
            win = self.swa_window or cfg.sliding_window
            alen = min(cache_len, win) if win else cache_len
            caches["attn"] = attn_mod.init_kv_cache(cfg, self.geom, batch,
                                                    alen)
        if cfg.has_ssm:
            caches["ssm"] = ssm_mod.init_ssm_cache(cfg, batch)
        return caches

    def decode_step(self, params: Dict[str, jax.Array],
                    caches: Dict[str, Any], tokens: jax.Array, t: jax.Array,
                    positions3: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One token for the whole batch. tokens: (B,1) int32; t is a
        scalar position or a (B,) vector (continuous batching decodes
        every slot at its own position)."""
        cfg = self.cfg
        x = jnp.take(params["embed/tok"], tokens, axis=0)
        layer_params = self._layer_params(params)
        win = self.swa_window or cfg.sliding_window

        xs: Dict[str, Any] = {"lp": layer_params}
        if "attn" in caches:
            xs["attn"] = caches["attn"]
        if "ssm" in caches:
            xs["ssm"] = caches["ssm"]

        def body(x, layer_in):
            lp = layer_in["lp"]
            new = {}
            if cfg.family == "hybrid":
                h = self._norm(lp, x, "layers/attn/norm")
                a, new_a = attn_mod.attn_decode(
                    cfg, self.geom, self.pset, lp, h, t, layer_in["attn"],
                    window=win, positions3=positions3)
                hs = self._norm(lp, x, "layers/ssm/norm")
                s, new_s = ssm_mod.ssm_decode(cfg, self.pset, lp, hs,
                                              layer_in["ssm"])
                x = x + 0.5 * (a + s)
                new["attn"], new["ssm"] = new_a, new_s
            elif cfg.has_attention:
                h = self._norm(lp, x, "layers/attn/norm")
                a, new_a = attn_mod.attn_decode(
                    cfg, self.geom, self.pset, lp, h, t, layer_in["attn"],
                    window=win, positions3=positions3)
                x = x + a
                new["attn"] = new_a
            elif cfg.has_ssm:
                h = self._norm(lp, x, "layers/ssm/norm")
                s, new_s = ssm_mod.ssm_decode(cfg, self.pset, lp, h,
                                              layer_in["ssm"])
                x = x + s
                new["ssm"] = new_s
            if cfg.is_moe:
                h = self._norm(lp, x, "layers/moe/norm")
                y, _ = moe_mod.moe_forward(cfg, self.pset, lp, h, mesh=self._mesh)
                if cfg.moe_dense_residual:
                    y = y + ffn_mod.ffn_forward(cfg, self.pset, lp, h,
                                                prefix="layers/moe/dense")
                x = x + y
            elif cfg.d_ff:
                h = self._norm(lp, x, "layers/ffn/norm")
                x = x + ffn_mod.ffn_forward(cfg, self.pset, lp, h)
            return x, new

        x, new_caches = jax.lax.scan(body, x, xs)
        logits = self.logits(params, x)
        return logits, new_caches

    def prefill(self, params: Dict[str, jax.Array],
                batch: Dict[str, jax.Array],
                cache_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
        """Full-sequence forward returning last-position logits + caches.

        Caches are rebuilt from a forward pass that also emits per-layer
        k/v (attention) and final states (ssm).  `cache_len` sizes the
        returned KV cache (>= S leaves free slots for decode — the
        continuous engine prefills straight into its slot shape);
        default S, the legacy rolling-cache behaviour."""
        cfg = self.cfg
        x = self.embed(params, batch)
        B, S = x.shape[:2]
        positions = positions_for(cfg, batch, S)
        win = self.swa_window or cfg.sliding_window
        target = cache_len or S
        alen = min(target, win) if win else target
        layer_params = self._layer_params(params)

        def body(carry, lp):
            x = self._constrain(carry)
            new = {}
            if cfg.family == "hybrid":
                h = self._norm(lp, x, "layers/attn/norm")
                a, kv = _attn_with_kv(self, lp, h, positions, win)
                hs = self._norm(lp, x, "layers/ssm/norm")
                s, st = _ssm_with_state(self, lp, hs)
                x = x + 0.5 * (a + s)
                new["attn"] = _kv_to_cache(kv, alen)
                new["ssm"] = st
            elif cfg.has_attention:
                h = self._norm(lp, x, "layers/attn/norm")
                a, kv = _attn_with_kv(self, lp, h, positions, win)
                x = x + a
                new["attn"] = _kv_to_cache(kv, alen)
            elif cfg.has_ssm:
                h = self._norm(lp, x, "layers/ssm/norm")
                s, st = _ssm_with_state(self, lp, h)
                x = x + s
                new["ssm"] = st
            if cfg.is_moe:
                h = self._norm(lp, x, "layers/moe/norm")
                y, _ = moe_mod.moe_forward(cfg, self.pset, lp, h, mesh=self._mesh)
                if cfg.moe_dense_residual:
                    y = y + ffn_mod.ffn_forward(cfg, self.pset, lp, h,
                                                prefix="layers/moe/dense")
                x = x + y
            elif cfg.d_ff:
                h = self._norm(lp, x, "layers/ffn/norm")
                x = x + ffn_mod.ffn_forward(cfg, self.pset, lp, h)
            return x, new

        body = self._checkpoint(body)
        x, caches = jax.lax.scan(body, x, layer_params)
        logits = self.logits(params, x[:, -1:])
        return logits, caches


def _attn_with_kv(model: Model, lp, h, positions, win):
    cfg, geom, pset = model.cfg, model.geom, model.pset
    q, k, v = attn_mod._proj_qkv(cfg, geom, pset, lp, h)
    from repro.models.common import rotate
    q = rotate(cfg, q.reshape(*q.shape[:2], -1, geom.head_dim), positions
               ).reshape(q.shape)
    k = rotate(cfg, k, positions)
    o = attn_mod.flash_attention(q, k, v, causal=cfg.causal, window=win)
    return attn_mod._out_proj(geom, pset, lp, o), (k, v)


def _kv_to_cache(kv, alen: int):
    k, v = kv
    B, S = k.shape[:2]
    take = min(alen, S)
    pos = jnp.arange(S - take, S, dtype=jnp.int32)
    slot = pos % alen
    kc = jnp.zeros((B, alen) + k.shape[2:], k.dtype).at[:, slot].set(
        k[:, S - take:])
    vc = jnp.zeros((B, alen) + v.shape[2:], v.dtype).at[:, slot].set(
        v[:, S - take:])
    pc = jnp.full((B, alen), -1, jnp.int32).at[:, slot].set(pos[None])
    return {"k": kc, "v": vc, "pos": pc}


def _ssm_with_state(model: Model, lp, h):
    cfg, pset = model.cfg, model.pset
    B, S, _ = h.shape
    di, ns, nh, hd = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
                      cfg.ssm_head_dim)
    z, xin, b, c, dt = ssm_mod._split_proj(cfg, pset, lp, h)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_out, conv_state = ssm_mod.causal_conv(conv_in, lp["layers/ssm/conv_w"])
    xin, b, c = (conv_out[..., :di], conv_out[..., di:di + ns],
                 conv_out[..., di + ns:])
    xh = xin.reshape(B, S, nh, hd)
    y, state = ssm_mod.ssd_chunk_scan(xh, dt, lp["layers/ssm/A_log"], b, c,
                                      cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * lp["layers/ssm/D"].astype(
        jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(h.dtype)
    from repro.models.common import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype),
                lp["layers/ssm/gate_norm"])
    out = seg_matmul(y, lp, pset, "layers/ssm/wo", 0)
    # conv state of the last K-1 steps
    cache = {"state": state,
             "conv": conv_state.astype(jnp.float32)}
    return out, cache
