"""Mixture-of-Experts layer: top-k router + capacity dispatch.

Dispatch is index-based (sort-free Shazeer-style with capacity): for
each expert we compute the positions of the tokens routed to it (rank
within expert via a cumulative-sum over the one-hot routing mask —
O(T·E) int ops, no (T,E,C) one-hot dispatch tensor), gather the tokens
into an (E, C, d) buffer, run the expert FFNs as a single grouped
einsum over the expert axis (TP = expert parallelism: E is sharded
over `model`), and combine with router weights via scatter-add.
Tokens overflowing an expert's capacity are dropped (standard capacity
semantics); the aux load-balance loss pushes the router away from that
regime.

Router runs in fp32; aux loss = E * sum_e f_e * p_e (Switch-style).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import ParamSet, gather_weight


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.moe_capacity_factor * n_tokens * cfg.moe_top_k
              / cfg.moe_experts)
    return max(8, -(-cap // 8) * 8)


def route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T,d) -> (probs (T,k), experts (T,k), aux_loss)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (T,E)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)    # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: fraction of tokens per expert * mean router prob
    E = cfg.moe_experts
    onehot = jax.nn.one_hot(top_e[:, 0], E)               # primary choice
    f = onehot.mean(0)
    p = probs.mean(0)
    aux = E * jnp.sum(f * p)
    return top_p, top_e, aux


def _expert_constrain(x: jax.Array, mesh, axis: int = 0) -> jax.Array:
    """Pin the expert axis to the `model` mesh axis (expert parallelism)
    with every other dim replicated. Without this, a d-sharded residual
    stream makes GSPMD partial-sum the (E, C, ff) expert activations in
    fp32 across the model axis (§Perf pair-2 pathology: ~28 GB
    all-reduce per matmul per layer) instead of gathering the much
    smaller (E, C, d) input."""
    if mesh is None or x.shape[axis] % mesh.shape["model"] != 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    parts = [None] * x.ndim
    parts[axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def moe_forward(cfg: ModelConfig, pset: ParamSet, lp: Dict[str, jax.Array],
                x: jax.Array, mesh=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y: (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E, k, ff = cfg.moe_experts, cfg.moe_top_k, cfg.d_ff
    C = _capacity(cfg, T)
    xt = x.reshape(T, d)

    top_p, top_e, aux = route(cfg, lp["layers/moe/router"], xt)

    # flatten (token, choice) pairs -> assignment list of length T*k
    flat_e = top_e.reshape(-1)                            # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    # rank of each assignment within its expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (T*k, E)
    rank = jnp.cumsum(onehot, axis=0) - onehot            # rank per expert
    my_rank = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
    keep = my_rank < C
    slot = flat_e * C + jnp.where(keep, my_rank, 0)       # (T*k,)

    # index-gather dispatch (§Perf pair-2 iter 2): the only scatter
    # builds tiny int32 slot->token maps; tokens then move via a single
    # gather whose output is expert-sharded (GSPMD lowers it to bf16
    # gathers instead of the fp32 scatter-add all-reduce).
    safe = jnp.where(keep, slot, E * C)   # dropped -> scratch slot E*C
    idx = jnp.zeros((E * C + 1,), jnp.int32).at[safe].set(
        flat_tok.astype(jnp.int32))[:E * C]
    occ = jnp.zeros((E * C + 1,), bool).at[safe].set(True)[:E * C]
    xe = xt[idx] * occ[:, None].astype(x.dtype)           # (E*C, d)
    xe = _expert_constrain(xe.reshape(E, C, d), mesh)

    # expert FFNs (E sharded over model axis)
    w13 = gather_weight(lp, pset, "layers/moe/w13")       # (E, d, 2ff)
    w2 = gather_weight(lp, pset, "layers/moe/w2")         # (E, ff, d)
    h = jnp.einsum("ecd,edf->ecf", xe, w13)
    h = _expert_constrain(h, mesh)
    if cfg.act == "swiglu":
        g1, g3 = h[..., :ff], h[..., ff:]
        h = jax.nn.silu(g1.astype(jnp.float32)).astype(x.dtype) * g3
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    ye = _expert_constrain(jnp.einsum("ecf,efd->ecd", h, w2), mesh)  # (E,C,d)

    # combine: gather each assignment's expert output and reduce over
    # the k choices — flat_tok is contiguous repeat(arange(T), k), so
    # this is a scatter-free reshape-sum.
    ye_flat = ye.reshape(E * C, d)
    contrib = ye_flat[slot] * (flat_p * keep)[:, None].astype(x.dtype)
    y = contrib.reshape(T, k, d).sum(axis=1)
    return y.reshape(B, S, d), aux.astype(jnp.float32)


def moe_ref(cfg: ModelConfig, router_w, w13, w2, x: jax.Array
            ) -> jax.Array:
    """Dense oracle (no capacity drops): every token times its top-k
    experts, computed with full dense expert application."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    top_p, top_e, _ = route(cfg, router_w, xt)
    y = jnp.zeros((T, d), jnp.float32)
    for e in range(cfg.moe_experts):
        h = xt @ w13[e]
        ff = cfg.d_ff
        if cfg.act == "swiglu":
            h = (jax.nn.silu(h[..., :ff].astype(jnp.float32))
                 .astype(x.dtype) * h[..., ff:])
        else:
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        out_e = (h @ w2[e]).astype(jnp.float32)
        w = ((top_e == e) * top_p).sum(-1)                # (T,)
        y = y + out_e * w[:, None]
    return y.reshape(B, S, d).astype(x.dtype)
