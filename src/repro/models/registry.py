"""Model construction + abstract input specs for every (arch, shape).

`build_model(run, plan, mesh)` returns a `Built` bundle:
  * model        — the Model (forward/loss/prefill/decode)
  * param_specs  — WeightSpec list (for checkpointing / inspection)
  * abstract()   — ShapeDtypeStruct param tree (dry-run, no allocation)
  * init(key)    — materialized params (small configs / smoke tests)
  * shardings    — param sharding tree from the OSDP plan

`input_specs(run)` builds the abstract input batch for the assigned
shape — tokens/labels for train, request batch for serving — matching
the carve-outs (audio frames, VLM patches are precomputed embeddings).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core.cost_model import Decision
from repro.core.plan import Plan, batch_axes
from repro.models.common import attn_geometry
from repro.models.transformer import Model, build_specs
from repro.sharding.specs import (OverlapConfig, ParamSet, build_param_set,
                                  saved_activation_names)

# VLM stub: patch-embedding budget per sequence (see configs/qwen2_vl_2b)
N_PATCHES = 256


@dataclass
class Built:
    model: Model
    pset_abstract: ParamSet
    run: RunConfig
    mesh: Optional[Mesh]

    @property
    def shardings(self) -> Dict[str, NamedSharding]:
        return self.pset_abstract.shardings

    def abstract_params(self) -> Dict[str, jax.ShapeDtypeStruct]:
        return self.pset_abstract.params

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        specs = build_specs(self.run.model,
                            self.run.mesh.model_parallel if self.mesh else 1)
        decisions = self.model.decisions
        concrete = build_param_set(specs, decisions, self.mesh, key,
                                   abstract=False)
        return concrete.params


def build_model(run: RunConfig, plan: Optional[Plan] = None,
                mesh: Optional[Mesh] = None,
                overlap: Optional[OverlapConfig] = None) -> Built:
    """`overlap` enables the runtime comm/compute overlap transforms:
    segment-weight prefetch in `seg_matmul` (via the pset the model
    holds) and bucketed gradient barriers in `make_train_step` (which
    reads it back off `built.pset_abstract.overlap`).  None keeps the
    exact legacy program."""
    cfg = run.model
    cfg.validate()
    tp = run.mesh.model_parallel
    decisions: Dict[str, Decision] = plan.decisions if plan else {}
    specs = build_specs(cfg, tp)
    pset = build_param_set(specs, decisions, mesh,
                           jax.random.PRNGKey(run.seed), abstract=True,
                           overlap=overlap)
    geom = attn_geometry(cfg, tp) if cfg.has_attention else None
    model = Model(cfg=cfg, geom=geom, pset=pset, decisions=decisions,
                  remat=_remat_policy(run, decisions, pset),
                  swa_window=(run.swa_window
                              if run.shape.name == "long_500k"
                              and not cfg.sliding_window else 0),
                  residual_sharding=_residual_sharding(run, mesh))
    return Built(model=model, pset_abstract=pset, run=run, mesh=mesh)


def _remat_policy(run: RunConfig, decisions: Dict[str, Decision],
                  pset: ParamSet):
    """Compile the plan's remat axis into Model.remat.

    Legacy plans (no explicit per-slice bits) keep the global flag.
    Selective plans compile to the tuple of checkpoint_name tags whose
    activations the jax.checkpoint policy must SAVE (everything else is
    rematerialized); all-keep plans drop the checkpoint entirely and
    all-remat plans fall back to the plain full checkpoint.
    """
    default = run.osdp.env_checkpointing
    if not decisions or not any(d.remat is not None
                                for d in decisions.values()):
        return default
    saved, any_remat = saved_activation_names(pset.layouts, default)
    if not any_remat:
        return False
    if not saved:
        return True
    return saved


def _residual_sharding(run: RunConfig, mesh: Optional[Mesh]):
    """(mesh, shape -> PartitionSpec) for the (B, S, d) residual stream:
    batch over (pod, data), d over model — axes dropped when they don't
    divide. See Model.residual_sharding."""
    if mesh is None or mesh.devices.size <= 1:
        return None
    dp = batch_axes(mesh)
    import numpy as _np
    n_dp = int(_np.prod([mesh.shape[a] for a in dp]))
    n_tp = mesh.shape["model"]

    def spec_fn(shape):
        if len(shape) != 3:
            return None
        b, _, d = shape
        parts = [None, None, None]
        if b % n_dp == 0:
            parts[0] = dp
        if d % n_tp == 0:
            parts[2] = "model"
        if parts == [None, None, None]:
            return None
        return P(*parts)

    return (mesh, spec_fn)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(run: RunConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract input batch for (arch, shape) — no device allocation."""
    cfg, shape = run.model, run.shape
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return train_inputs(cfg, B, S)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, B, S)
    return decode_inputs(run, B, S)


def train_inputs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    if cfg.family == "audio":
        return {
            "frames": _sds((B, S, cfg.d_model), jnp.bfloat16),
            "mask": _sds((B, S), jnp.bool_),
            "labels": _sds((B, S), jnp.int32),
        }
    if cfg.family == "vlm":
        s_text = S - N_PATCHES
        return {
            "tokens": _sds((B, s_text), jnp.int32),
            "patches": _sds((B, N_PATCHES, cfg.d_model), jnp.bfloat16),
            "positions": _sds((B, S, 3), jnp.int32),
            "labels": _sds((B, s_text), jnp.int32),
        }
    return {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }


def prefill_inputs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    b = train_inputs(cfg, B, S)
    b.pop("labels", None)
    if cfg.family == "audio":
        b.pop("mask", None)
    return b


def decode_inputs(run: RunConfig, B: int, S: int) -> Dict[str, Any]:
    """One-token decode with a seq_len cache: {tokens, t, caches...}."""
    cfg = run.model
    built = build_model(run)
    caches = jax.eval_shape(lambda: built.model.init_caches(B, S))
    out: Dict[str, Any] = {
        "tokens": _sds((B, 1), jnp.int32),
        "t": _sds((), jnp.int32),
        "caches": caches,
    }
    if cfg.rope == "mrope":
        out["positions3"] = _sds((B, 1, 3), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# input shardings
# ---------------------------------------------------------------------------

def input_shardings(run: RunConfig, mesh: Mesh,
                    inputs: Dict[str, Any]) -> Dict[str, Any]:
    """Batch over (pod, data); long_500k caches seq-sharded (DESIGN §6)."""
    dp = batch_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    n_tp = mesh.shape["model"]

    def leaf_spec(path: str, leaf) -> NamedSharding:
        ndim = len(leaf.shape)
        parts = [None] * ndim
        batch_ok = lambda ax: leaf.shape[ax] % n_dp == 0
        if path.startswith("caches/attn"):
            # (L, B, Sc, KV, hd) — flash-decoding: seq over `model`
            if ndim >= 2 and batch_ok(1):
                parts[1] = dp
                if ndim >= 3 and leaf.shape[2] % n_tp == 0:
                    parts[2] = "model"
            elif ndim >= 3:
                # batch=1 (long_500k): spread the window over everything
                if leaf.shape[2] % (n_dp * n_tp) == 0:
                    parts[2] = dp + ("model",)
                elif leaf.shape[2] % n_tp == 0:
                    parts[2] = "model"
        elif path.startswith("caches/ssm/state"):
            # (L, B, nh, hd, ns): batch over dp, heads over model
            if batch_ok(1):
                parts[1] = dp
            if ndim >= 3 and leaf.shape[2] % n_tp == 0:
                parts[2] = "model"
        elif path.startswith("caches/ssm/conv"):
            if batch_ok(1):
                parts[1] = dp
        elif path == "t":
            pass
        elif ndim >= 1 and leaf.shape and batch_ok(0):
            parts[0] = dp
        return NamedSharding(mesh, P(*parts))

    flat = _flatten("", inputs)
    specs = {k: leaf_spec(k, v) for k, v in flat.items()}
    return _unflatten(specs, inputs)


def _flatten(prefix: str, tree) -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(f"{prefix}/{k}" if prefix else k, v))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any], like) -> Any:
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        return flat[prefix]
    return rec("", like)
