"""Shared model building blocks: norms, RoPE / M-RoPE, GQA geometry.

GQA geometry on a fixed 16-way `model` axis (DESIGN.md §6):
  * q/k/v/o projections use flat (n_heads*head_dim) layouts; the flat
    dim is TP-sharded.
  * For reshape (flat -> (kv, group, hd)) to preserve the sharding, we
    need (kv * group) % tp == 0. If the config's head count doesn't
    satisfy that, q heads are padded *per kv group* (layout
    (kv, group_padded, hd)); padded heads are masked to exact zero
    before the out-projection, so gradients to their weights vanish and
    the model is semantically identical to the unpadded config.
  * kv heads are replicated across the model axis (kv tensors are small
    under GQA); see DESIGN.md for the cache sharding that compensates.
  * If padding would exceed PAD_LIMIT of the true head count, attention
    runs without TP (params/compute replicated on the model axis) —
    OSDP's memory search then naturally leans ZDP for those weights.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PAD_LIMIT = 1.5


@dataclass(frozen=True)
class AttnGeom:
    """Resolved GQA geometry for a given model-axis size."""

    n_heads: int          # true q heads
    n_kv: int
    head_dim: int
    group: int            # true q heads per kv head
    group_padded: int     # padded group size (>= group)
    tp: bool              # whether attention projections are TP-sharded

    @property
    def padded_heads(self) -> int:
        return self.n_kv * self.group_padded

    @property
    def q_flat(self) -> int:
        return self.padded_heads * self.head_dim

    @property
    def kv_flat(self) -> int:
        return self.n_kv * self.head_dim


def attn_geometry(cfg: ModelConfig, tp_size: int) -> AttnGeom:
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    gp = g
    if tp_size > 1:
        while (kv * gp) % tp_size != 0:
            gp += 1
    if gp * kv > PAD_LIMIT * h:
        return AttnGeom(h, kv, hd, g, g, tp=False)
    return AttnGeom(h, kv, hd, g, gp, tp=(tp_size > 1))


# --- norms -------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def norm(cfg: ModelConfig, x: jax.Array, scale: jax.Array,
         bias: Optional[jax.Array] = None) -> jax.Array:
    if cfg.norm == "layernorm":
        assert bias is not None
        return layernorm(x, scale, bias)
    return rmsnorm(x, scale)


# --- rotary embeddings -------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2 / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions3: (..., S, 3) = (t, h, w) index per token. The hd/2
    frequency slots are split into `sections` (t, h, w); each section
    rotates by its own position component.
    """
    hd = x.shape[-1]
    half = hd // 2
    st, sh, sw = sections
    assert st + sh + sw == half, (sections, half)
    freqs = rope_freqs(hd, theta)                       # (half,)
    # per-slot position: section t uses positions3[...,0], etc.
    sec_id = jnp.concatenate([
        jnp.zeros((st,), jnp.int32), jnp.ones((sh,), jnp.int32),
        jnp.full((sw,), 2, jnp.int32)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions3.shape[:-1] + (half,)).astype(
            jnp.int32),
        axis=-1)                                        # (..., S, half)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg: ModelConfig, batch: dict, seq: int) -> jax.Array:
    """Token positions: (B,S) for rope, (B,S,3) for mrope."""
    if cfg.rope == "mrope":
        return batch["positions"]
    if "positions" in batch:
        return batch["positions"]
    ref = batch.get("tokens", batch.get("frames"))
    b = ref.shape[0]
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (b, seq))


def rotate(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)
