"""Dense FFN (SwiGLU / GeLU) with OSDP operator-splitting hooks."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cost_model import Decision
from repro.core.operator_split import chunked_ffn
from repro.sharding.specs import ParamSet, seg_matmul


def ffn_forward(cfg: ModelConfig, pset: ParamSet, lp: Dict[str, jax.Array],
                x: jax.Array, prefix: str = "layers/ffn",
                granularity: int = 1) -> jax.Array:
    """x: (B,S,d) -> (B,S,d).

    Three execution paths:
      * plan split the op into mixed-mode segments -> seg_matmul
        (paper §3.3 per-slice modes);
      * uniform mode but splitting requested -> chunked_ffn (sequential
        slice processing caps the live hidden / gathered weight);
      * otherwise plain matmuls.
    """
    w13_path, w2_path = f"{prefix}/w13", f"{prefix}/w2"
    mixed = pset.layouts[w13_path].is_split or pset.layouts[w2_path].is_split
    if mixed:
        h = seg_matmul(x, lp, pset, w13_path, 0)
        h = _act(cfg, h)
        return seg_matmul(h, lp, pset, w2_path, 0)
    w13 = lp[w13_path]
    w2 = lp[w2_path]
    if granularity > 1:
        return chunked_ffn(x, w13, w2, granularity, cfg.act)
    return _act(cfg, x @ w13) @ w2


def _act(cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        ff = h.shape[-1] // 2
        return (jax.nn.silu(h[..., :ff].astype(jnp.float32))
                .astype(h.dtype) * h[..., ff:])
    return jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
