"""GQA attention: blockwise-flash training path + cached decode path.

Layouts (see common.AttnGeom):
  q: (B, S, KV, Gp, hd)   — grouped by kv head; Gp includes padding
  k/v: (B, T, KV, hd)     — kv heads replicated over the model axis

The training/prefill path is an online-softmax blockwise ("flash")
attention written in pure jnp with `lax.scan` over query and key
blocks, so the (S, T) score matrix never materializes — mandatory at
the 32k/500k assigned shapes. The Pallas kernel in
`repro.kernels.flash_attention` implements the same contract for the
TPU hot path and is validated against the same oracle.

Decode: the KV cache tags every slot with its absolute position
(`pos`, -1 = empty), which makes full-cache and rolling sliding-window
caches uniform: validity/window masking is pure position arithmetic.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import AttnGeom, rotate
from repro.sharding.specs import ParamSet, seg_matmul

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# blockwise flash attention (pure jnp)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: int = 0, q_offset: int = 0,
                    bq: int = 512, bk: int = 1024,
                    scale: Optional[float] = None) -> jax.Array:
    """q:(B,S,KV,G,hd) k,v:(B,T,KV,hd) -> (B,S,KV,G,hd)."""
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(bq, S)
    bk = min(bk, T)
    # pad S/T to block multiples
    Sp, Tp = -(-S // bq) * bq, -(-T // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    nq, nk = Sp // bq, Tp // bk

    qb = jnp.moveaxis(qp.reshape(B, nq, bq, KV, G, hd), 1, 0)
    kb = jnp.moveaxis(kp.reshape(B, nk, bk, KV, hd), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nk, bk, KV, hd), 1, 0)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def k_step(carry, kj_blk):
            kj, k_blk, v_blk = kj_blk
            m, l, acc = carry
            k_pos = kj * bk + jnp.arange(bk)
            s = jnp.einsum("bqkgh,btkh->bkgqt", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            msk = (k_pos[None, :] < T)
            if causal:
                msk = msk & (k_pos[None, :] <= q_pos[:, None])
            if window:
                msk = msk & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,KV,G,bq,hd) -> (B,bq,KV,G,hd)
        return None, jnp.moveaxis(out, 3, 1)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, Sp, KV, G, hd)[:, :S]
    return out.astype(q.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, window: int = 0,
                  q_offset: int = 0) -> jax.Array:
    """Naive oracle — same contract as flash_attention."""
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqkgh,btkh->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(S)
    k_pos = jnp.arange(T)
    msk = jnp.ones((S, T), bool)
    if causal:
        msk = msk & (k_pos[None, :] <= q_pos[:, None])
    if window:
        msk = msk & (q_pos[:, None] - k_pos[None, :] < window)
    s = jnp.where(msk[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def _proj_qkv(cfg: ModelConfig, geom: AttnGeom, pset: ParamSet,
              lp: Dict[str, jax.Array], x: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = seg_matmul(x, lp, pset, "layers/attn/wq", 0)
    k = seg_matmul(x, lp, pset, "layers/attn/wk", 0)
    v = seg_matmul(x, lp, pset, "layers/attn/wv", 0)
    if cfg.qkv_bias:
        q = q + lp["layers/attn/bq"]
        k = k + lp["layers/attn/bk"]
        v = v + lp["layers/attn/bv"]
    q = q.reshape(B, S, geom.n_kv, geom.group_padded, geom.head_dim)
    k = k.reshape(B, S, geom.n_kv, geom.head_dim)
    v = v.reshape(B, S, geom.n_kv, geom.head_dim)
    return q, k, v


def _group_mask(geom: AttnGeom, dtype) -> jax.Array:
    """(KV, Gp) 1/0 mask zeroing padded q heads."""
    return (jnp.arange(geom.group_padded) < geom.group).astype(dtype)[None, :]


def _out_proj(geom: AttnGeom, pset: ParamSet, lp: Dict[str, jax.Array],
              o: jax.Array) -> jax.Array:
    """o: (B,S,KV,Gp,hd) -> (B,S,d); masks padded heads to exact zero."""
    B, S = o.shape[:2]
    o = o * _group_mask(geom, o.dtype)[None, None, :, :, None]
    o = o.reshape(B, S, geom.q_flat)
    return seg_matmul(o, lp, pset, "layers/attn/wo", 0)


# ---------------------------------------------------------------------------
# block entry points
# ---------------------------------------------------------------------------

def attn_forward(cfg: ModelConfig, geom: AttnGeom, pset: ParamSet,
                 lp: Dict[str, jax.Array], x: jax.Array,
                 positions: jax.Array, *, window: int = 0) -> jax.Array:
    """Training / prefill attention over a full sequence."""
    q, k, v = _proj_qkv(cfg, geom, pset, lp, x)
    q = rotate(cfg, q.reshape(*q.shape[:2], -1, geom.head_dim), positions
               ).reshape(q.shape)
    k = rotate(cfg, k, positions)
    win = window or cfg.sliding_window
    o = flash_attention(q, k, v, causal=cfg.causal, window=win)
    return _out_proj(geom, pset, lp, o)


def init_kv_cache(cfg: ModelConfig, geom: AttnGeom, batch: int,
                  cache_len: int, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Per-layer stacked cache pytree (leading L axis)."""
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, cache_len, geom.n_kv, geom.head_dim), dtype),
        "v": jnp.zeros((L, batch, cache_len, geom.n_kv, geom.head_dim), dtype),
        "pos": jnp.full((L, batch, cache_len), -1, jnp.int32),
    }


def attn_decode(cfg: ModelConfig, geom: AttnGeom, pset: ParamSet,
                lp: Dict[str, jax.Array], x: jax.Array, t: jax.Array,
                cache: Dict[str, jax.Array], *,
                window: int = 0,
                positions3: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B,1,d); t: step index — a scalar (whole
    batch in lockstep) or a (B,) vector (continuous batching: each
    sequence at its own position); cache holds this layer's slices
    {k:(B,Sc,KV,hd), v:..., pos:(B,Sc)}."""
    B = x.shape[0]
    Sc = cache["k"].shape[1]
    q, k, v = _proj_qkv(cfg, geom, pset, lp, x)
    t_vec = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    if cfg.rope == "mrope":
        pos_arg = positions3                       # (B,1,3)
    else:
        pos_arg = t_vec[:, None]                   # (B,1)
    if cfg.rope != "none":
        q = rotate(cfg, q.reshape(B, 1, -1, geom.head_dim), pos_arg
                   ).reshape(q.shape)
        k = rotate(cfg, k, pos_arg)
    # per-sequence ring-buffer slot: a scatter row-by-row (identical to
    # the old dynamic_update_slice when every t is equal)
    slot = jnp.where(Sc > 0, t_vec % Sc, 0).astype(jnp.int32)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    pos_cache = cache["pos"].at[bidx, slot].set(t_vec)

    # single-row softmax over the cache (scores are (B,KV,Gp,1,Sc) — small)
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(geom.head_dim)
    valid = pos_cache >= 0
    if window:
        valid = valid & (t_vec[:, None] - pos_cache < window)
    valid = valid & (pos_cache <= t_vec[:, None])
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v_cache.astype(jnp.float32)
                   ).astype(x.dtype)
    out = _out_proj(geom, pset, lp, o)
    return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}
