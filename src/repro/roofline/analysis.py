"""Roofline analysis from compiled HLO (no hardware needed).

Three terms per (arch x shape x mesh), per the assignment:

    compute    = HLO_FLOPs_per_device / (peak_FLOP/s)
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

`compiled.cost_analysis()` reports per-device FLOPs / bytes (the SPMD
partitioner has already divided the program). Collective bytes are NOT
in cost_analysis: we parse the post-optimization HLO text
(`compiled.as_text()`; collectives don't exist in the pre-partitioning
StableHLO from `lowered.as_text()`) and sum, for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, the
largest tensor touched (≈ ring wire bytes for large N).
"""
from __future__ import annotations

import dataclasses
import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import DeviceInfo

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_TENSOR_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+[^=]*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")


def _tensor_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    if not dims:
        return bpe
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * bpe


def analyze_lowered(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Collective census of an HLO/StableHLO text dump."""
    per_kind: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in COLLECTIVE_KINDS}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        sizes = [_tensor_bytes(d, s) for d, s in _TENSOR_RE.findall(line)]
        b = float(max(sizes)) if sizes else 0.0
        per_kind[kind]["count"] += 1
        per_kind[kind]["bytes"] += b
        total += b
    out: Dict[str, Dict[str, float]] = {
        k: v for k, v in per_kind.items() if v["count"]}
    out["total_bytes"] = total  # type: ignore[assignment]
    return out


def hlo_flops_bytes(cost_analysis) -> Dict[str, float]:
    """Normalize compiled.cost_analysis() output."""
    if isinstance(cost_analysis, (list, tuple)):
        cost_analysis = cost_analysis[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in cost_analysis:
            out[k.replace(" ", "_")] = float(cost_analysis[k])
    # per-memory-space breakdown if present
    for k, v in cost_analysis.items():
        if k.startswith("bytes accessed") and k != "bytes accessed":
            out[k.replace(" ", "_")] = float(v)
    return out


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float        # MODEL_FLOPS / (HLO_FLOPs * chips)

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def analytic_roofline(record: Dict,
                      device: Optional[DeviceInfo] = None) -> Dict[str, float]:
    """Cost-model (scan-aware) roofline terms for a dry-run record.

    XLA's cost_analysis counts a `while` body once, so for scan-over-
    layers programs the raw HLO terms undercount by ~n_layers; these
    analytic terms come from the operator description instead (exact
    FLOP/byte counts for every matmul we emit) and are what the §Perf
    dominance calls use. Raw HLO terms stay in the report for
    comparison.
    """
    from repro.configs import get_arch, get_shape
    from repro.core.cost_model import CostEnv, plan_cost, uniform_plan, ZDP
    from repro.core.descriptions import describe, STATE_BYTES_PER_PARAM
    from repro.configs.base import MULTI_POD_MESH, SINGLE_POD_MESH

    device = device or DeviceInfo()
    model = get_arch(record["arch"])
    shape = get_shape(record["shape"])
    mesh = MULTI_POD_MESH if record["mesh"].count("x") == 2 \
        else SINGLE_POD_MESH
    chips = mesh.n_devices
    desc = describe(model, shape)
    env = CostEnv(device, mesh, checkpointing=(shape.kind == "train"),
                  train=(shape.kind == "train"))
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    mult = (3.0 if shape.kind == "train" else 1.0) * (
        1.3 if shape.kind == "train" else 1.0)
    flops_tok = sum(op.flops_per_token for op in desc.operators)
    if model.is_moe:
        pass  # flops_per_token already counts top-k only
    compute_s = flops_tok * tokens * mult / chips / (
        device.peak_flops * device.mxu_efficiency)
    # memory traffic per step: read params (+ grads/opt in train) + acts
    state = desc.total_params * (STATE_BYTES_PER_PARAM
                                 if shape.kind == "train" else 2)
    act_traffic = sum(op.act_bytes_per_token for op in desc.operators) \
        * tokens * (2.0 if shape.kind == "train" else 1.0)
    memory_s = (state + act_traffic) / chips / device.hbm_bw
    # collective: evaluate the record's actual OSDP plan
    from repro.core.cost_model import Decision
    digest = record.get("plan", {})
    decisions = {}
    for name, modes in digest.items():
        if modes.startswith("MIXED("):
            decisions[name] = Decision(name, tuple(
                modes[6:-1].split(",")))
        else:
            decisions[name] = Decision(name, (modes,))
    if not decisions:
        decisions = uniform_plan(desc, ZDP)
    comm = plan_cost(desc, decisions, shape.global_batch, env).comm_time
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": comm}


def roofline(record: Dict, device: Optional[DeviceInfo] = None,
             n_chips: Optional[int] = None) -> RooflineTerms:
    """Compute the three terms from a dry-run record (see launch.dryrun)."""
    device = device or DeviceInfo()
    mesh = record["mesh"]
    chips = n_chips or math.prod(int(x) for x in mesh.split("x"))
    cost = record.get("cost_analysis", {})
    flops = cost.get("flops", 0.0)                  # per-device
    bytes_acc = cost.get("bytes_accessed", 0.0)     # per-device
    coll = record.get("collectives", {})
    coll_bytes = coll.get("total_bytes", 0.0)       # per-device program

    compute_s = flops / device.peak_flops
    memory_s = bytes_acc / device.hbm_bw
    collective_s = coll_bytes / device.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6 N D for training, 2 N D for inference fwd
    n_active = record.get("active_params", record.get("params", 0))
    tokens = record.get("tokens", 0)
    mult = 6.0 if record.get("kind") == "train" else 2.0
    model_flops = mult * n_active * tokens
    hlo_total = flops * chips
    ratio = model_flops / hlo_total if hlo_total else 0.0
    return RooflineTerms(compute_s, memory_s, collective_s, dominant,
                         model_flops, flops, ratio)
