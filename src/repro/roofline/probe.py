"""HLO probes for the §Perf hypothesis loop.

Given compiled HLO text:
  * `largest_tensors` — the top-k biggest buffers (what dominates temp),
  * `collectives_by_scope` — collective ops inside vs outside `while`
    bodies (a gather hoisted out of the layer scan materializes the
    whole stacked weight: the §Perf-1 pathology),
  * `count_op` — occurrences of an opcode (e.g. remat-duplicated ops).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.roofline.analysis import _DTYPE_BYTES, _TENSOR_RE, _OP_RE


def _bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype, 0)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bpe


def largest_tensors(hlo: str, k: int = 12) -> List[Tuple[float, str]]:
    seen: Dict[str, float] = {}
    for line in hlo.splitlines():
        line = line.strip()
        if "=" not in line or line.startswith("//"):
            continue
        head = line.split("=", 1)[0].strip()
        m = _TENSOR_RE.search(line.split("=", 1)[1])
        if not m:
            continue
        b = _bytes(m.group(1), m.group(2))
        if b:
            seen[head[:80]] = max(seen.get(head[:80], 0), b)
    top = sorted(seen.items(), key=lambda kv: -kv[1])[:k]
    return [(v / 2**30, k_) for k_, v in top]


def collectives_by_scope(hlo: str) -> Dict[str, Dict[str, float]]:
    """Split the collective census into while-body vs entry scopes.

    HLO text lists one computation per block: `%name (args) -> ... {`.
    While bodies are computations referenced by `while(...)` ops; we
    approximate scope by tracking the current computation and whether
    its name contains 'while' / 'body' / 'cond' (XLA's naming).
    """
    scopes = {"in_loop": {"count": 0, "bytes": 0.0},
              "top_level": {"count": 0, "bytes": 0.0}}
    current_in_loop = False
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith(("%", "ENTRY")) and s.endswith("{"):
            name = s.split("(", 1)[0]
            current_in_loop = ("while" in name or "body" in name
                               or "scan" in name)
            continue
        m = _OP_RE.search(s)
        if not m:
            continue
        sizes = [_bytes(d, dd) for d, dd in _TENSOR_RE.findall(s)]
        b = float(max(sizes)) if sizes else 0.0
        key = "in_loop" if current_in_loop else "top_level"
        scopes[key]["count"] += 1
        scopes[key]["bytes"] += b
    return scopes


def count_op(hlo: str, opcode: str) -> int:
    return len(re.findall(rf"=\s+[^=]*?\b{re.escape(opcode)}\(", hlo))
