"""Deterministic synthetic data pipeline.

Produces reproducible token/frame batches per (seed, step) — the same
global batch regardless of host count — with a learnable signal (a
noisy affine-autoregressive token process) so smoke-training shows a
decreasing loss, not just non-NaN.

The pipeline is host-sharded: `Dataset.global_batch(step)` builds the
full batch (for single-host CPU runs), `host_batch(step, host, n)` the
per-host slice a multi-host launcher would feed `jax.make_array_from
_process_local_data`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class Dataset:
    model: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    n_patches: int = 256          # VLM stub budget
    mask_prob: float = 0.3        # audio masked-prediction rate

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def global_batch(self, step: int,
                     batch: Optional[int] = None,
                     seq: Optional[int] = None) -> Dict[str, np.ndarray]:
        B = batch or self.shape.global_batch
        S = seq or self.shape.seq_len
        cfg = self.model
        rng = self._rng(step)
        if cfg.family == "audio":
            return self._audio(rng, B, S)
        if cfg.family == "vlm":
            return self._vlm(rng, B, S)
        toks = self._lm_tokens(rng, B, S + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def host_batch(self, step: int, host: int, n_hosts: int,
                   **kw) -> Dict[str, np.ndarray]:
        g = self.global_batch(step, **kw)
        return {k: np.array_split(v, n_hosts, axis=0)[host]
                for k, v in g.items()}

    # -- generators -----------------------------------------------------------
    def _lm_tokens(self, rng, B: int, S: int) -> np.ndarray:
        """Markov-ish stream: tok[t] = (a*tok[t-1] + b + noise) % V."""
        V = self.model.vocab_size
        a = 31, 17
        toks = np.zeros((B, S), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        noise = (rng.random((B, S)) < 0.1)
        jump = rng.integers(0, V, (B, S))
        for t in range(1, S):
            nxt = (toks[:, t - 1] * a[0] + a[1]) % V
            toks[:, t] = np.where(noise[:, t], jump[:, t], nxt)
        return toks

    def _audio(self, rng, B: int, S: int) -> Dict[str, np.ndarray]:
        d = self.model.d_model
        V = self.model.vocab_size
        # temporally-correlated unit stream (real audio has structure;
        # iid labels would make masked prediction unlearnable — the
        # model must infer masked units from CONTEXT)
        labels = self._lm_tokens(rng, B, S) % V
        # frames carry a linear rendering of the label (learnable signal)
        proj = self._rng(0).standard_normal((V, d)).astype(np.float32) * 0.1
        frames = proj[labels] + rng.standard_normal(
            (B, S, d)).astype(np.float32) * 0.05
        mask = rng.random((B, S)) < self.mask_prob
        lab = np.where(mask, labels, -1)   # loss only on masked frames
        return {"frames": frames.astype(np.float32),
                "mask": mask, "labels": lab.astype(np.int32)}

    def _vlm(self, rng, B: int, S: int) -> Dict[str, np.ndarray]:
        d = self.model.d_model
        P = min(self.n_patches, S // 2)
        s_text = S - P
        toks = self._lm_tokens(rng, B, s_text + 1)
        patches = rng.standard_normal((B, P, d)).astype(np.float32) * 0.1
        positions = mrope_positions(B, P, s_text)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "patches": patches,
                "positions": positions,
                "labels": toks[:, 1:].astype(np.int32)}


def mrope_positions(B: int, n_patches: int, s_text: int,
                    grid: Optional[int] = None) -> np.ndarray:
    """Qwen2-VL M-RoPE positions: image patches get (t0, h, w) on an
    h x w grid at a single timestep; text continues t = t0+1, t0+2, ...
    with h = w = t (diagonal)."""
    g = grid or int(np.sqrt(n_patches))
    pos = np.zeros((B, n_patches + s_text, 3), np.int32)
    hh, ww = np.divmod(np.arange(n_patches), g)
    pos[:, :n_patches, 0] = 0
    pos[:, :n_patches, 1] = hh
    pos[:, :n_patches, 2] = ww
    t = np.arange(s_text) + max(g, 1)
    pos[:, n_patches:, 0] = t
    pos[:, n_patches:, 1] = t
    pos[:, n_patches:, 2] = t
    return pos
