"""Pure-jnp oracles for every Pallas kernel (self-contained)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def split_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B,KV,G,S,hd), k/v: (B,KV,T,hd) — kernel layout."""
    B, KV, G, S, hd = q.shape
    T = k.shape[2]
    s = jnp.einsum("bkgqh,bkth->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,bkth->bkgqh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                 b: jax.Array, c: jax.Array) -> jax.Array:
    """Sequential state-space recurrence (x:(B,S,nh,hd), b/c:(B,S,ns))."""
    B, S, nh, hd = x.shape
    ns = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(s, t):
        dec = jnp.exp(dtf[:, t] * a)
        upd = jnp.einsum("bs,bnh->bnhs", bf[:, t],
                         xf[:, t] * dtf[:, t][..., None])
        s = s * dec[:, :, None, None] + upd
        y = jnp.einsum("bs,bnhs->bnh", cf[:, t], s)
        return s, y

    s0 = jnp.zeros((B, nh, hd, ns), jnp.float32)
    _, ys = jax.lax.scan(step, s0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
