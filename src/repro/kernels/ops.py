"""jit'd public wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; elsewhere (this CPU
container) they execute in `interpret=True` mode, which runs the exact
kernel body per grid step — correctness-identical, used by the test
sweeps. `use_pallas()` reports whether the native path is available.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.split_matmul import split_matmul as _split_matmul
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interp(interpret):
    return (not use_pallas()) if interpret is None else interpret


def split_matmul(x, w, *, bm: int = 512, bn: int = 512, bk: int = 512,
                 interpret=None):
    return _split_matmul(x, w, bm=bm, bn=bn, bk=bk,
                         interpret=_interp(interpret))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret=None):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=_interp(interpret))


def ssd_scan(x, dt, a_log, b, c, *, chunk: int = 256, bh: int = 0,
             interpret=None):
    return _ssd_scan(x, dt, a_log, b, c, chunk=chunk, bh=bh,
                     interpret=_interp(interpret))
