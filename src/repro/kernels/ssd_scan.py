"""ssd_scan — Mamba2 SSD chunk scan as a TPU Pallas kernel.

Grid: (B, nh/bh, S/Q) with the chunk dimension sequential; the running
inter-chunk state (bh, hd, ns) lives in VMEM scratch. Each grid step
computes the intra-chunk quadratic form (Q x Q attention-like matrix,
MXU work) plus the contribution of the carried state, then updates the
state — the chunk-parallel/recurrent split of the SSD paper mapped
onto the (parallel, parallel, arbitrary) TPU grid.

Layouts: x (B, S, nh, hd), dt (B, S, nh), b/c (B, S, ns), a_log (nh,)
-> y (B, S, nh, hd). Single B/C group shared by all heads (as in the
model path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, bh, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, bh)
    a = -jnp.exp(a_ref[...].astype(jnp.float32))   # (bh,)
    b = b_ref[0].astype(jnp.float32)          # (Q, ns)
    c = c_ref[0].astype(jnp.float32)          # (Q, ns)
    Q, bh, hd = x.shape

    dA = dt * a[None, :]                      # (Q, bh) log-decay
    csum = jnp.cumsum(dA, axis=0)             # (Q, bh)
    xd = x * dt[:, :, None]                   # (Q, bh, hd)

    # intra-chunk quadratic form
    diff = csum[:, None, :] - csum[None, :, :]          # (Q, Q, bh)
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask before exp (masked diffs are positive -> inf otherwise)
    att = jnp.exp(jnp.where(mask[:, :, None], diff, -jnp.inf))
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jnp.einsum("ij,ijh,jhd->ihd", cb, att, xd)

    # contribution of the carried state + state update
    s_prev = state_ref[...]                   # (bh, hd, ns)
    y = y + jnp.exp(csum)[:, :, None] * jnp.einsum(
        "is,hds->ihd", c, s_prev)
    decay_to_end = jnp.exp(csum[-1][None, :] - csum)    # (Q, bh)
    s_new = jnp.einsum("js,jh,jhd->hds", b, decay_to_end, xd)
    state_ref[...] = s_prev * jnp.exp(csum[-1])[:, None, None] + s_new

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bh", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 256, bh: int = 0,
             interpret: bool = False) -> jax.Array:
    """SSD over (B, S, nh, hd); returns y (no final state — training path)."""
    B, S, nh, hd = x.shape
    ns = b.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    bh = bh or nh
    assert nh % bh == 0, (nh, bh)
    grid = (B, nh // bh, S // Q)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, bh, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, bh), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, Q, ns), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, Q, ns), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, bh, hd),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, nh, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((bh, hd, ns), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b, c)
