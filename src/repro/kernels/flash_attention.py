"""flash_attention — online-softmax attention Pallas kernel (GQA + SWA).

Grid: (B * KV, S/bq, T/bk); the key dimension is iterated sequentially
with running (m, l, acc) carried in VMEM scratch, so the (S, T) score
matrix never exists and at most one (bk, hd) K/V tile is resident per
step. Sliding-window and causal masking are position arithmetic on
block indices; fully-masked key blocks still execute (uniform grid) but
contribute zero — the TPU production variant would prune them with a
grid remap, noted in EXPERIMENTS.md §Perf.

Layouts match the model path (models/attention.py):
  q: (B, KV, G, S, hd)   k/v: (B, KV, T, hd)   out like q
G folds into the score-matrix row dim ((bq*G, bk) MXU tiles).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, scale: float, t_valid: int):
    bq_i = pl.program_id(1)
    bk_i = pl.program_id(2)

    @pl.when(bk_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # (G, bq, hd)
    k = k_ref[0]                         # (bk, hd)
    v = v_ref[0]
    G, bq, hd = q.shape
    bk = k.shape[0]

    s = jax.lax.dot_general(
        q.reshape(G * bq, hd), k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (G*bq, bk)

    q_pos = bq_i * bq + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0
                                                 ) % bq
    # NOTE: rows are (g, q) pairs flattened; q index = row % bq
    k_pos = bk_i * bk + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
    mask = k_pos < t_valid
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                  # (G*bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p, v.astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(bk_i == pl.num_programs(2) - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.reshape(G, bq, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, S, hd); k, v: (B, KV, T, hd) -> like q."""
    B, KV, G, S, hd = q.shape
    T = k.shape[2]
    bq, bk = min(bq, S), min(bk, T)
    assert S % bq == 0, (S, bq)
    tpad = (-T) % bk
    if tpad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, tpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tpad), (0, 0)))
    Tp = T + tpad
    # fold (B, KV) into one grid dim
    qf = q.reshape(B * KV, 1, G, S, hd).transpose(0, 1, 2, 3, 4)
    kf = k.reshape(B * KV, Tp, hd)
    vf = v.reshape(B * KV, Tp, hd)
    grid = (B * KV, S // bq, Tp // bk)
    kern = functools.partial(_kernel, causal=causal, window=window,
                             scale=1.0 / math.sqrt(hd), t_valid=T)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, hd), lambda b, i, j: (b, 0, 0, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, hd),
                               lambda b, i, j: (b, 0, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, 1, G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, KV, G, S, hd)
