"""split_matmul — OSDP operator splitting (§3.3) as a TPU Pallas kernel.

The paper splits a huge MatMul into slices processed sequentially so
only one gathered slice is live. On TPU the natural granularity is the
VMEM tile: this kernel blocks x:(M,K) @ w:(K,N) on a (M/bm, N/bn, K/bk)
grid with the K dimension iterated sequentially ("arbitrary" semantics)
and an fp32 VMEM accumulator — at any instant exactly one (bk, bn)
weight tile is resident on-chip, which *is* the paper's slice-and-sum
schedule with slice_granularity = K/bk (DESIGN.md §3).

Block shapes default to MXU-aligned 512x512x512 and are clamped to the
problem size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def split_matmul(x: jax.Array, w: jax.Array, *, bm: int = 512,
                 bn: int = 512, bk: int = 512,
                 interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N); K blocked sequentially."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"dims {(m, k, n)} must divide blocks {(bm, bk, bn)}")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
